"""End-to-end driver: fault-tolerant distributed subgraph counting service.

Runs the paper's workload (PGBSC on an RMAT graph) across a simulated
8-device (pod=2, data=2, model=2) mesh with per-iteration checkpointing —
kill it mid-run and rerun: it resumes from the ledger.

    PYTHONPATH=src python examples/distributed_counting.py [--iters 32]
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse


from repro.core import count_subgraphs_exact, get_template
from repro.core.distributed import DistributedPgbsc
from repro.core.runner import EstimatorRunner, distributed_counter
from repro.graph import erdos_renyi
from repro.launch.mesh import make_mesh

ap = argparse.ArgumentParser()
ap.add_argument("--iters", type=int, default=32)
ap.add_argument("--ledger", default="/tmp/pgbsc_ledger")
args = ap.parse_args()

g = erdos_renyi(200, 6.0, seed=4)
t = get_template("u5")
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
print(f"mesh: {dict(mesh.shape)}  graph: n={g.n} m={g.m}  template: {t}")

dist = DistributedPgbsc(g, t, mesh)
runner = EstimatorRunner(
    distributed_counter(dist, seed=3), k=t.k,
    automorphisms=t.automorphisms, n_iterations=args.iters,
    ledger_dir=args.ledger, checkpoint_every=4, seed=3)
res = runner.run()

print(f"estimate={res.count:.5g}  colorful_sum={res.colorful_sum:.4g}")
print(f"iterations done={len(res.completed)}  restarts={res.restarts}  "
      f"elapsed={res.elapsed_s:.1f}s")
exact = count_subgraphs_exact(g, t)
print(f"exact={exact}  rel_err={abs(res.count - exact) / exact:.3%}")
