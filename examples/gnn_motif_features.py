"""Motif-count features (the paper's engine) feeding a GraphSAGE classifier.

GSN-style integration: per-vertex subgraph-count estimates from PGBSC become
structural input features for the assigned GNN architectures. Trains two
GraphSAGE models — with and without motif features — on a synthetic
community-structured graph where motif counts are discriminative.

    PYTHONPATH=src python examples/gnn_motif_features.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import motif_features
from repro.configs import reduced_config
from repro.graph import Graph
from repro.models.gnn import gnn_forward, gnn_loss, init_gnn
from repro.optim.optimizer import AdamWConfig, adamw_update, init_adamw

# --- one connected graph, two planted vertex roles, degree-matched --------
# role 0 "pendant-star anchor": 5 extra leaf neighbors (star4-rich,
#        path4-poor: paths die at the leaves)
# role 1 "connected hub": 5 extra edges into the ER core (path4-rich)
# Degrees match, so only multi-hop tree-motif structure separates the roles —
# exactly what the paper's engine counts. Evaluation is on HELD-OUT nodes.
rng = np.random.default_rng(0)
n_core, n_roles = 120, 40
edges = [(i, int(x)) for i in range(n_core)
         for x in rng.integers(0, n_core, 2)]
anchors = rng.choice(n_core, n_roles * 2, replace=False)
labels_full = np.full(n_core, -1, np.int64)
nxt = n_core
for j, v in enumerate(anchors):
    role = j % 2
    labels_full[v] = role
    if role == 0:
        for _ in range(5):                    # pendant leaves
            edges.append((int(v), nxt))
            nxt += 1
    else:
        for x in rng.integers(0, n_core, 5):  # edges into the core
            edges.append((int(v), int(x)))
g = Graph.from_edges(nxt, np.asarray(edges))
labels = np.zeros(g.n, np.int32)
labels[anchors] = labels_full[anchors]
role_nodes = anchors
d0 = g.degrees[anchors[::2]].mean()
d1 = g.degrees[anchors[1::2]].mean()
print(f"avg degree: role0={d0:.1f} role1={d1:.1f} (matched)")
train_mask = np.zeros(g.n, np.float32)
train_mask[anchors[: n_roles]] = 1.0          # half the anchors train
eval_nodes = anchors[n_roles:]

# --- motif features from the paper's engine --------------------------------
# (path4 and star4 share one fused-plan engine: their common rooted
# sub-templates are computed once per coloring — see repro.api)
feats_motif = motif_features(g, ["u3", "path4", "star4"], n_iters=8, seed=1)
print("motif feature matrix:", feats_motif.shape,
      "\n  role0 (pendant-star) means:",
      feats_motif[anchors[::2]].mean(0).round(2),
      "\n  role1 (connected-hub) means:",
      feats_motif[anchors[1::2]].mean(0).round(2))

base_x = rng.normal(size=(g.n, 8)).astype(np.float32)  # uninformative


def train(x, tag):
    arch = reduced_config("graphsage-reddit")
    cfg = arch.model
    src, dst = g.edges_by_dst
    batch = {
        "x": jnp.asarray(x),
        "edge_index": jnp.asarray(np.stack([src, dst])),
        "labels": jnp.asarray(labels),
        "label_mask": jnp.asarray(train_mask),
        "node_graph": jnp.zeros((g.n,), jnp.int32),
    }
    params = init_gnn(jax.random.PRNGKey(0), cfg, d_in=x.shape[1])
    opt = init_adamw(params)
    ocfg = AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=200,
                       weight_decay=0.0)
    full = dict(batch, pool=False, n_graphs=1)

    @jax.jit
    def step(params, opt):
        loss, grads = jax.value_and_grad(
            lambda p: gnn_loss(p, cfg, full))(params)
        params, opt, m = adamw_update(ocfg, params, grads, opt)
        return params, opt, loss

    for it in range(150):
        params, opt, loss = step(params, opt)
    logits = gnn_forward(params, cfg, full)
    pred = np.asarray(jnp.argmax(logits, -1))
    acc = float((pred[eval_nodes] == labels[eval_nodes]).mean())
    print(f"{tag:28s} final_loss={float(loss):.4f} "
          f"held-out accuracy={acc:.3f}")
    return acc


acc_base = train(base_x, "random features")
acc_motif = train(np.concatenate([base_x, feats_motif], 1),
                  "random + motif features")
print(f"motif-feature gain on held-out anchors: "
      f"+{(acc_motif - acc_base) * 100:.1f} pts")
