"""Quickstart: count tree subgraphs in a graph with PGBSC.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (build_engine, count_subgraphs_exact, get_template)
from repro.graph import erdos_renyi

g = erdos_renyi(500, 8.0, seed=0)
print(f"graph: n={g.n} directed-edge-slots={g.m} avg_deg={g.avg_degree:.1f}")

for tname in ("u3", "u5", "u7"):
    t = get_template(tname)
    # batch_size chunks the estimator's coloring batches: each device call
    # runs 25 colorings through the plan at once (peak table memory per plan
    # node ~ batch_size * C(k, t) * n floats).
    engine = build_engine(g, t, engine="pgbsc", dedup=True, batch_size=25)
    est = engine.estimate(n_iters=50, seed=42)
    line = (f"{tname} (k={t.k}, aut={t.automorphisms}): "
            f"estimate={est['count']:.4g} +- {est['std']:.2g}")
    if g.n <= 60:  # exact verification is exponential; small graphs only
        line += f"  exact={count_subgraphs_exact(g, t)}"
    print(line)

# compare the three engines of the paper on a batch of colorings: one
# batched device call per engine instead of a Python loop
from repro.graph.coloring import batch_colorings
t = get_template("u5")
colorings = batch_colorings(7, range(8), g.n, t.k)   # (8, n) device-side
for eng in ("fascia", "pfascia", "pgbsc"):
    e = build_engine(g, t, eng)
    totals, _ = e.count_colorful_batch(colorings)
    print(f"{eng:8s} colorful-counts[0:3] = "
          f"{[round(float(v), 1) for v in totals[:3]]} "
          f"(work: {e.work.total_flops / 1e6:.1f} Mflop/coloring)")
