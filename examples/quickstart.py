"""Quickstart: count tree subgraphs in a graph with PGBSC.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (build_engine, count_subgraphs_exact, get_template)
from repro.graph import erdos_renyi

g = erdos_renyi(500, 8.0, seed=0)
print(f"graph: n={g.n} directed-edge-slots={g.m} avg_deg={g.avg_degree:.1f}")

for tname in ("u3", "u5", "u7"):
    t = get_template(tname)
    # batch_size chunks the estimator's coloring batches: each device call
    # runs 25 colorings through the plan at once (peak table memory per plan
    # node ~ batch_size * C(k, t) * n floats).
    engine = build_engine(g, t, engine="pgbsc", dedup=True, batch_size=25)
    est = engine.estimate(n_iters=50, seed=42)
    line = (f"{tname} (k={t.k}, aut={t.automorphisms}): "
            f"estimate={est['count']:.4g} +- {est['std']:.2g}")
    if g.n <= 60:  # exact verification is exponential; small graphs only
        line += f"  exact={count_subgraphs_exact(g, t)}"
    print(line)

# compare the three engines of the paper on a batch of colorings: one
# batched device call per engine instead of a Python loop
from repro.graph.coloring import batch_colorings
t = get_template("u5")
colorings = batch_colorings(7, range(8), g.n, t.k)   # (8, n) device-side
for eng in ("fascia", "pfascia", "pgbsc"):
    e = build_engine(g, t, eng)
    totals, _ = e.count_colorful_batch(colorings)
    print(f"{eng:8s} colorful-counts[0:3] = "
          f"{[round(float(v), 1) for v in totals[:3]]} "
          f"(work: {e.work.total_flops / 1e6:.1f} Mflop/coloring)")

# --- multi-request counting service ---------------------------------------
# Many tenants, one scheduler: requests carry a precision target
# (rel_stderr) instead of a fixed iteration budget, engines are cached by
# graph-content fingerprint, and requests sharing (graph, template, seed)
# consume one sample stream — the repeated u3 below adds no device work.
from repro.service import CountingService, CountRequest

svc = CountingService(round_size=16, default_max_iters=64)
svc.add_graph("demo", g)
rids = [svc.submit(CountRequest("demo", tname, rel_stderr=0.15))
        for tname in ("u3", "u5", "u3")]
svc.run()
for rid in rids:
    r = svc.result(rid)
    lo, hi = r.ci95
    print(f"service {rid}: estimate={r.estimate:.4g} +- {r.stderr:.2g} "
          f"ci95=[{lo:.4g}, {hi:.4g}] ({r.iterations} iters"
          f"{', shared' if r.shared_group else ''})")
stats = svc.stats()
print(f"service: {stats['engine_cache']['builds']} engine builds for "
      f"{stats['requests']} requests, "
      f"{stats['unique_iterations']} device iterations")
