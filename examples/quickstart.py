"""Quickstart: count tree subgraphs in a graph with PGBSC.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (build_engine, count_subgraphs_exact, get_template)
from repro.graph import erdos_renyi

g = erdos_renyi(500, 8.0, seed=0)
print(f"graph: n={g.n} directed-edge-slots={g.m} avg_deg={g.avg_degree:.1f}")

for tname in ("u3", "u5", "u7"):
    t = get_template(tname)
    engine = build_engine(g, t, engine="pgbsc", dedup=True)
    est = engine.estimate(n_iters=50, seed=42)
    line = (f"{tname} (k={t.k}, aut={t.automorphisms}): "
            f"estimate={est['count']:.4g} +- {est['std']:.2g}")
    if g.n <= 60:  # exact verification is exponential; small graphs only
        line += f"  exact={count_subgraphs_exact(g, t)}"
    print(line)

# compare the three engines of the paper on one coloring
from repro.graph.coloring import coloring_numpy
t = get_template("u5")
colors = coloring_numpy(7, 0, g.n, t.k)
for eng in ("fascia", "pfascia", "pgbsc"):
    e = build_engine(g, t, eng)
    total, _ = e.count_colorful(colors)
    print(f"{eng:8s} colorful-count = {float(total):.6g} "
          f"(work: {e.work.total_flops / 1e6:.1f} Mflop)")
