"""Quickstart: count tree subgraphs in a graph through the query API.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.api import TemplateSpec, count
from repro.core import count_subgraphs_exact, get_template
from repro.graph import erdos_renyi

g = erdos_renyi(500, 8.0, seed=0)
print(f"graph: n={g.n} directed-edge-slots={g.m} avg_deg={g.avg_degree:.1f}")

# --- one-call counting -----------------------------------------------------
# count() accepts registry names (sugar), dynamic path{k}/star{k} names,
# TemplateSpec objects, or raw edge lists; results carry the estimate, its
# standard error, and a 95% confidence interval.
for tname in ("u3", "u5", "u7"):
    t = get_template(tname)
    res = count(g, tname, max_iters=50, seed=42)
    line = (f"{tname} (k={t.k}, aut={t.automorphisms}): "
            f"estimate={res.estimate:.4g} +- {res.stderr:.2g}")
    if g.n <= 60:  # exact verification is exponential; small graphs only
        line += f"  exact={count_subgraphs_exact(g, t)}"
    print(line)

# an arbitrary user tree — no registry entry needed
chair = TemplateSpec(edges=((0, 1), (1, 2), (1, 3)), name="chair")
res = count(g, chair, max_iters=32, seed=7)
print(f"{chair.display_name} (hash {chair.canonical_hash[:8]}): "
      f"estimate={res.estimate:.4g} +- {res.stderr:.2g}")

# --- multi-template queries: cross-template subplan sharing ----------------
# count_many fuses same-k templates into ONE execution plan: canonical
# rooted sub-templates they share (paths, star arms) are computed once per
# coloring for the whole bundle. The SpMM column-op counters prove it.
from repro.api import CountQuery, compile_query

bundle = ["u5", "path5", "star5", "u7"]
cq = compile_query(g, CountQuery(templates=bundle, max_iters=16, seed=1))
results = cq.run()
for name, r in zip(bundle, results):
    print(f"count_many {name}: estimate={r.estimate:.4g} +- {r.stderr:.2g} "
          f"({r.iterations} iters{', fused' if r.shared_group else ''})")
fused_cols = sum(e.n_spmm_cols_dispatched for e in cq.engines)
solo_cols = 0
for name in bundle:
    solo = compile_query(g, CountQuery(templates=[name], max_iters=16, seed=1))
    solo.run()
    solo_cols += sum(e.n_spmm_cols_dispatched for e in solo.engines)
print(f"SpMM column-ops: fused={fused_cols} vs per-template={solo_cols} "
      f"({100 * (1 - fused_cols / solo_cols):.0f}% saved by subplan sharing)")

# --- multi-request counting service ----------------------------------------
# Many tenants, one scheduler: requests carry a precision target
# (rel_stderr) instead of a fixed iteration budget, engines are cached by
# graph-content fingerprint x template canonical hash, and requests whose
# templates are the SAME tree — by any spelling — consume one sample
# stream: the relabeled path4 edge list below adds no device work over the
# "path4" registry name.
from repro.service import CountingService, CountRequest

relabeled_path4 = TemplateSpec(edges=((3, 2), (2, 1), (1, 0)), root=3)
svc = CountingService(round_size=16, default_max_iters=64)
svc.add_graph("demo", g)
rids = [svc.submit(CountRequest("demo", tpl, rel_stderr=0.15))
        for tpl in ("u3", "path4", relabeled_path4)]
svc.run()
for rid in rids:
    r = svc.result(rid)
    lo, hi = r.ci95
    print(f"service {rid}: estimate={r.estimate:.4g} +- {r.stderr:.2g} "
          f"ci95=[{lo:.4g}, {hi:.4g}] ({r.iterations} iters"
          f"{', shared' if r.shared_group else ''})")
stats = svc.stats()
print(f"service: {stats['engine_cache']['builds']} engine builds for "
      f"{stats['requests']} requests, {stats['groups']} dispatch groups, "
      f"{stats['unique_iterations']} device iterations")
