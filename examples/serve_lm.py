"""Serve a reduced LM with batched decode requests (prefill + decode loop).

    PYTHONPATH=src python examples/serve_lm.py
"""

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.synthetic import lm_token_stream
from repro.models.transformer import (init_decode_cache, init_lm,
                                      lm_decode_step)

base = get_config("gemma3-1b")   # exercises local/global attention serving
model = dataclasses.replace(
    base.model, n_layers=6, d_model=128, n_heads=4, n_kv_heads=1, d_ff=256,
    vocab_size=1024, d_head=32, sliding_window=16, global_every=6,
    param_dtype=jnp.float32, remat=False)

BATCH, PROMPT, GEN, S_MAX = 4, 24, 16, 64
params = init_lm(jax.random.PRNGKey(0), model)

# chunked prefill (Sarathi-style): fills the KV cache in sequence chunks —
# peak attention memory O(chunk x prefix) instead of O(prompt^2)
from repro.models.transformer import lm_prefill_chunked
cache = init_decode_cache(model, BATCH, S_MAX, dtype=jnp.float32)
prompt = lm_token_stream(jax.random.PRNGKey(1), BATCH, PROMPT,
                         model.vocab_size)
decode = jax.jit(lambda p, c, t: lm_decode_step(p, model, c, t))

t0 = time.time()
logits, cache = jax.jit(
    lambda p, t, c: lm_prefill_chunked(p, model, t, c, chunk=8)
)(params, prompt, cache)
print(f"chunked prefill({PROMPT} tokens x {BATCH} requests): "
      f"{time.time() - t0:.2f}s")

# batched greedy decode
out_tokens = []
tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
t0 = time.time()
for _ in range(GEN):
    out_tokens.append(tok)
    logits, cache = decode(params, cache, tok)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
dt = time.time() - t0
gen = jnp.concatenate(out_tokens, axis=1)
print(f"generated {GEN} tokens x {BATCH} requests in {dt:.2f}s "
      f"({BATCH * GEN / dt:.1f} tok/s)")
print("sample:", gen[0].tolist())
assert int(cache["len"]) == PROMPT + GEN
