"""Train a reduced smollm-family LM for a few hundred steps with the full
substrate: synthetic pipeline, AdamW + cosine, checkpoint/resume.

    PYTHONPATH=src python examples/train_lm.py --steps 100 [--resume]

(~15M params at the default reduced width; the loss should drop visibly
within 100 steps on the synthetic zipf stream.)
"""

import argparse
import dataclasses
import time

import jax

from repro.configs import get_config
from repro.configs.base import ShapeCell
from repro.data.synthetic import lm_token_stream
from repro.optim.optimizer import AdamWConfig
from repro.train.checkpoint import restore_checkpoint, save_checkpoint
from repro.train.step import build_train_step, concrete_train_state

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=100)
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--ckpt", default="/tmp/lm_ckpt")
ap.add_argument("--resume", action="store_true")
args = ap.parse_args()

base = get_config("smollm-360m")
model = dataclasses.replace(
    base.model, n_layers=4, d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
    vocab_size=2048, param_dtype=jax.numpy.float32, remat=False)
arch = dataclasses.replace(
    base, model=model,
    cells=(ShapeCell("train", "train",
                     {"seq": args.seq, "batch": args.batch}),))

n_params = sum(x.size for x in jax.tree_util.tree_leaves(
    concrete_train_state(arch, jax.random.PRNGKey(0))["params"]))
print(f"params: {n_params / 1e6:.1f}M")

state = concrete_train_state(arch, jax.random.PRNGKey(0))
start = 0
if args.resume:
    restored, extras = restore_checkpoint(args.ckpt, state)
    if restored is not None:
        state, start = restored, extras["step"]
        print(f"resumed from step {start}")

step_fn = jax.jit(build_train_step(
    arch, AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)))

t0 = time.time()
for it in range(start, args.steps):
    key = jax.random.fold_in(jax.random.PRNGKey(1234), it)
    toks = lm_token_stream(key, args.batch, args.seq + 1, model.vocab_size)
    batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
    state, metrics = step_fn(state, batch)
    if it % 10 == 0 or it == args.steps - 1:
        print(f"step {it:4d} loss={float(metrics['loss']):.4f} "
              f"lr={float(metrics['lr']):.2e} "
              f"gnorm={float(metrics['grad_norm']):.2f} "
              f"({(time.time() - t0):.1f}s)", flush=True)
    if (it + 1) % 50 == 0:
        save_checkpoint(args.ckpt, it + 1, state, extras={"step": it + 1})
        print(f"checkpointed at step {it + 1}")
print("done")
