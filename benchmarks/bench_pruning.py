"""Paper Fig. 9: pruning speedup (PFASCIA vs FASCIA) vs graph skew.

RMAT skew grows with the `a` parameter (paper uses K=3,5,8 kroneker
skews); the pruning win should grow with skew because redundant neighbor
traversals are proportional to degree.
"""

from __future__ import annotations

from benchmarks.common import emit, timeit
from repro.core import build_engine, get_template
from repro.graph import rmat
from repro.graph.coloring import coloring_numpy

SKEWS = {"low": 0.45, "mid": 0.57, "high": 0.65}


def run() -> dict:
    t = get_template("u7")
    out = {}
    for name, a in SKEWS.items():
        rest = (1.0 - a) / 3
        g = rmat(10, 16, a=a, b=rest, c=rest, seed=1)
        colors = coloring_numpy(1, 0, g.n, t.k)
        e_f = build_engine(g, t, "fascia")
        e_p = build_engine(g, t, "pfascia")
        tf = timeit(lambda: e_f.count_colorful(colors)[0])
        tp = timeit(lambda: e_p.count_colorful(colors)[0])
        emit(f"fig9/skew_{name}/fascia", tf * 1e6,
             f"max_deg={g.max_degree}")
        emit(f"fig9/skew_{name}/pfascia", tp * 1e6,
             f"speedup=x{tf / tp:.2f}")
        out[name] = tf / tp
    return out
