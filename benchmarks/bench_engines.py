"""Paper Fig. 8 + Fig. 15: engine runtime vs template size (+ speedups).

FASCIA vs PFASCIA vs PGBSC on RMAT graphs, increasing template size. The
paper's headline claim — the pruning speedup grows with template size and
graph skew, and vectorized PGBSC adds a further constant factor — must
reproduce qualitatively on CPU (absolute numbers are hardware-specific).

Also sweeps the batched estimator pipeline (``batch/...`` rows): estimator
iterations/sec for the sequential per-coloring loop vs. batched dispatch at
increasing batch sizes — the dispatch-overhead lever of the batch PR.
"""

from __future__ import annotations


from benchmarks.common import emit, timeit
from repro.core import build_engine, get_template
from repro.graph import rmat
from repro.graph.coloring import coloring_numpy

GRAPH_SCALE = 11          # 2048 vertices
EDGE_FACTOR = 16
TEMPLATES = ("u5", "u7", "u10")
ENGINES = ("fascia", "pfascia", "pgbsc")
BATCH_SIZES = (1, 8, 16)
BATCH_ITERS = 16          # estimator iterations per throughput measurement


def run() -> dict:
    g = rmat(GRAPH_SCALE, EDGE_FACTOR, seed=0)
    results: dict[str, dict[str, float]] = {}
    for tname in TEMPLATES:
        t = get_template(tname)
        colors = coloring_numpy(0, 0, g.n, t.k)
        times = {}
        vals = {}
        for eng in ENGINES:
            e = build_engine(g, t, eng)
            sec = timeit(lambda: e.count_colorful(colors)[0])
            times[eng] = sec
            vals[eng] = float(e.count_colorful(colors)[0])
            emit(f"fig8/{tname}/{eng}", sec * 1e6,
                 f"count={vals[eng]:.6g}")
        # identical results across engines (paper §7.4)
        ref = vals["pgbsc"]
        for eng in ENGINES:
            rel = abs(vals[eng] - ref) / max(abs(ref), 1e-30)
            assert rel < 1e-5, (tname, eng, vals)
        emit(f"fig15/{tname}/speedup_pgbsc_vs_fascia",
             times["fascia"] / times["pgbsc"] * 1e6,
             f"x{times['fascia'] / times['pgbsc']:.2f}")
        results[tname] = times

    results["batch"] = _bench_batched(g)
    return results


def _bench_batched(g) -> dict[str, float]:
    """Estimator iterations/sec: sequential loop vs batched pipeline."""
    t = get_template("u5")
    e = build_engine(g, t, "pgbsc")
    out: dict[str, float] = {}

    def sequential():
        vals = []
        for it in range(BATCH_ITERS):
            colors = coloring_numpy(0, it, g.n, t.k)
            vals.append(e.count_colorful(colors)[0])
        return vals

    sec_seq = timeit(sequential)
    out["sequential"] = BATCH_ITERS / sec_seq
    emit("batch/u5/sequential", sec_seq / BATCH_ITERS * 1e6,
         f"{out['sequential']:.1f} iters/s")

    for bs in BATCH_SIZES:
        sec = timeit(lambda: list(e.count_iterations_batch(
            range(BATCH_ITERS), seed=0, batch_size=bs).values()))
        out[f"bs{bs}"] = BATCH_ITERS / sec
        emit(f"batch/u5/bs{bs}", sec / BATCH_ITERS * 1e6,
             f"{out[f'bs{bs}']:.1f} iters/s "
             f"x{sec_seq / sec:.2f} vs sequential")
    return out
