"""Paper Fig. 8 + Fig. 15: engine runtime vs template size (+ speedups).

FASCIA vs PFASCIA vs PGBSC on RMAT graphs, increasing template size. The
paper's headline claim — the pruning speedup grows with template size and
graph skew, and vectorized PGBSC adds a further constant factor — must
reproduce qualitatively on CPU (absolute numbers are hardware-specific).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import build_engine, get_template
from repro.graph import rmat
from repro.graph.coloring import coloring_numpy

GRAPH_SCALE = 11          # 2048 vertices
EDGE_FACTOR = 16
TEMPLATES = ("u5", "u7", "u10")
ENGINES = ("fascia", "pfascia", "pgbsc")


def run() -> dict:
    g = rmat(GRAPH_SCALE, EDGE_FACTOR, seed=0)
    results: dict[str, dict[str, float]] = {}
    for tname in TEMPLATES:
        t = get_template(tname)
        colors = coloring_numpy(0, 0, g.n, t.k)
        times = {}
        vals = {}
        for eng in ENGINES:
            e = build_engine(g, t, eng)
            sec = timeit(lambda: e.count_colorful(colors)[0])
            times[eng] = sec
            vals[eng] = float(e.count_colorful(colors)[0])
            emit(f"fig8/{tname}/{eng}", sec * 1e6,
                 f"count={vals[eng]:.6g}")
        # identical results across engines (paper §7.4)
        ref = vals["pgbsc"]
        for eng in ENGINES:
            rel = abs(vals[eng] - ref) / max(abs(ref), 1e-30)
            assert rel < 1e-5, (tname, eng, vals)
        emit(f"fig15/{tname}/speedup_pgbsc_vs_fascia",
             times["fascia"] / times["pgbsc"] * 1e6,
             f"x{times['fascia'] / times['pgbsc']:.2f}")
        results[tname] = times
    return results
