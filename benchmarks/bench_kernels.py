"""Paper Table 5 / Fig. 10 analog: kernel-level throughput.

Effective bandwidth (GB/s over the algorithmically-required bytes) of the
SpMM backends and the eMA kernel on this host. The paper's claim: the
GraphBLAS formulation turns irregular per-vertex traversal into streaming
kernels that saturate memory bandwidth (their eMA hits ~110+ GB/s on
Skylake; the segment/ELL XLA paths here play that role on CPU).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.colorsets import split_tables
from repro.graph import rmat
from repro.kernels.ema.ops import ema_xla
from repro.kernels.spmm import ops as spmm_ops

N_ROWS = 64


def run() -> dict:
    g = rmat(13, 16, seed=2)   # 8192 vertices, ~260k directed edges
    rng = np.random.default_rng(0)
    m = jnp.asarray(rng.random((N_ROWS, g.n), np.float32))
    out = {}

    for method in ("segment", "ell", "dense"):
        prep = spmm_ops.prepare(g, method)
        sec = timeit(lambda: spmm_ops.spmm(m, prep))
        # required traffic: read m values once per edge + write out
        bytes_req = 4 * (g.m * N_ROWS + 2 * g.n * N_ROWS)
        gbs = bytes_req / sec / 1e9
        emit(f"table5/spmm_{method}", sec * 1e6, f"{gbs:.1f}GB/s")
        out[f"spmm_{method}"] = gbs

    # eMA: k=10 sub-template of size 5 split 2+3
    ia, ip = split_tables(10, 5, 2)
    m_a = jnp.asarray(rng.random((45, g.n), np.float32))
    y_p = jnp.asarray(rng.random((120, g.n), np.float32))
    ia_j, ip_j = jnp.asarray(ia), jnp.asarray(ip)
    sec = timeit(lambda: ema_xla(m_a, y_p, ia_j, ip_j))
    s, l = ia.shape
    bytes_req = 4 * g.n * (2 * s * l + s)
    gbs = bytes_req / sec / 1e9
    flops = 2 * g.n * s * l / sec / 1e9
    emit("table5/ema_xla", sec * 1e6, f"{gbs:.1f}GB/s|{flops:.1f}GFLOP/s")
    out["ema"] = gbs
    return out
