"""Memory-aware executor benchmark: peak table bytes + throughput vs budget.

Rows (benchmarks.common.emit):

  memory/model/<tmpl>/<plan>            modeled bytes: keep-everything vs
                                        liveness-scheduled peak (batch=1)
  memory/budget/<tmpl>/<MiB>mb          estimator us/iteration at the
                                        budget-derived batch size
  memory/chunked/b12                    k=12 binary template under a budget
                                        the unchunked executor exceeds

``--smoke`` runs only the k=12 assertion (the CI step): the chunked path
must complete — and match the unchunked result to 1e-6 — under a budget
whose unchunked peak does not fit.
"""

from __future__ import annotations

import sys


from benchmarks.common import emit, timeit
from repro.core import build_engine, get_template
from repro.core import executor as ex
from repro.core.templates import TreeTemplate
from repro.graph import erdos_renyi, rmat

BINARY12 = TreeTemplate([((i - 1) // 2, i) for i in range(1, 12)],
                        name="b12")


def _model_rows(tname: str) -> None:
    t = get_template(tname)
    for pname in ("dedup", "optimized"):
        plan = {"dedup": t.plan_dedup, "optimized": t.plan_optimized}[pname]
        n = 1 << 14                      # per-vertex-scaled reference size
        keep = ex.keep_everything_bytes(plan, t.k, n)
        sched = ex.compute_schedule(plan, t.k)
        peak = ex.peak_table_bytes(plan, t.k, n, schedule=sched)
        emit(f"memory/model/{tname}/{pname}", 0.0,
             f"keepall_mb={keep / 2**20:.2f};peak_mb={peak / 2**20:.2f};"
             f"saving={keep / max(peak, 1):.2f}x")


def _budget_sweep(g, tname: str, budgets_mb, iters: int = 16) -> None:
    t = get_template(tname)
    for mb in budgets_mb:
        e = build_engine(g, t, "pgbsc", plan="optimized",
                         memory_budget_bytes=int(mb * 2 ** 20))
        ids = list(range(iters))

        def run_iters():
            return e.count_iterations_batch(ids, seed=0)

        sec = timeit(run_iters, warmup=1, iters=2)
        emit(f"memory/budget/{tname}/{mb}mb", sec / iters * 1e6,
             f"batch={e.batch_size};"
             f"peak_mb={e.peak_table_bytes / 2**20:.2f};"
             f"iters_per_s={iters / sec:.1f}")


def smoke() -> int:
    """CI assertion: k=12 completes under a budget the unchunked path
    exceeds, matching the unchunked result to 1e-6 relative error."""
    g = erdos_renyi(48, 3.0, seed=3)
    plan = BINARY12.plan_dedup
    ref = build_engine(g, BINARY12, "pgbsc", plan="dedup")
    budget = 2200 * g.n * 4
    unchunked_peak = ex.peak_table_bytes(plan, 12, g.n,
                                         schedule=ref.schedule)
    keep = ex.keep_everything_bytes(plan, 12, g.n)
    assert keep > budget, "always-live walk must exceed the smoke budget"
    assert unchunked_peak > budget, \
        "unchunked executor must exceed the smoke budget"
    e = build_engine(g, BINARY12, "pgbsc", plan="dedup",
                     memory_budget_bytes=budget)
    assert e.schedule.chunk_map, "budget must force colorset chunking"
    assert e.exec_choice.fits and e.exec_choice.peak_bytes <= budget
    from repro.graph.coloring import coloring_numpy
    colors = coloring_numpy(0, 0, g.n, 12)
    want = float(ref.count_colorful(colors)[0])
    got = float(e.count_colorful(colors)[0])
    rel = abs(got - want) / max(abs(want), 1e-30)
    assert rel <= 1e-6, (got, want, rel)
    print(f"memory smoke OK: k=12 b12 under {budget} bytes "
          f"(keepall={keep}, unchunked_peak={unchunked_peak}, "
          f"chunks={dict(e.schedule.chunk_map)}, rel_err={rel:.2e})")
    return 0


def run() -> None:
    for tname in ("u7", "u10", "u12"):
        _model_rows(tname)
    g = rmat(10, 16, seed=0)
    _budget_sweep(g, "u7", (0.5, 2, 8, 32))
    # the chunked regime: a budget the unchunked b12 walk exceeds
    gb = erdos_renyi(48, 3.0, seed=3)
    e = build_engine(gb, BINARY12, "pgbsc", plan="dedup",
                     memory_budget_bytes=2200 * gb.n * 4)
    from repro.graph.coloring import coloring_numpy
    colors = coloring_numpy(0, 0, gb.n, 12)
    sec = timeit(lambda: e.count_colorful(colors)[0], warmup=1, iters=2)
    emit("memory/chunked/b12", sec * 1e6,
         f"chunks={len(e.schedule.chunk_map)};"
         f"peak_mb={e.peak_table_bytes / 2**20:.3f}")


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        sys.exit(smoke())
    run()
