"""Paper Fig. 13 analog: scaling of the distributed engine with device count.

Thread scaling on the paper's Skylake node becomes device scaling of the
shard_map ring engine here (1 real core under the hood, so this measures
partitioning overhead, not true speedup — the trend of interest is that the
ring decomposition stays correct and the per-device work shrinks).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit

_WORKER = """
import os, sys, json, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
import jax
from repro.core import get_template
from repro.core.distributed import DistributedPgbsc
from repro.graph import rmat
from repro.launch.mesh import make_mesh

d = %d
g = rmat(10, 16, seed=7)
t = get_template("u5")
mesh = make_mesh((d, 1), ("data", "model"))
dist = DistributedPgbsc(g, t, mesh)
step, args, _ = dist.count_step_fn()
f = jax.jit(step)
out = f(*args); out.block_until_ready()
t0 = time.time()
for _ in range(3):
    out = f(*args)
out.block_until_ready()
rec = {"devices": d, "sec": (time.time() - t0) / 3, "count": float(out[0]),
       "batch": {}}

# batched per-pod dispatch: iterations/sec vs batch size (one scanned
# device call per batch; warm cache first so jit cost is excluded)
n_iters = 8
for bs in (1, 4, 8):
    dist.count_iterations(list(range(n_iters)), seed=0, batch_size=bs)
    t0 = time.time()
    dist.count_iterations(list(range(n_iters)), seed=0, batch_size=bs)
    rec["batch"]["bs%%d" %% bs] = n_iters / (time.time() - t0)
print(json.dumps(rec))
"""


def run() -> dict:
    out = {}
    counts = {}
    for d in (1, 2, 4, 8):
        env = dict(os.environ)
        env["PYTHONPATH"] = "src"
        proc = subprocess.run(
            [sys.executable, "-c", _WORKER % (d, d)], env=env,
            capture_output=True, text=True, timeout=600)
        if proc.returncode != 0:
            emit(f"fig13/devices{d}", -1, "FAILED")
            continue
        rec = json.loads(proc.stdout.strip().splitlines()[-1])
        emit(f"fig13/devices{d}", rec["sec"] * 1e6,
             f"count={rec['count']:.6g}")
        for bs, ips in rec["batch"].items():
            emit(f"fig13/devices{d}/batch/{bs}", 1e6 / ips,
                 f"{ips:.1f} iters/s")
        out[d] = rec["sec"]
        counts[d] = rec["count"]
    # ring decomposition must be device-count invariant up to f32
    # reassociation (counts here exceed 2^24, so exactness doesn't apply)
    vals = list(counts.values())
    if vals:
        spread = (max(vals) - min(vals)) / max(abs(max(vals)), 1e-30)
        assert spread < 1e-6, counts
    return out
