"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig8,fig9,...]

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit).
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks.common import header

BENCHES = {
    "fig8_engines": "benchmarks.bench_engines",
    "fig9_pruning": "benchmarks.bench_pruning",
    "table5_kernels": "benchmarks.bench_kernels",
    "fig11_roofline": "benchmarks.bench_roofline",
    "fig13_scaling": "benchmarks.bench_scaling",
    "fig14_error": "benchmarks.bench_error",
    "plans_beyond_paper": "benchmarks.bench_plans",
    "service": "benchmarks.bench_service",
    "memory": "benchmarks.bench_memory",
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all",
                    help="comma-separated bench keys (see BENCHES)")
    args = ap.parse_args(argv)
    keys = list(BENCHES) if args.only == "all" else args.only.split(",")

    header()
    failures = []
    for key in keys:
        mod_name = BENCHES[key]
        t0 = time.time()
        print(f"# --- {key} ({mod_name}) ---", flush=True)
        try:
            mod = __import__(mod_name, fromlist=["run"])
            mod.run()
            print(f"# {key} done in {time.time() - t0:.1f}s", flush=True)
        except Exception:
            failures.append(key)
            print(f"# {key} FAILED:\n{traceback.format_exc()}", flush=True)
    if failures:
        print(f"# FAILURES: {failures}")
        return 1
    print("# all benchmarks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
