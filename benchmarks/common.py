"""Shared benchmark utilities: timed runs + CSV emission."""

from __future__ import annotations

import time

import jax
import numpy as np

ROWS: list[tuple] = []


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds; blocks on jax results."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def header() -> None:
    print("name,us_per_call,derived", flush=True)
