"""Paper Fig. 14: relative error of the estimator (and cross-engine fp drift).

Two claims: (a) FASCIA and PGBSC agree to ~1e-6 relative (pure fp
reassociation); (b) the (eps, delta) estimator converges to the exact count.
"""

from __future__ import annotations


from benchmarks.common import emit
from repro.core import build_engine, count_subgraphs_exact, get_template
from repro.graph import erdos_renyi
from repro.graph.coloring import coloring_numpy


def run() -> dict:
    out = {}
    g = erdos_renyi(200, 6.0, seed=3)
    for tname in ("u3", "path4", "u5"):
        t = get_template(tname)
        colors = coloring_numpy(2, 0, g.n, t.k)
        engines = {e: build_engine(g, t, e) for e in
                   ("fascia", "pfascia", "pgbsc")}
        vals = {e: float(eng.count_colorful(colors)[0])
                for e, eng in engines.items()}
        ref = vals["fascia"]
        drift = max(abs(v - ref) / max(abs(ref), 1e-30)
                    for v in vals.values())
        emit(f"fig14/{tname}/engine_drift", 0.0, f"rel={drift:.2e}")
        out[f"{tname}/drift"] = drift

    g2 = erdos_renyi(40, 4.0, seed=4)
    t = get_template("path4")
    exact = count_subgraphs_exact(g2, t)
    eng = build_engine(g2, t, "pgbsc")
    for iters in (10, 50, 200):
        est = eng.estimate(n_iters=iters, seed=5)
        rel = abs(est["count"] - exact) / exact
        emit(f"fig14/estimator_iters{iters}", 0.0,
             f"rel={rel:.3e}|exact={exact:.0f}")
        out[f"iters{iters}"] = rel
    return out
