"""Counting-service benchmarks: request throughput and cache-hit speedup.

    PYTHONPATH=src python -m benchmarks.run --only service

Rows (CSV, via benchmarks.common):

* ``service/cold_first_request``   — engine build + compile + run (the cost
  an uncached tenant pays once per (graph, template, plan)).
* ``service/warm_repeat_request``  — same query again: engine cache hit +
  answer from the group's existing sample stream.
* ``service/estimate_cache_hit``   — repeat query through the persistent
  estimate cache in a fresh service (no engine build, no dispatch).
* ``service/throughput_mixed``     — requests/sec over a mixed-template,
  distinct-seed workload on a warm service (steady-state scheduling +
  real device work per request).
* ``service/latency_p50|p95|p99``  — mixed-workload request latency
  percentiles, read from the obs registry's
  ``service_request_total_seconds`` histogram (the same numbers a
  ``serve --metrics-out`` snapshot reports).

A machine-readable summary is written to ``BENCH_service.json`` at the
repo root (committed, so latency drift shows up in review).
"""

from __future__ import annotations

import json
import os
import tempfile
import time

from benchmarks.common import emit
from repro.graph import rmat
from repro.obs.metrics import (MetricsRegistry, get_registry, set_registry,
                               snapshot)
from repro.service import CountingService, CountRequest, EstimateCache

GRAPH_SCALE = 9           # 512 vertices
EDGE_FACTOR = 16
TEMPLATES = ("u3", "u5", "path4", "star4")
REQUESTS_PER_TEMPLATE = 4


def _run_one(svc, template, rel=0.1, seed=0):
    rid = svc.submit(CountRequest("g", template, rel_stderr=rel, seed=seed))
    svc.run()
    return svc.result(rid)


def run() -> dict:
    # fresh registry: this benchmark owns its counters/histograms
    set_registry(MetricsRegistry())
    g = rmat(GRAPH_SCALE, EDGE_FACTOR, seed=0)
    out: dict = {}

    # cold vs warm on one template --------------------------------------
    fd, est_path = tempfile.mkstemp(suffix=".json", prefix="pgbsc_bench_est_")
    os.close(fd)
    os.unlink(est_path)   # EstimateCache treats a missing file as empty
    svc = CountingService(round_size=16, default_max_iters=64,
                          estimate_cache=est_path)
    svc.add_graph("g", g)
    t0 = time.perf_counter()
    _run_one(svc, "u5")
    cold = time.perf_counter() - t0
    emit("service/cold_first_request", cold * 1e6, "build+compile+run")
    out["cold_s"] = cold

    t0 = time.perf_counter()
    _run_one(svc, "u5")
    warm = time.perf_counter() - t0
    emit("service/warm_repeat_request", warm * 1e6,
         f"speedup={cold / max(warm, 1e-9):.1f}x")
    out["warm_s"] = warm

    svc2 = CountingService(round_size=16, default_max_iters=64,
                           estimate_cache=EstimateCache(est_path))
    svc2.add_graph("g", g)
    t0 = time.perf_counter()
    _run_one(svc2, "u5")
    hit = time.perf_counter() - t0
    emit("service/estimate_cache_hit", hit * 1e6,
         f"speedup={cold / max(hit, 1e-9):.1f}x")
    out["estimate_hit_s"] = hit
    os.unlink(est_path)

    # mixed-workload throughput on a warm service -----------------------
    warm_svc = CountingService(round_size=16, default_max_iters=32)
    warm_svc.add_graph("g", g)
    for t in TEMPLATES:                      # warm engines + compile
        _run_one(warm_svc, t)
    # reset so the latency histogram covers only the mixed workload
    get_registry().reset()
    n_req = REQUESTS_PER_TEMPLATE * len(TEMPLATES)
    t0 = time.perf_counter()
    for i in range(n_req):
        # distinct seeds defeat the estimate/sample caches: every request
        # does real device work, measuring steady-state scheduling + compute
        _run_one(warm_svc, TEMPLATES[i % len(TEMPLATES)], seed=100 + i)
    dt = time.perf_counter() - t0
    emit("service/throughput_mixed", dt / n_req * 1e6,
         f"req_per_s={n_req / dt:.2f}")
    out["req_per_s"] = n_req / dt

    # per-request latency percentiles from the obs registry -------------
    hist = get_registry().histogram("service_request_total_seconds")
    pcts = {"p50": hist.percentile(0.50), "p95": hist.percentile(0.95),
            "p99": hist.percentile(0.99)}
    for label, v in pcts.items():
        emit(f"service/latency_{label}", v * 1e6, f"n={hist.count}")
        out[f"latency_{label}_ms"] = v * 1e3

    st = warm_svc.stats()
    print(f"# warm service: {st['engine_cache']['builds']} builds / "
          f"{st['requests']} requests, "
          f"{st['unique_iterations']} device iterations", flush=True)

    summary = {
        "bench": "service",
        "graph": f"rmat:{GRAPH_SCALE} x{EDGE_FACTOR}",
        "templates": list(TEMPLATES),
        "requests_mixed": n_req,
        "cold_s": out["cold_s"], "warm_s": out["warm_s"],
        "estimate_hit_s": out["estimate_hit_s"],
        "req_per_s": out["req_per_s"],
        "latency_ms": {label: v * 1e3 for label, v in pcts.items()},
        "service_stats": st,
        "metrics_snapshot": snapshot(),
    }
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_service.json")
    with open(path, "w") as f:
        json.dump(summary, f, indent=1, sort_keys=True)
    print(f"# wrote {path}", flush=True)
    return out


if __name__ == "__main__":
    from benchmarks.common import header
    header()
    run()
