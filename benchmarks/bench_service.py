"""Counting-service benchmarks: micro rows + a QoS serving load harness.

    PYTHONPATH=src python -m benchmarks.run --only service
    PYTHONPATH=src python -m benchmarks.bench_service --seed 0
    PYTHONPATH=src python -m benchmarks.bench_service \\
        --http http://127.0.0.1:8080 --requests 50 --metrics-out SNAP.json

Micro rows (CSV, via benchmarks.common):

* ``service/cold_first_request``   — engine build + compile + run (the cost
  an uncached tenant pays once per (graph, template, plan)).
* ``service/warm_repeat_request``  — same query again: engine cache hit +
  answer from the group's existing sample stream.
* ``service/estimate_cache_hit``   — repeat query through the persistent
  estimate cache in a fresh service (no engine build, no dispatch).
* ``service/throughput_mixed``     — requests/sec over a mixed-template,
  distinct-seed workload on a warm service.
* ``service/latency_p50|p95|p99``  — mixed-workload request latency
  percentiles from ``service_request_total_seconds``.

Load harness (``--seed`` makes the class mix and open-loop arrival gaps
deterministic): the same seeded stream of interactive / batch / deadline
requests — each class drawing from its own template+seed pools, so
dispatch groups stay class-pure — is played twice:

* **sync baseline**: submit everything, then the round-barrier ``run()``
  (every round extends every group, so interactive tail latency is a
  function of total load);
* **async**: open-loop arrivals into :class:`AsyncCountingService`
  (deadline EDF ahead of interactive ahead of batch at every dispatch
  boundary).

Both runs share one pre-warmed :class:`EngineCache`, so the comparison
measures *scheduling*, not compiles. Per-class p50/p95/p99, req/s, the
interactive-p99 speedup, the shed/dropped counts, and a bitwise
estimate-equality check (async answers must equal the sync baseline's
exactly — shared streams are deterministic in (seed, iteration id)) all
land in ``BENCH_service.json`` at the repo root (committed, so drift
shows up in review).

``--http URL`` switches to a closed-loop driver for a live ``serve
--http`` server: a worker pool POSTs mixed-class ``/count`` bodies
(every 5th ``wait:false`` to exercise fire-and-forget + ``/result``
polling), polls every accepted request to a *terminal* status, tallies
done/shed/failed, and writes the server's ``/metrics.json`` snapshot to
``--metrics-out`` (the CI serving smoke validates it with
``repro.obs.validate``). ``--min-success FRAC`` makes the driver exit
nonzero unless that fraction of requests terminates ``done`` — the CI
chaos smoke's containment bar against a ``serve --inject`` server.
"""

from __future__ import annotations

import argparse
import json
import os
import random
import tempfile
import threading
import time

from benchmarks.common import emit, header
from repro.graph import rmat
from repro.obs.metrics import (MetricsRegistry, get_registry, set_registry,
                               snapshot)
from repro.service import (AsyncCountingService, CountingService,
                           CountRequest, EngineCache, EstimateCache, QoS)

GRAPH_SCALE = 9           # 512 vertices (micro rows)
EDGE_FACTOR = 16
TEMPLATES = ("u3", "u5", "path4", "star4")
REQUESTS_PER_TEMPLATE = 4

# ---------------------------------------------------------- load harness
LOAD_GRAPH_SCALE = 8      # 256 vertices: ~14 class-pure groups, real work
LOAD_REQUESTS = 1000
ROUND_SIZE = 8            # caps below are multiples => stable dispatch shape

# Each class owns its template + seed pools: requests of different classes
# never share a dispatch group, so QoS ordering is visible end to end.
# Caps are multiples of ROUND_SIZE and contracts are uniform per class, so
# every member of a group retires at the same iteration count — the
# bitwise sync/async comparison then holds per request, not just per group.
WORKLOAD = {
    "interactive": dict(weight=0.50, templates=("u3", "path4"),
                        seeds=(0, 1, 2, 3), rel_stderr=0.15, max_iters=24,
                        tenants=("alice", "bob"), deadline_s=None),
    "batch": dict(weight=0.35, templates=("u5", "star4"),
                  seeds=(10, 11), rel_stderr=0.05, max_iters=48,
                  tenants=("etl",), deadline_s=None),
    "deadline": dict(weight=0.15, templates=("u3",), seeds=(20, 21),
                     rel_stderr=None, max_iters=16, tenants=("sla",),
                     deadline_s=10.0),
}


def _run_one(svc, template, rel=0.1, seed=0):
    rid = svc.submit(CountRequest("g", template, rel_stderr=rel, seed=seed))
    svc.run()
    return svc.result(rid)


# ------------------------------------------------------------ micro rows
def _micro(out: dict) -> None:
    g = rmat(GRAPH_SCALE, EDGE_FACTOR, seed=0)

    # cold vs warm on one template --------------------------------------
    fd, est_path = tempfile.mkstemp(suffix=".json", prefix="pgbsc_bench_est_")
    os.close(fd)
    os.unlink(est_path)   # EstimateCache treats a missing file as empty
    svc = CountingService(round_size=16, default_max_iters=64,
                          estimate_cache=est_path)
    svc.add_graph("g", g)
    t0 = time.perf_counter()
    _run_one(svc, "u5")
    cold = time.perf_counter() - t0
    emit("service/cold_first_request", cold * 1e6, "build+compile+run")
    out["cold_s"] = cold

    t0 = time.perf_counter()
    _run_one(svc, "u5")
    warm = time.perf_counter() - t0
    emit("service/warm_repeat_request", warm * 1e6,
         f"speedup={cold / max(warm, 1e-9):.1f}x")
    out["warm_s"] = warm

    svc2 = CountingService(round_size=16, default_max_iters=64,
                           estimate_cache=EstimateCache(est_path))
    svc2.add_graph("g", g)
    t0 = time.perf_counter()
    _run_one(svc2, "u5")
    hit = time.perf_counter() - t0
    emit("service/estimate_cache_hit", hit * 1e6,
         f"speedup={cold / max(hit, 1e-9):.1f}x")
    out["estimate_hit_s"] = hit
    os.unlink(est_path)

    # mixed-workload throughput on a warm service -----------------------
    warm_svc = CountingService(round_size=16, default_max_iters=32)
    warm_svc.add_graph("g", g)
    for t in TEMPLATES:                      # warm engines + compile
        _run_one(warm_svc, t)
    # reset so the latency histogram covers only the mixed workload
    get_registry().reset()
    n_req = REQUESTS_PER_TEMPLATE * len(TEMPLATES)
    t0 = time.perf_counter()
    for i in range(n_req):
        # distinct seeds defeat the estimate/sample caches: every request
        # does real device work, measuring steady-state scheduling + compute
        _run_one(warm_svc, TEMPLATES[i % len(TEMPLATES)], seed=100 + i)
    dt = time.perf_counter() - t0
    emit("service/throughput_mixed", dt / n_req * 1e6,
         f"req_per_s={n_req / dt:.2f}")
    out["req_per_s"] = n_req / dt

    # per-request latency percentiles from the obs registry -------------
    hist = get_registry().histogram("service_request_total_seconds")
    pcts = {"p50": hist.percentile(0.50), "p95": hist.percentile(0.95),
            "p99": hist.percentile(0.99)}
    for label, v in pcts.items():
        emit(f"service/latency_{label}", v * 1e6, f"n={hist.count}")
        out[f"latency_{label}_ms"] = v * 1e3
    out["latency_ms"] = {label: v * 1e3 for label, v in pcts.items()}
    out["requests_mixed"] = n_req
    out["service_stats"] = warm_svc.stats()

    st = warm_svc.stats()
    print(f"# warm service: {st['engine_cache']['builds']} builds / "
          f"{st['requests']} requests, "
          f"{st['unique_iterations']} device iterations", flush=True)


# ----------------------------------------------------------- load harness
def _make_workload(seed: int, n: int) -> list[tuple]:
    """Deterministic request stream: ``(class, CountRequest, QoS, gap_s)``
    per entry; the gap is the open-loop inter-arrival sleep."""
    rng = random.Random(seed)
    classes = list(WORKLOAD)
    weights = [WORKLOAD[c]["weight"] for c in classes]
    out = []
    for _ in range(n):
        cls = rng.choices(classes, weights)[0]
        w = WORKLOAD[cls]
        req = CountRequest("g", rng.choice(w["templates"]),
                           rel_stderr=w["rel_stderr"],
                           max_iters=w["max_iters"],
                           seed=rng.choice(w["seeds"]))
        qos = QoS(klass=cls, tenant=rng.choice(w["tenants"]),
                  deadline_s=w["deadline_s"])
        out.append((cls, req, qos, rng.expovariate(2000.0)))
    return out


def _pcts(xs: list[float]) -> dict:
    if not xs:
        return {"p50": 0.0, "p95": 0.0, "p99": 0.0}
    s = sorted(xs)
    return {p: s[min(len(s) - 1, int(q * len(s)))]
            for p, q in (("p50", 0.50), ("p95", 0.95), ("p99", 0.99))}


def _latency_s(res) -> float:
    # estimate-cache hits resolve inside submit(): effectively zero latency
    return 0.0 if res.from_cache else res.breakdown["total_s"]


def _per_class(work, svc, rids) -> tuple[dict, dict, int]:
    """(per-class percentile dict, per-rid results, dropped count)."""
    by_cls: dict[str, list[float]] = {c: [] for c in WORKLOAD}
    results, dropped = {}, 0
    for (cls, _req, _qos, _gap), rid in zip(work, rids):
        st = svc.status(rid)
        if st.value != "done":
            dropped += 1
            continue
        res = svc.result(rid)
        results[rid] = res
        by_cls[cls].append(_latency_s(res))
    pc = {c: dict(_pcts(xs), n=len(xs)) for c, xs in by_cls.items()}
    return pc, results, dropped


def _prewarm(g, engine_cache) -> None:
    """Absorb engine builds + jit compiles once, outside both timed runs
    (same ROUND_SIZE => same dispatch shapes as the measured workload)."""
    svc = CountingService(round_size=ROUND_SIZE, engine_cache=engine_cache)
    svc.add_graph("g", g)
    for w in WORKLOAD.values():
        for t in w["templates"]:
            svc.submit(CountRequest("g", t, max_iters=ROUND_SIZE,
                                    seed=w["seeds"][0]))
    svc.run()


def _load_harness(out: dict, seed: int, n_requests: int) -> None:
    g = rmat(LOAD_GRAPH_SCALE, EDGE_FACTOR, seed=0)
    work = _make_workload(seed, n_requests)
    cache = EngineCache()
    _prewarm(g, cache)

    # sync baseline: round barrier over the full backlog ----------------
    ssvc = CountingService(round_size=ROUND_SIZE, engine_cache=cache)
    ssvc.add_graph("g", g)
    t0 = time.perf_counter()
    srids = [ssvc.submit(req) for _cls, req, _qos, _gap in work]
    ssvc.run()
    swall = time.perf_counter() - t0
    spc, sres, sdrop = _per_class(work, ssvc, srids)

    # async: open-loop arrivals into the QoS dispatcher -----------------
    asvc = AsyncCountingService(
        round_size=ROUND_SIZE, engine_cache=cache,
        max_queue_depth=2 * n_requests + 16, idle_wait_s=0.005)
    asvc.add_graph("g", g)
    arids = []
    t0 = time.perf_counter()
    with asvc:
        for _cls, req, qos, gap in work:
            if gap > 0:
                time.sleep(gap)
            arids.append(asvc.submit(req, qos=qos))
        asvc.drain(timeout=900.0)
    awall = time.perf_counter() - t0
    apc, ares, adrop = _per_class(work, asvc, arids)
    shed = asvc.stats()["shed"]

    # acceptance: bitwise-equal estimates, no drops, interactive p99 win
    bitwise = len(sres) == len(ares) == n_requests and all(
        sres[sr].estimate == ares[ar].estimate
        and sres[sr].stderr == ares[ar].stderr
        and sres[sr].iterations == ares[ar].iterations
        for sr, ar in zip(srids, arids))
    accept = {
        "interactive_p99_async_lt_sync":
            apc["interactive"]["p99"] < spc["interactive"]["p99"],
        "zero_dropped": sdrop == 0 and adrop == 0 and shed == 0,
        "bitwise_equal_estimates": bitwise,
    }

    emit("service/load_sync_wall", swall * 1e6,
         f"req_per_s={n_requests / swall:.1f}")
    emit("service/load_async_wall", awall * 1e6,
         f"req_per_s={n_requests / awall:.1f}")
    for cls in WORKLOAD:
        emit(f"service/load_sync_{cls}_p99", spc[cls]["p99"] * 1e6,
             f"n={spc[cls]['n']}")
        emit(f"service/load_async_{cls}_p99", apc[cls]["p99"] * 1e6,
             f"n={apc[cls]['n']}")
    speedup = spc["interactive"]["p99"] / max(apc["interactive"]["p99"],
                                              1e-9)
    emit("service/load_interactive_p99_speedup", speedup, "sync/async")
    for k, ok in accept.items():
        print(f"# acceptance {k}: {'PASS' if ok else 'FAIL'}", flush=True)

    out["load"] = {
        "seed": seed,
        "graph": f"rmat:{LOAD_GRAPH_SCALE} x{EDGE_FACTOR}",
        "requests": n_requests,
        "class_mix": {c: sum(1 for cls, *_ in work if cls == c)
                      for c in WORKLOAD},
        "cached_async": sum(1 for r in ares.values() if r.from_cache),
        "sync": {"wall_s": swall, "req_per_s": n_requests / swall,
                 "per_class_latency_s": spc, "dropped": sdrop},
        "async": {"wall_s": awall, "req_per_s": n_requests / awall,
                  "per_class_latency_s": apc, "dropped": adrop,
                  "shed": shed},
        "interactive_p99_speedup": speedup,
        "acceptance": accept,
    }


def run(seed: int = 0, n_requests: int = LOAD_REQUESTS,
        skip_micro: bool = False) -> dict:
    # fresh registry: this benchmark owns its counters/histograms
    set_registry(MetricsRegistry())
    out: dict = {"bench": "service",
                 "graph": f"rmat:{GRAPH_SCALE} x{EDGE_FACTOR}",
                 "templates": list(TEMPLATES)}
    if not skip_micro:
        _micro(out)
    _load_harness(out, seed, n_requests)
    out["metrics_snapshot"] = snapshot()

    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_service.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    print(f"# wrote {path}", flush=True)
    return out


# ------------------------------------------------------- HTTP (CI) driver
def _http_body(rng: random.Random, i: int) -> dict:
    classes = list(WORKLOAD)
    cls = rng.choices(classes, [WORKLOAD[c]["weight"] for c in classes])[0]
    w = WORKLOAD[cls]
    qos = {"class": cls, "tenant": rng.choice(w["tenants"])}
    if w["deadline_s"] is not None:
        qos["deadline_s"] = w["deadline_s"]
    return {"graph": "g", "templates": [rng.choice(w["templates"])],
            "max_iters": 8, "seed": rng.choice(w["seeds"]), "qos": qos,
            # every 5th request is fire-and-forget: exercises 202 +
            # /result polling while keeping most latencies measurable
            "wait": (i % 5 != 0), "timeout_s": 120}


def _http_drive(url: str, n: int, seed: int, workers: int,
                metrics_out: str | None,
                min_success: float | None = None) -> int:
    import urllib.error
    import urllib.request

    url = url.rstrip("/")
    rng = random.Random(seed)
    bodies = [_http_body(rng, i) for i in range(n)]
    tally = {"done": 0, "shed": 0, "accepted": 0, "failed": 0,
             "cancelled": 0, "error": 0}
    poll_rids: list[str] = []
    lock = threading.Lock()
    cursor = [0]

    def post(body: dict) -> None:
        req = urllib.request.Request(
            url + "/count", data=json.dumps(body).encode(),
            headers={"Content-Type": "application/json"})
        try:
            with urllib.request.urlopen(req, timeout=180) as resp:
                payload = json.load(resp)
        except urllib.error.HTTPError as e:     # 429 all-shed is expected;
            payload = json.load(e)              # 500 carries error_class
        except Exception as exc:
            with lock:
                tally["error"] += 1
            print(f"# http error: {exc}", flush=True)
            return
        with lock:
            if "requests" not in payload:       # structured handler error
                tally["failed"] += len(body["templates"])
                return
            for ent in payload["requests"]:
                st = ent.get("status")
                if st in ("done", "shed", "failed", "cancelled"):
                    tally[st] += 1
                else:
                    tally["accepted"] += 1
                    poll_rids.append(ent["id"])

    def worker() -> None:              # closed loop: next request on finish
        while True:
            with lock:
                if cursor[0] >= len(bodies):
                    return
                body = bodies[cursor[0]]
                cursor[0] += 1
            post(body)

    threads = [threading.Thread(target=worker, daemon=True)
               for _ in range(workers)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0

    # fire-and-forget followups: poll every accepted request to a terminal
    # status — the containment contract says none may stay in limbo
    deadline = time.monotonic() + 120.0
    for rid in poll_rids:
        status = "accepted"
        while time.monotonic() < deadline:
            try:
                with urllib.request.urlopen(f"{url}/result/{rid}",
                                            timeout=30) as resp:
                    status = json.load(resp).get("status", status)
            except urllib.error.HTTPError as e:   # 429 shed / 500 failed
                try:
                    status = json.load(e).get("status", status)
                except Exception:
                    pass
            except Exception:
                break
            if status in ("done", "shed", "failed", "cancelled"):
                break
            time.sleep(0.2)
        with lock:
            tally["accepted"] -= 1
            tally[status if status in tally else "error"] += 1

    snap = None
    try:
        with urllib.request.urlopen(url + "/metrics.json",
                                    timeout=30) as resp:
            snap = json.load(resp)
    except Exception as exc:
        print(f"# metrics.json fetch failed: {exc}", flush=True)
        tally["error"] += 1
    if metrics_out and snap is not None:
        with open(metrics_out, "w") as f:
            json.dump(snap, f, indent=1, sort_keys=True)
        print(f"# wrote {metrics_out}", flush=True)

    success = tally["done"] / max(n, 1)
    print(f"# http drive: {n} requests in {wall:.2f}s "
          f"({n / wall:.1f} req/s) -> {tally} "
          f"(success rate {success:.1%})", flush=True)
    if tally["error"]:
        return 1
    if min_success is not None and success < min_success:
        print(f"# FAIL: success rate {success:.1%} < "
              f"required {min_success:.1%}", flush=True)
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="counting-service benchmark / serving load generator")
    ap.add_argument("--seed", type=int, default=0,
                    help="class mix + arrival times are deterministic in "
                         "this seed")
    ap.add_argument("--requests", type=int, default=None,
                    help=f"load-harness request count (default "
                         f"{LOAD_REQUESTS}; 50 in --http mode)")
    ap.add_argument("--http", metavar="URL",
                    help="drive a live serve --http server instead of the "
                         "in-process harness")
    ap.add_argument("--workers", type=int, default=8,
                    help="closed-loop worker threads in --http mode")
    ap.add_argument("--metrics-out", metavar="PATH",
                    help="--http mode: write the server's /metrics.json "
                         "snapshot here")
    ap.add_argument("--min-success", type=float, default=None,
                    metavar="FRAC",
                    help="--http mode: exit nonzero unless at least this "
                         "fraction of requests terminates 'done' (the CI "
                         "chaos smoke's containment bar)")
    ap.add_argument("--skip-micro", action="store_true",
                    help="skip the micro rows; run only the load harness")
    args = ap.parse_args(argv)
    if args.http:
        return _http_drive(args.http, args.requests or 50, args.seed,
                           args.workers, args.metrics_out,
                           min_success=args.min_success)
    header()
    run(seed=args.seed, n_requests=args.requests or LOAD_REQUESTS,
        skip_micro=args.skip_micro)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
