"""Beyond-paper plan optimizations, measured in real wall time on CPU:
FASCIA partitioning (plain) vs canonical-form dedup vs work-optimal
partitioning — the §Perf P1/P2 iterations validated on actual hardware,
not just the dry-run cost model."""

from __future__ import annotations

from benchmarks.common import emit, timeit
from repro.core import build_engine, get_template
from repro.graph import rmat
from repro.graph.coloring import coloring_numpy


def run() -> dict:
    g = rmat(11, 16, seed=0)
    out = {}
    for tname in ("u10", "u12"):
        t = get_template(tname)
        colors = coloring_numpy(0, 0, g.n, t.k)
        times = {}
        vals = {}
        for plan in ("plain", "dedup", "optimized"):
            e = build_engine(g, t, "pgbsc", plan=plan)
            times[plan] = timeit(lambda: e.count_colorful(colors)[0])
            vals[plan] = float(e.count_colorful(colors)[0])
            emit(f"plans/{tname}/{plan}", times[plan] * 1e6,
                 f"nodes={e.plan.n_nodes}")
        # identical results across plans up to f32 reassociation (counts
        # here exceed 2^24 — the paper's §7.4 rounding phenomenon)
        ref = vals["plain"]
        for v in vals.values():
            assert abs(v - ref) / abs(ref) < 1e-5, vals
        emit(f"plans/{tname}/speedup_optimized_vs_plain",
             0.0, f"x{times['plain'] / times['optimized']:.2f}")
        out[tname] = times
    return out
