"""Paper Fig. 11 + kernel-level roofline closure (BENCH_roofline.json).

Two sections:

1. Engine placement (paper Fig. 11): achieved GFLOP/s and operational
   intensity per engine; the paper's claim is that PGBSC moves from the
   latency region toward the bandwidth roof.
2. Kernel closure: for every fused-eligible plan-node shape of a template,
   time the unfused Pallas pair (BSR SpMM kernel, then eMA kernel through a
   materialized neighbor-sum table) against the fused SpMM->eMA kernel, and
   place both on the host roofline via the ``analysis.roofline`` traffic
   models. The fused kernel moves strictly fewer modeled HBM bytes (the
   ``(B, C(k,t_p), N)`` y table never leaves VMEM), so achieved bandwidth —
   modeled bytes / measured seconds — rises iff the saved traffic shows up
   as saved wall time. The same budget/batch admission win is recorded from
   the executor's memory model.

Host peaks are measured crudely with a matmul (compute) and a triad
(bandwidth) microbenchmark; kernel wall times on CPU run the kernels in
interpret mode, so absolute numbers are emulation-scale — the fused-vs-
unfused *ratios* are the portable signal.

    PYTHONPATH=src python -m benchmarks.bench_roofline [--smoke] [--out F]

writes BENCH_roofline.json (repo root by default).
"""

from __future__ import annotations

import argparse
import json
import pathlib
from math import comb

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.analysis.roofline import (KernelRoofline, spmm_ema_flops,
                                     spmm_ema_hbm_bytes)
from repro.core import build_engine, colorsets as cs, get_template
from repro.graph import rmat
from repro.graph.coloring import coloring_numpy
from repro.kernels.ema import ops as ema_ops
from repro.kernels.fused import ops as fused_ops
from repro.kernels.fused.pallas_fused import pick_batch_block
from repro.kernels.spmm import ops as spmm_ops

DEFAULT_OUT = pathlib.Path(__file__).resolve().parents[1] / \
    "BENCH_roofline.json"


def _host_peaks() -> tuple[float, float]:
    a = jnp.asarray(np.random.default_rng(0).random((1024, 1024), np.float32))
    mm = jax.jit(lambda x: x @ x)
    sec = timeit(lambda: mm(a))
    flops = 2 * 1024 ** 3 / sec
    v = jnp.asarray(np.random.default_rng(1).random(1 << 24, np.float32))
    triad = jax.jit(lambda x: x * 2.0 + 1.0)
    sec_b = timeit(lambda: triad(v))
    bw = 3 * v.nbytes / sec_b
    return flops, bw


def _engine_section(g, peaks) -> dict:
    peak_flops, peak_bw = peaks
    t = get_template("u7")
    colors = coloring_numpy(0, 0, g.n, t.k)
    out = {}
    for eng_name in ("fascia", "pfascia", "pgbsc"):
        e = build_engine(g, t, eng_name)
        sec = timeit(lambda: e.count_colorful(colors)[0])
        flops = e.work.total_flops
        bytes_req = e.work.table_bytes * 3  # read a+p, write out (approx)
        gflops = flops / sec / 1e9
        oi = flops / bytes_req
        frac_roof = min(gflops * 1e9 / min(peak_flops, oi * peak_bw), 9.99)
        emit(f"fig11/{eng_name}", sec * 1e6,
             f"{gflops:.2f}GFLOPs|OI={oi:.2f}|roof={frac_roof * 100:.0f}%")
        out[eng_name] = {"gflops": gflops, "oi": oi, "roof_frac": frac_roof}
    return out


def _node_shapes(engine) -> list[tuple[int, int]]:
    """Distinct (t, t_a) of the engine's fused-eligible plan nodes."""
    shapes = []
    for idx in engine.schedule.fused:
        node = engine.plan.nodes[idx]
        key = (node.size, engine.plan.nodes[node.active].size)
        if key not in shapes:
            shapes.append(key)
    return shapes


def _kernel_section(g, tmpl_name: str, peaks, *, batch: int,
                    reps: int) -> dict:
    """Fused vs unfused Pallas timing for every fused-eligible node shape."""
    peak_flops, peak_bw = peaks
    engine = build_engine(g, tmpl_name, "pgbsc", fuse_spmm_ema=True)
    k = engine.k
    fprep = fused_ops.prepare_fused(g, interpret=True)
    bsr_prep = spmm_ops.prepare(g, "pallas_bsr", interpret=True)
    adj_bytes = int(np.asarray(fprep.arrays["blocks"]).nbytes)
    rng = np.random.default_rng(0)
    itemsize = jnp.dtype(jnp.float32).itemsize
    kernels = []
    for t, t_a in _node_shapes(engine):
        c_a, c_p, s = comb(k, t_a), comb(k, t - t_a), comb(k, t)
        ia, ip = cs.split_tables(k, t, t_a)
        ia, ip = jnp.asarray(ia), jnp.asarray(ip)
        length = ia.shape[1]
        m_a = jnp.asarray(rng.random((batch, c_a, g.n), np.float32))
        m_p = jnp.asarray(rng.random((batch, c_p, g.n), np.float32))

        fused = jax.jit(
            lambda a, p: fused_ops.fused_spmm_ema(a, p, ia, ip, fprep))
        unfused = jax.jit(lambda a, p: ema_ops.ema(
            a, spmm_ops.spmm(p, bsr_prep), ia, ip,
            use_pallas=True, interpret=True))

        sec_f = timeit(fused, m_a, m_p, iters=reps)
        sec_u = timeit(unfused, m_a, m_p, iters=reps)
        flops = spmm_ema_flops(batch, g.m, g.n, c_p, s, length)
        s_pad = -(-s // 8) * 8
        bb = pick_batch_block(batch, c_a, c_p, s_pad, length, 128, itemsize)
        pair = {}
        for variant, sec in (("fused", sec_f), ("unfused", sec_u)):
            hbm = spmm_ema_hbm_bytes(
                batch, g.n, c_a, c_p, s, adj_bytes, itemsize,
                fused=(variant == "fused"),
                adj_passes=(-(-batch // bb) if variant == "fused" else 1))
            r = KernelRoofline(
                name=f"{tmpl_name}/t{t}a{t_a}/{variant}", flops=flops,
                hbm_bytes=hbm, seconds=sec,
                peak_flops=peak_flops, peak_bw=peak_bw)
            pair[variant] = r.as_dict()
            emit(f"roofline/{r.name}", sec * 1e6,
                 f"{r.achieved_bw / 1e9:.2f}GB/s|OI={r.oi:.2f}"
                 f"|{r.bound}")
        pair["node"] = {"t": t, "t_a": t_a, "c_a": c_a, "c_p": c_p,
                        "s": s, "l": length, "batch": batch}
        pair["speedup"] = pair["unfused"]["seconds"] / \
            pair["fused"]["seconds"]
        pair["bw_gain"] = pair["fused"]["achieved_gbps"] / \
            pair["unfused"]["achieved_gbps"]
        kernels.append(pair)
    return {"kernels": kernels,
            "fused_nodes": list(engine.schedule.fused)}


def _admission_section(g, tmpl_name: str,
                       budget: int | None = None) -> dict:
    """Same memory budget, unfused vs fused: batch the model admits.

    The budget defaults to 32x the unfused per-coloring peak, which keeps
    the comparison below the batch-size cap where admission is actually
    budget-limited.
    """
    if budget is None:
        probe = build_engine(g, tmpl_name, "pgbsc")
        budget = 32 * probe.exec_choice.peak_bytes_per_coloring
    e0 = build_engine(g, tmpl_name, "pgbsc", memory_budget_bytes=budget)
    e1 = build_engine(g, tmpl_name, "pgbsc", memory_budget_bytes=budget,
                      fuse_spmm_ema=True)
    emit(f"roofline/{tmpl_name}/admitted_batch", 0.0,
         f"unfused={e0.batch_size}|fused={e1.batch_size}")
    return {"budget_bytes": budget,
            "unfused_batch": e0.batch_size, "fused_batch": e1.batch_size,
            "unfused_peak_per_coloring": e0.exec_choice.
            peak_bytes_per_coloring,
            "fused_peak_per_coloring": e1.exec_choice.
            peak_bytes_per_coloring}


def run(smoke: bool = False, out_path: pathlib.Path | None = None) -> dict:
    peak_flops, peak_bw = peaks = _host_peaks()
    emit("fig11/host_peak", 0.0,
         f"{peak_flops / 1e9:.1f}GFLOPs|{peak_bw / 1e9:.1f}GB/s")
    if smoke:
        g = rmat(9, 8, seed=0)
        templates, batch, reps = ("u5",), 4, 2
    else:
        g = rmat(11, 16, seed=0)
        templates, batch, reps = ("u5", "u7"), 8, 3
    result = {
        "smoke": smoke,
        "host": {"peak_gflops": peak_flops / 1e9,
                 "peak_gbps": peak_bw / 1e9,
                 "note": "kernels run in Pallas interpret mode on CPU; "
                         "ratios, not absolutes, are the portable signal"},
        "graph": {"n": g.n, "m": g.m},
        "engines": {} if smoke else _engine_section(g, peaks),
        "templates": {},
    }
    for name in templates:
        result["templates"][name] = _kernel_section(
            g, name, peaks, batch=batch, reps=reps)
        result["templates"][name]["admission"] = _admission_section(g, name)
    out_path = pathlib.Path(out_path) if out_path else DEFAULT_OUT
    out_path.write_text(json.dumps(result, indent=2) + "\n")
    emit("roofline/json", 0.0, str(out_path))
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small graph, one template, fewer reps (CI)")
    ap.add_argument("--out", default=None, help="JSON output path")
    args = ap.parse_args(argv)
    run(smoke=args.smoke, out_path=args.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
