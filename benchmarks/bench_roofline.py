"""Paper Fig. 11 + kernel-level roofline closure (BENCH_roofline.json).

Two sections:

1. Engine placement (paper Fig. 11): achieved GFLOP/s and operational
   intensity per engine; the paper's claim is that PGBSC moves from the
   latency region toward the bandwidth roof.
2. Kernel closure: for every fused-eligible plan-node shape of a template,
   time the unfused Pallas pair (BSR SpMM kernel, then eMA kernel through a
   materialized neighbor-sum table) against the fused SpMM->eMA kernel, and
   place both on the host roofline via the ``analysis.roofline`` traffic
   models. The fused kernel moves strictly fewer modeled HBM bytes (the
   ``(B, C(k,t_p), N)`` y table never leaves VMEM), so achieved bandwidth —
   modeled bytes / measured seconds — rises iff the saved traffic shows up
   as saved wall time. The same budget/batch admission win is recorded from
   the executor's memory model.

Host peaks are measured crudely with a matmul (compute) and a triad
(bandwidth) microbenchmark; kernel wall times on CPU run the kernels in
interpret mode, so absolute numbers are emulation-scale — the fused-vs-
unfused *ratios* are the portable signal.

Three locality/precision sections ride along (PR 8):

- ``reorder``: occupied BSR blocks, block density, and fused-kernel grid
  steps before vs after RCM / degree reordering of the bench graph.
- ``dtype``: the fused kernel timed with float32 vs bfloat16 storage
  (f32 accumulation), per-variant achieved bandwidth on each variant's
  own modeled bytes, and the workload-bandwidth gain — the same logical
  table traffic delivered per second — plus engine-level count error.
- ``shared_passive``: the shared-passive group launch (one SpMM leg for
  N consumers) against per-consumer fused launches on a two-template
  bundle whose plan shares a path2 passive, with the SpMM column-op
  model for both.

    PYTHONPATH=src python -m benchmarks.bench_roofline [--smoke] [--out F]

writes BENCH_roofline.json (repo root by default).
"""

from __future__ import annotations

import argparse
import json
import pathlib
from math import comb

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.analysis.roofline import (KernelRoofline, spmm_ema_flops,
                                     spmm_ema_hbm_bytes)
from repro.core import build_engine, colorsets as cs, get_template
from repro.core.templates import TreeTemplate
from repro.graph import rmat
from repro.graph.coloring import coloring_numpy
from repro.graph.reorder import ORDERINGS, apply_order
from repro.kernels.ema import ops as ema_ops
from repro.kernels.fused import ops as fused_ops
from repro.kernels.fused.pallas_fused import pick_batch_block
from repro.kernels.spmm import ops as spmm_ops

DEFAULT_OUT = pathlib.Path(__file__).resolve().parents[1] / \
    "BENCH_roofline.json"


def _host_peaks() -> tuple[float, float]:
    a = jnp.asarray(np.random.default_rng(0).random((1024, 1024), np.float32))
    mm = jax.jit(lambda x: x @ x)
    sec = timeit(lambda: mm(a))
    flops = 2 * 1024 ** 3 / sec
    v = jnp.asarray(np.random.default_rng(1).random(1 << 24, np.float32))
    triad = jax.jit(lambda x: x * 2.0 + 1.0)
    sec_b = timeit(lambda: triad(v))
    bw = 3 * v.nbytes / sec_b
    return flops, bw


def _engine_section(g, peaks) -> dict:
    peak_flops, peak_bw = peaks
    t = get_template("u7")
    colors = coloring_numpy(0, 0, g.n, t.k)
    out = {}
    for eng_name in ("fascia", "pfascia", "pgbsc"):
        e = build_engine(g, t, eng_name)
        sec = timeit(lambda: e.count_colorful(colors)[0])
        flops = e.work.total_flops
        bytes_req = e.work.table_bytes * 3  # read a+p, write out (approx)
        gflops = flops / sec / 1e9
        oi = flops / bytes_req
        frac_roof = min(gflops * 1e9 / min(peak_flops, oi * peak_bw), 9.99)
        emit(f"fig11/{eng_name}", sec * 1e6,
             f"{gflops:.2f}GFLOPs|OI={oi:.2f}|roof={frac_roof * 100:.0f}%")
        out[eng_name] = {"gflops": gflops, "oi": oi, "roof_frac": frac_roof}
    return out


def _node_shapes(engine) -> list[tuple[int, int]]:
    """Distinct (t, t_a) of the engine's fused-eligible plan nodes."""
    shapes = []
    for idx in engine.schedule.fused:
        node = engine.plan.nodes[idx]
        key = (node.size, engine.plan.nodes[node.active].size)
        if key not in shapes:
            shapes.append(key)
    return shapes


def _kernel_section(g, tmpl_name: str, peaks, *, batch: int,
                    reps: int) -> dict:
    """Fused vs unfused Pallas timing for every fused-eligible node shape."""
    peak_flops, peak_bw = peaks
    engine = build_engine(g, tmpl_name, "pgbsc", fuse_spmm_ema=True)
    k = engine.k
    fprep = fused_ops.prepare_fused(g, interpret=True)
    bsr_prep = spmm_ops.prepare(g, "pallas_bsr", interpret=True)
    adj_bytes = int(np.asarray(fprep.arrays["blocks"]).nbytes)
    rng = np.random.default_rng(0)
    itemsize = jnp.dtype(jnp.float32).itemsize
    kernels = []
    for t, t_a in _node_shapes(engine):
        c_a, c_p, s = comb(k, t_a), comb(k, t - t_a), comb(k, t)
        ia, ip = cs.split_tables(k, t, t_a)
        ia, ip = jnp.asarray(ia), jnp.asarray(ip)
        length = ia.shape[1]
        m_a = jnp.asarray(rng.random((batch, c_a, g.n), np.float32))
        m_p = jnp.asarray(rng.random((batch, c_p, g.n), np.float32))

        fused = jax.jit(
            lambda a, p: fused_ops.fused_spmm_ema(a, p, ia, ip, fprep))
        unfused = jax.jit(lambda a, p: ema_ops.ema(
            a, spmm_ops.spmm(p, bsr_prep), ia, ip,
            use_pallas=True, interpret=True))

        sec_f = timeit(fused, m_a, m_p, iters=reps)
        sec_u = timeit(unfused, m_a, m_p, iters=reps)
        flops = spmm_ema_flops(batch, g.m, g.n, c_p, s, length)
        s_pad = -(-s // 8) * 8
        bb = pick_batch_block(batch, c_a, c_p, s_pad, length, 128, itemsize)
        pair = {}
        for variant, sec in (("fused", sec_f), ("unfused", sec_u)):
            hbm = spmm_ema_hbm_bytes(
                batch, g.n, c_a, c_p, s, adj_bytes, itemsize,
                fused=(variant == "fused"),
                adj_passes=(-(-batch // bb) if variant == "fused" else 1))
            r = KernelRoofline(
                name=f"{tmpl_name}/t{t}a{t_a}/{variant}", flops=flops,
                hbm_bytes=hbm, seconds=sec,
                peak_flops=peak_flops, peak_bw=peak_bw)
            pair[variant] = r.as_dict()
            emit(f"roofline/{r.name}", sec * 1e6,
                 f"{r.achieved_bw / 1e9:.2f}GB/s|OI={r.oi:.2f}"
                 f"|{r.bound}")
        pair["node"] = {"t": t, "t_a": t_a, "c_a": c_a, "c_p": c_p,
                        "s": s, "l": length, "batch": batch}
        pair["speedup"] = pair["unfused"]["seconds"] / \
            pair["fused"]["seconds"]
        pair["bw_gain"] = pair["fused"]["achieved_gbps"] / \
            pair["unfused"]["achieved_gbps"]
        kernels.append(pair)
    return {"kernels": kernels,
            "fused_nodes": list(engine.schedule.fused)}


def _admission_section(g, tmpl_name: str,
                       budget: int | None = None) -> dict:
    """Same memory budget, unfused vs fused: batch the model admits.

    The budget defaults to 32x the unfused per-coloring peak, which keeps
    the comparison below the batch-size cap where admission is actually
    budget-limited.
    """
    if budget is None:
        probe = build_engine(g, tmpl_name, "pgbsc")
        budget = 32 * probe.exec_choice.peak_bytes_per_coloring
    e0 = build_engine(g, tmpl_name, "pgbsc", memory_budget_bytes=budget)
    e1 = build_engine(g, tmpl_name, "pgbsc", memory_budget_bytes=budget,
                      fuse_spmm_ema=True)
    emit(f"roofline/{tmpl_name}/admitted_batch", 0.0,
         f"unfused={e0.batch_size}|fused={e1.batch_size}")
    return {"budget_bytes": budget,
            "unfused_batch": e0.batch_size, "fused_batch": e1.batch_size,
            "unfused_peak_per_coloring": e0.exec_choice.
            peak_bytes_per_coloring,
            "fused_peak_per_coloring": e1.exec_choice.
            peak_bytes_per_coloring}


def _reorder_section(g) -> dict:
    """Occupied BSR blocks / density / fused grid steps, before vs after."""
    before = g.bsr_block_stats()
    grid_before = int(np.asarray(
        fused_ops.prepare_fused(g, interpret=True).arrays["src_tile"]).size)
    out = {"before": {**before, "fused_grid_steps": grid_before}}
    for name, fn in sorted(ORDERINGS.items()):
        gp = apply_order(g, fn(g))
        after = gp.bsr_block_stats()
        grid = int(np.asarray(
            fused_ops.prepare_fused(gp, interpret=True)
            .arrays["src_tile"]).size)
        out[name] = {**after, "fused_grid_steps": grid}
        emit(f"reorder/{name}/occupied_blocks", 0.0,
             f"{before['occupied_blocks']}->{after['occupied_blocks']}"
             f"|grid={grid_before}->{grid}")
    return out


def _dtype_section(g, peaks, *, batch: int, reps: int) -> dict:
    """Fused kernel, float32 vs bfloat16 storage (f32 accumulation).

    Per-variant achieved bandwidth divides each variant's OWN modeled
    bytes (bf16 streams half the physical table/adjacency bytes) by its
    measured seconds. ``workload_bw_gain`` is the portable headline: both
    variants deliver the identical logical table traffic, so the gain in
    logical bytes per second equals the measured speedup.
    """
    peak_flops, peak_bw = peaks
    k, t, t_a = 5, 3, 1
    c_a, c_p, s = comb(k, t_a), comb(k, t - t_a), comb(k, t)
    ia, ip = cs.split_tables(k, t, t_a)
    ia, ip = jnp.asarray(ia), jnp.asarray(ip)
    length = ia.shape[1]
    rng = np.random.default_rng(2)
    m_a32 = jnp.asarray(rng.random((batch, c_a, g.n), np.float32))
    m_p32 = jnp.asarray(rng.random((batch, c_p, g.n), np.float32))
    flops = spmm_ema_flops(batch, g.m, g.n, c_p, s, length)
    s_pad = -(-s // 8) * 8
    out = {}
    for dt in (jnp.float32, jnp.bfloat16):
        dname = np.dtype(dt).name
        prep = fused_ops.prepare_fused(g, interpret=True, dtype=dt)
        m_a, m_p = m_a32.astype(dt), m_p32.astype(dt)
        fn = jax.jit(
            lambda a, p, prep=prep: fused_ops.fused_spmm_ema(
                a, p, ia, ip, prep))
        sec = timeit(fn, m_a, m_p, iters=reps)
        item = jnp.dtype(dt).itemsize
        acc_item = jnp.dtype(ema_ops.accum_dtype(dt)).itemsize
        adj_bytes = int(np.asarray(prep.arrays["blocks"]).nbytes)
        bb = pick_batch_block(batch, c_a, c_p, s_pad, length, 128, acc_item)
        hbm = spmm_ema_hbm_bytes(batch, g.n, c_a, c_p, s, adj_bytes, item,
                                 fused=True, adj_passes=-(-batch // bb))
        r = KernelRoofline(name=f"fused/{dname}", flops=flops,
                           hbm_bytes=hbm, seconds=sec,
                           peak_flops=peak_flops, peak_bw=peak_bw)
        out[dname] = r.as_dict()
        emit(f"roofline/{r.name}", sec * 1e6,
             f"{r.achieved_bw / 1e9:.2f}GB/s|OI={r.oi:.2f}|{r.bound}")
    out["speedup"] = out["float32"]["seconds"] / out["bfloat16"]["seconds"]
    out["workload_bw_gain"] = out["speedup"]
    # engine-level count accuracy: bf16 storage vs the f32 reference
    e32 = build_engine(g, "u5", "pgbsc")
    e16 = build_engine(g, "u5", "pgbsc", dtype=jnp.bfloat16,
                       fuse_spmm_ema=True)
    colors = coloring_numpy(0, 0, g.n, 5)
    want = float(e32.count_colorful(colors)[0])
    got = float(e16.count_colorful(colors)[0])
    out["count_rel_err"] = abs(got - want) / max(abs(want), 1.0)
    emit("roofline/fused/bf16_gain", 0.0,
         f"x{out['workload_bw_gain']:.2f}|relerr="
         f"{out['count_rel_err']:.1e}")
    return out


def _shared_bundle() -> tuple:
    """Two k=5 trees whose dedup plan shares a path2 passive between T1's
    root and an interior node of T2 (see tests/test_kernels_fused.py)."""
    return (TreeTemplate([(0, 1), (1, 2), (0, 3), (0, 4)], root=0,
                         name="sharedp_a"),
            TreeTemplate([(0, 1), (1, 2), (2, 3), (1, 4)], root=0,
                         name="sharedp_b"))


def _shared_section(g, *, batch: int, reps: int) -> dict:
    """Shared-passive group launch vs per-consumer fused launches."""
    shared = build_engine(g, _shared_bundle(), "pgbsc", plan="dedup",
                          fuse_spmm_ema=True)
    assert shared.schedule.fused_groups, "bundle must form a group"
    grp = shared.schedule.fused_groups[0]
    k = shared.k
    c_p = comb(k, shared.plan.nodes[shared.plan.nodes[grp[0]].passive].size)
    cols_grouped = shared.spmm_cols_per_coloring
    cols_per_consumer = cols_grouped + (len(grp) - 1) * c_p
    rng = np.random.default_rng(3)
    fprep = fused_ops.prepare_fused(g, interpret=True)
    m_p = jnp.asarray(rng.random((batch, c_p, g.n), np.float32))
    m_as, ias, ips = [], [], []
    for m in grp:
        node = shared.plan.nodes[m]
        t, t_a = node.size, shared.plan.nodes[node.active].size
        ia, ip = cs.split_tables(k, t, t_a)
        ias.append(jnp.asarray(ia))
        ips.append(jnp.asarray(ip))
        m_as.append(jnp.asarray(
            rng.random((batch, comb(k, t_a), g.n), np.float32)))

    def one_launch(mas, mp):
        return fused_ops.fused_spmm_ema_shared(mas, mp, ias, ips, fprep)

    def per_consumer(mas, mp):
        return tuple(fused_ops.fused_spmm_ema(ma, mp, ia, ip, fprep)
                     for ma, ia, ip in zip(mas, ias, ips))

    sec_s = timeit(jax.jit(one_launch), m_as, m_p, iters=reps)
    sec_p = timeit(jax.jit(per_consumer), m_as, m_p, iters=reps)
    emit("roofline/shared_passive", sec_s * 1e6,
         f"cols={cols_grouped}(vs {cols_per_consumer})"
         f"|x{sec_p / sec_s:.2f}")
    return {"group": list(grp), "consumers": len(grp),
            "spmm_cols_grouped": cols_grouped,
            "spmm_cols_per_consumer_fusion": cols_per_consumer,
            "shared_seconds": sec_s, "per_consumer_seconds": sec_p,
            "speedup": sec_p / sec_s}


def run(smoke: bool = False, out_path: pathlib.Path | None = None) -> dict:
    peak_flops, peak_bw = peaks = _host_peaks()
    emit("fig11/host_peak", 0.0,
         f"{peak_flops / 1e9:.1f}GFLOPs|{peak_bw / 1e9:.1f}GB/s")
    if smoke:
        g = rmat(9, 8, seed=0)
        templates, batch, reps = ("u5",), 4, 2
    else:
        g = rmat(11, 16, seed=0)
        templates, batch, reps = ("u5", "u7"), 8, 3
    result = {
        "smoke": smoke,
        "host": {"peak_gflops": peak_flops / 1e9,
                 "peak_gbps": peak_bw / 1e9,
                 "note": "kernels run in Pallas interpret mode on CPU; "
                         "ratios, not absolutes, are the portable signal"},
        "graph": {"n": g.n, "m": g.m},
        "engines": {} if smoke else _engine_section(g, peaks),
        "templates": {},
    }
    for name in templates:
        result["templates"][name] = _kernel_section(
            g, name, peaks, batch=batch, reps=reps)
        result["templates"][name]["admission"] = _admission_section(g, name)
    result["reorder"] = _reorder_section(g)
    result["dtype"] = _dtype_section(g, peaks, batch=batch, reps=reps)
    result["shared_passive"] = _shared_section(g, batch=batch, reps=reps)
    out_path = pathlib.Path(out_path) if out_path else DEFAULT_OUT
    out_path.write_text(json.dumps(result, indent=2) + "\n")
    emit("roofline/json", 0.0, str(out_path))
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small graph, one template, fewer reps (CI)")
    ap.add_argument("--out", default=None, help="JSON output path")
    args = ap.parse_args(argv)
    run(smoke=args.smoke, out_path=args.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
