"""Paper Fig. 11: roofline placement of the three engines on this host.

Measures achieved GFLOP/s and operational intensity (useful flops / required
bytes) per engine; the paper's claim is that PGBSC moves from the latency
region to the bandwidth roof. Host peaks are measured crudely with a matmul
(compute) and a triad (bandwidth) microbenchmark.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core import build_engine, get_template
from repro.graph import rmat
from repro.graph.coloring import coloring_numpy


def _host_peaks() -> tuple[float, float]:
    a = jnp.asarray(np.random.default_rng(0).random((1024, 1024), np.float32))
    mm = jax.jit(lambda x: x @ x)
    sec = timeit(lambda: mm(a))
    flops = 2 * 1024 ** 3 / sec
    v = jnp.asarray(np.random.default_rng(1).random(1 << 24, np.float32))
    triad = jax.jit(lambda x: x * 2.0 + 1.0)
    sec_b = timeit(lambda: triad(v))
    bw = 3 * v.nbytes / sec_b
    return flops, bw


def run() -> dict:
    peak_flops, peak_bw = _host_peaks()
    emit("fig11/host_peak", 0.0,
         f"{peak_flops / 1e9:.1f}GFLOPs|{peak_bw / 1e9:.1f}GB/s")
    g = rmat(11, 16, seed=0)
    t = get_template("u7")
    colors = coloring_numpy(0, 0, g.n, t.k)
    out = {}
    for eng_name in ("fascia", "pfascia", "pgbsc"):
        e = build_engine(g, t, eng_name)
        sec = timeit(lambda: e.count_colorful(colors)[0])
        flops = e.work.total_flops
        bytes_req = e.work.table_bytes * 3  # read a+p, write out (approx)
        gflops = flops / sec / 1e9
        oi = flops / bytes_req
        frac_roof = min(gflops * 1e9 / min(peak_flops, oi * peak_bw), 9.99)
        emit(f"fig11/{eng_name}", sec * 1e6,
             f"{gflops:.2f}GFLOPs|OI={oi:.2f}|roof={frac_roof * 100:.0f}%")
        out[eng_name] = {"gflops": gflops, "oi": oi, "roof_frac": frac_roof}
    return out
