"""Fused SpMM->eMA kernel: oracle equivalence, fallbacks, memory model.

The fused kernel must be indistinguishable (to float reassociation) from the
unfused pair ``ema(m_a, spmm(m_p), ia, ip)`` at the kernel level, and a
``fuse_spmm_ema=True`` engine must reproduce the unfused engine's counts on
u5/u7/u10 for single and batched colorings. The executor's peak-memory model
must charge fused nodes no y-table, so the same budget admits at least as
large a coloring batch.
"""

from math import comb

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build_engine, executor as pexec, get_template
from repro.core.colorsets import split_tables
from repro.graph import Graph, erdos_renyi, grid_2d, rmat, star
from repro.graph.coloring import coloring_numpy
from repro.kernels import autotune
from repro.kernels.ema.ops import ema_xla
from repro.kernels.fused import (fused_fits_vmem, fused_spmm_ema,
                                 fused_spmm_ema_shared, prepare_fused)
from repro.kernels.fused.pallas_fused import pick_batch_block
from repro.kernels.spmm.ref import spmm_dense


def _rand_table(rng, shape, dtype=np.float32):
    return jnp.asarray(rng.integers(0, 4, size=shape).astype(dtype))


def _oracle(g, m_a, m_p, ia, ip):
    y = spmm_dense(m_p, jnp.asarray(g.to_dense()).astype(m_p.dtype))
    return ema_xla(m_a, y, ia, ip)


GRAPHS = {
    "er_uneven": lambda: erdos_renyi(130, 7.0, seed=1),   # n % 128 != 0
    "grid": lambda: grid_2d(12, 11),
    "star_skew": lambda: star(150),
    "rmat": lambda: rmat(8, 8, seed=2),
    "empty": lambda: Graph.from_edges(100, np.zeros((0, 2), np.int64)),
}


class TestFusedKernel:
    @pytest.mark.parametrize("gname", sorted(GRAPHS))
    @pytest.mark.parametrize("k,t,ta", [(5, 3, 1), (7, 4, 2)])
    def test_matches_oracle(self, gname, k, t, ta):
        g = GRAPHS[gname]()
        ia, ip = split_tables(k, t, ta)
        ia, ip = jnp.asarray(ia), jnp.asarray(ip)
        rng = np.random.default_rng(k * 10 + ta)
        m_a = _rand_table(rng, (comb(k, ta), g.n))
        m_p = _rand_table(rng, (comb(k, t - ta), g.n))
        prep = prepare_fused(g)
        got = fused_spmm_ema(m_a, m_p, ia, ip, prep)
        want = _oracle(g, m_a, m_p, ia, ip)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6)

    def test_empty_graph_is_zero(self):
        g = GRAPHS["empty"]()
        ia, ip = split_tables(5, 3, 1)
        rng = np.random.default_rng(0)
        m_a = _rand_table(rng, (5, g.n))
        m_p = _rand_table(rng, (10, g.n))
        got = fused_spmm_ema(m_a, m_p, jnp.asarray(ia), jnp.asarray(ip),
                             prepare_fused(g))
        assert not np.asarray(got).any()

    @pytest.mark.parametrize("b", [1, 3, 5])  # 5 exercises batch padding
    def test_batched(self, b):
        g = GRAPHS["er_uneven"]()
        ia, ip = split_tables(7, 4, 2)
        ia, ip = jnp.asarray(ia), jnp.asarray(ip)
        rng = np.random.default_rng(b)
        m_a = _rand_table(rng, (b, comb(7, 2), g.n))
        m_p = _rand_table(rng, (b, comb(7, 2), g.n))
        prep = prepare_fused(g)
        got = fused_spmm_ema(m_a, m_p, ia, ip, prep)
        assert got.shape == (b, comb(7, 4), g.n)
        for i in range(b):
            want = _oracle(g, m_a[i], m_p[i], ia, ip)
            np.testing.assert_allclose(np.asarray(got[i]),
                                       np.asarray(want), rtol=1e-6)

    def test_batch_blocking_smaller_than_batch(self, monkeypatch):
        # force bb < B so the grid walks multiple batch blocks
        from repro.kernels.fused import pallas_fused
        monkeypatch.setattr(pallas_fused, "_VMEM_BUDGET", 1 << 16)
        g = GRAPHS["rmat"]()
        ia, ip = split_tables(5, 3, 1)
        ia, ip = jnp.asarray(ia), jnp.asarray(ip)
        rng = np.random.default_rng(9)
        m_a = _rand_table(rng, (4, 5, g.n))
        m_p = _rand_table(rng, (4, 10, g.n))
        assert pick_batch_block(4, 5, 10, 16, ia.shape[1], 128, 4) < 4
        got = fused_spmm_ema(m_a, m_p, ia, ip, prepare_fused(g))
        for i in range(4):
            want = _oracle(g, m_a[i], m_p[i], ia, ip)
            np.testing.assert_allclose(np.asarray(got[i]),
                                       np.asarray(want), rtol=1e-6)

    def test_float64(self, x64):
        g = GRAPHS["grid"]()
        ia, ip = split_tables(5, 3, 2)
        ia, ip = jnp.asarray(ia), jnp.asarray(ip)
        rng = np.random.default_rng(5)
        m_a = _rand_table(rng, (comb(5, 2), g.n), np.float64)
        m_p = _rand_table(rng, (comb(5, 1), g.n), np.float64)
        got = fused_spmm_ema(m_a, m_p, ia, ip, prepare_fused(g))
        assert got.dtype == jnp.float64
        want = _oracle(g, m_a, m_p, ia, ip)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0)

    def test_unsupported_dtype_falls_back_exactly(self):
        # float16 is outside the kernel's dtype set -> explicit XLA fallback,
        # never a silent cast; small ints are exact in f16
        g = GRAPHS["rmat"]()
        ia, ip = split_tables(5, 2, 1)
        ia, ip = jnp.asarray(ia), jnp.asarray(ip)
        rng = np.random.default_rng(6)
        m_a = _rand_table(rng, (5, g.n), np.float16)
        m_p = _rand_table(rng, (5, g.n), np.float16)
        got = fused_spmm_ema(m_a, m_p, ia, ip, prepare_fused(g))
        assert got.dtype == jnp.float16
        want = _oracle(g, m_a.astype(jnp.float32),
                       m_p.astype(jnp.float32), ia, ip)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want), rtol=1e-3)

    def test_vmem_overflow_falls_back(self, monkeypatch):
        assert not fused_fits_vmem(4000, 4000, 8000, l=100)
        # shrink the budget so dispatch takes the XLA fallback, and verify
        # the kernel is really bypassed (it would raise if called)
        from repro.kernels.fused import ops as fops
        monkeypatch.setattr(fops, "_PALLAS_VMEM_BYTES", 1)
        monkeypatch.setattr(
            fops, "fused_spmm_ema_pallas",
            lambda *a, **k: (_ for _ in ()).throw(
                AssertionError("kernel path taken")))
        g = GRAPHS["rmat"]()
        ia, ip = split_tables(5, 3, 1)
        rng = np.random.default_rng(7)
        m_a = _rand_table(rng, (5, g.n))
        m_p = _rand_table(rng, (10, g.n))
        got = fused_spmm_ema(m_a, m_p, jnp.asarray(ia), jnp.asarray(ip),
                             prepare_fused(g))
        want = _oracle(g, m_a, m_p, jnp.asarray(ia), jnp.asarray(ip))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6)


class TestFusedEngine:
    @pytest.mark.parametrize("tname", ["u5", "u7", "u10"])
    def test_matches_unfused_single_and_batched(self, tname):
        g = erdos_renyi(60, 4.0, seed=3)
        t = get_template(tname)
        base = build_engine(g, t, "pgbsc")
        fused = build_engine(g, t, "pgbsc", fuse_spmm_ema=True)
        assert fused.schedule.fused, "expected fused-eligible nodes"
        colors = coloring_numpy(0, 0, g.n, t.k)
        want, _ = base.count_colorful(colors)
        got, _ = fused.count_colorful(colors)
        np.testing.assert_allclose(float(got), float(want), rtol=1e-6)
        batch = np.stack([coloring_numpy(0, i, g.n, t.k) for i in range(3)])
        want_b, _ = base.count_colorful_batch(jnp.asarray(batch))
        got_b, _ = fused.count_colorful_batch(jnp.asarray(batch))
        np.testing.assert_allclose(np.asarray(got_b), np.asarray(want_b),
                                   rtol=1e-6)

    def test_fusion_ignored_off_pgbsc(self):
        g = erdos_renyi(30, 3.0, seed=1)
        e = build_engine(g, "u5", "pfascia", fuse_spmm_ema=True)
        assert not e.fuse_spmm_ema and not e.schedule.fused

    def test_budget_admits_larger_batch(self):
        g = erdos_renyi(200, 5.0, seed=2)
        base = build_engine(g, "u10", "pgbsc")
        fused = build_engine(g, "u10", "pgbsc", fuse_spmm_ema=True)
        assert fused.exec_choice.peak_bytes_per_coloring < \
            base.exec_choice.peak_bytes_per_coloring
        budget = 8 * base.exec_choice.peak_bytes_per_coloring
        e0 = build_engine(g, "u10", "pgbsc", memory_budget_bytes=budget)
        e1 = build_engine(g, "u10", "pgbsc", memory_budget_bytes=budget,
                          fuse_spmm_ema=True)
        assert e1.batch_size > e0.batch_size

    def test_f64_counts_match_f32_engine(self, x64):
        # counts are integer-valued; f64 fused path must agree exactly
        g = erdos_renyi(40, 3.5, seed=8)
        colors = coloring_numpy(0, 0, g.n, 5)
        want, _ = build_engine(g, "u5", "pgbsc").count_colorful(colors)
        e = build_engine(g, "u5", "pgbsc", dtype=jnp.float64,
                         fuse_spmm_ema=True)
        assert e.schedule.fused
        got, _ = e.count_colorful(colors)
        assert float(got) == float(want)


class TestExecutorFusedModel:
    def _plan(self, tname):
        return get_template(tname).plan_dedup

    def test_fused_peak_not_higher(self):
        plan = self._plan("u7")
        k = 7
        fused_nodes = tuple(
            i for i, nd in enumerate(plan.nodes) if not nd.is_leaf)
        s0 = pexec.compute_schedule(plan, k)
        s1 = pexec.compute_schedule(plan, k, fused=fused_nodes)
        p0 = pexec.simulate_peak_rows(plan, k, s0)
        p1 = pexec.simulate_peak_rows(plan, k, s1)
        assert p1 <= p0
        assert s1.fused_set == set(fused_nodes)

    def test_chunking_beats_fusion_on_conflict(self):
        # a node assigned both chunking and fusion must execute chunked:
        # the engine dispatch checks packs first, and the schedule keeps
        # both markers
        g = erdos_renyi(60, 4.0, seed=3)
        e = build_engine(g, "u10", "pgbsc", fuse_spmm_ema=True,
                         memory_budget_bytes=1 << 20)
        for idx in e.schedule.chunk_map:
            assert e.schedule.chunk_map[idx] >= 1
        colors = coloring_numpy(0, 0, g.n, 10)
        want, _ = build_engine(g, "u10", "pgbsc",
                               memory_budget_bytes=1 << 20
                               ).count_colorful(colors)
        got, _ = e.count_colorful(colors)
        np.testing.assert_allclose(float(got), float(want), rtol=1e-6)


class TestAutotune:
    def test_ema_blocks_from_candidates(self):
        autotune.clear_cache()
        rng = np.random.default_rng(0)
        m_a = _rand_table(rng, (5, 256))
        y_p = _rand_table(rng, (10, 256))
        ia, ip = split_tables(5, 3, 1)
        ia, ip = jnp.asarray(ia), jnp.asarray(ip)
        blocks = autotune.ema_blocks(m_a, y_p, ia, ip, interpret=True)
        assert blocks in autotune.EMA_BLOCK_CANDIDATES
        # second call is a cache hit
        n_timed = len(autotune.cache_info())
        assert autotune.ema_blocks(m_a, y_p, ia, ip,
                                   interpret=True) == blocks
        assert len(autotune.cache_info()) == n_timed

    def test_autotuned_ema_matches_ref(self):
        from repro.kernels.ema.ops import ema
        from repro.kernels.ema.ref import ema_ref
        rng = np.random.default_rng(1)
        m_a = _rand_table(rng, (10, 300))
        y_p = _rand_table(rng, (10, 300))
        ia, ip = split_tables(5, 4, 2)
        ia, ip = jnp.asarray(ia), jnp.asarray(ip)
        got = ema(m_a, y_p, ia, ip, use_pallas=True, autotune=True)
        want = ema_ref(m_a, y_p, ia, ip)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=0)

    def test_engine_autotune_matches(self):
        g = erdos_renyi(50, 4.0, seed=4)
        colors = coloring_numpy(0, 0, g.n, 5)
        want, _ = build_engine(g, "u5", "pgbsc").count_colorful(colors)
        e = build_engine(g, "u5", "pgbsc", use_pallas_ema=True,
                         autotune_blocks=True)
        got, _ = e.count_colorful(colors)
        np.testing.assert_allclose(float(got), float(want), rtol=1e-6)


class TestSharedPassiveKernel:
    """One launch, one SpMM leg, N consumers reading the same y tiles."""

    def _inputs(self, g, dtype=np.float32):
        # two consumers of one passive: (k=5, t=5, ta=3) and (k=5, t=4,
        # ta=2) — same c_p = C(5,2), different c_a/s/l per consumer
        rng = np.random.default_rng(11)
        m_p = _rand_table(rng, (comb(5, 2), g.n), dtype)
        m_as, ias, ips = [], [], []
        for t, ta in ((5, 3), (4, 2)):
            ia, ip = split_tables(5, t, ta)
            ias.append(jnp.asarray(ia))
            ips.append(jnp.asarray(ip))
            m_as.append(_rand_table(rng, (comb(5, ta), g.n), dtype))
        return m_as, m_p, ias, ips

    @pytest.mark.parametrize("gname", ["er_uneven", "grid", "empty"])
    def test_matches_oracle_per_consumer(self, gname):
        g = GRAPHS[gname]()
        m_as, m_p, ias, ips = self._inputs(g)
        outs = fused_spmm_ema_shared(m_as, m_p, ias, ips, prepare_fused(g))
        assert len(outs) == 2
        for m_a, ia, ip, got in zip(m_as, ias, ips, outs):
            want = _oracle(g, m_a, m_p, ia, ip)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-6)

    def test_batched(self):
        g = GRAPHS["er_uneven"]()
        m_as, m_p, ias, ips = self._inputs(g)
        b = 3
        m_p_b = jnp.stack([m_p * (i + 1) for i in range(b)])
        m_as_b = [jnp.stack([m * (i + 1) for i in range(b)]) for m in m_as]
        outs = fused_spmm_ema_shared(m_as_b, m_p_b, ias, ips,
                                     prepare_fused(g))
        for m_a, ia, ip, got in zip(m_as, ias, ips, outs):
            assert got.shape[0] == b
            for i in range(b):
                want = _oracle(g, m_a * (i + 1), m_p * (i + 1), ia, ip)
                np.testing.assert_allclose(np.asarray(got[i]),
                                           np.asarray(want), rtol=1e-6)

    def test_bf16_within_tolerance(self):
        g = GRAPHS["er_uneven"]()
        m_as, m_p, ias, ips = self._inputs(g)
        prep16 = prepare_fused(g, dtype=jnp.bfloat16)
        outs = fused_spmm_ema_shared(
            [m.astype(jnp.bfloat16) for m in m_as],
            m_p.astype(jnp.bfloat16), ias, ips, prep16)
        for m_a, ia, ip, got in zip(m_as, ias, ips, outs):
            want = np.asarray(_oracle(g, m_a, m_p, ia, ip), np.float64)
            err = np.abs(np.asarray(got, np.float64) - want)
            rel = err / np.maximum(np.abs(want), 1.0)
            assert rel.max() <= 1e-2

    def test_vmem_overflow_falls_back_exactly(self, monkeypatch):
        from repro.kernels.fused import ops as fops
        monkeypatch.setattr(fops, "_PALLAS_VMEM_BYTES", 1 << 12)
        g = GRAPHS["er_uneven"]()
        m_as, m_p, ias, ips = self._inputs(g)
        outs = fused_spmm_ema_shared(m_as, m_p, ias, ips, prepare_fused(g))
        for m_a, ia, ip, got in zip(m_as, ias, ips, outs):
            want = _oracle(g, m_a, m_p, ia, ip)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-6)


def _shared_passive_bundle():
    """Two k=5 trees (the same unrooted 'fork', rooted differently) whose
    dedup plan shares a path2 passive between T1's root and an interior
    node of T2 — the groupable shape: neither consumer's active is in the
    pair and T2's root runs after both."""
    from repro.core.templates import TreeTemplate
    t1 = TreeTemplate([(0, 1), (1, 2), (0, 3), (0, 4)], root=0,
                      name="sharedp_a")
    t2 = TreeTemplate([(0, 1), (1, 2), (2, 3), (1, 4)], root=0,
                      name="sharedp_b")
    return (t1, t2)


class TestSharedPassiveEngine:
    def test_group_forms_and_counts_match(self):
        g = erdos_renyi(80, 6.0, seed=9)
        bundle = _shared_passive_bundle()
        base = build_engine(g, bundle, "pgbsc", plan="dedup")
        shared = build_engine(g, bundle, "pgbsc", plan="dedup",
                              fuse_spmm_ema=True)
        assert shared.schedule.fused_groups, "expected a shared group"
        grp = shared.schedule.fused_groups[0]
        assert len(grp) == 2
        assert all(shared.fusion_report[m] == "admitted_shared"
                   for m in grp)
        # both group members consume the same passive child
        passives = {shared.plan.nodes[m].passive for m in grp}
        assert len(passives) == 1
        batch = jnp.stack(
            [jnp.asarray(coloring_numpy(0, i, g.n, 5)) for i in range(3)])
        want, _ = base.count_colorful_batch(batch)
        got, _ = shared.count_colorful_batch(batch)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6)

    def test_cols_drop_vs_per_consumer_fusion(self):
        g = erdos_renyi(80, 6.0, seed=9)
        shared = build_engine(g, _shared_passive_bundle(), "pgbsc",
                              plan="dedup", fuse_spmm_ema=True)
        cols = shared.spmm_cols_per_coloring
        # per-consumer fusion would re-run the shared passive's SpMM once
        # per extra member; the group pays it exactly once
        per_consumer = cols + sum(
            (len(grp) - 1) * comb(
                shared.k,
                shared.plan.nodes[shared.plan.nodes[grp[0]].passive].size)
            for grp in shared.schedule.fused_groups)
        assert cols < per_consumer
        # dispatch accounting follows the model
        batch = jnp.stack(
            [jnp.asarray(coloring_numpy(0, i, g.n, 5)) for i in range(2)])
        shared.count_colorful_batch(batch)
        assert shared.n_spmm_cols_dispatched == 2 * cols

    def test_cols_not_worse_than_ycache(self):
        # full-coverage admission: grouping must never dispatch more SpMM
        # columns than the unfused y-cache walk of the same plan
        g = erdos_renyi(80, 6.0, seed=9)
        bundle = _shared_passive_bundle()
        base = build_engine(g, bundle, "pgbsc", plan="dedup")
        shared = build_engine(g, bundle, "pgbsc", plan="dedup",
                              fuse_spmm_ema=True)
        assert shared.spmm_cols_per_coloring <= base.spmm_cols_per_coloring

    def test_chain_consumers_stay_on_ycache(self):
        # path-like shared passives are consumed through active chains: a
        # single launch cannot consume its own outputs, so no group forms
        g = erdos_renyi(60, 5.0, seed=10)
        e = build_engine(g, ("u5", "path5", "star5"), "pgbsc",
                         plan="dedup", fuse_spmm_ema=True)
        assert not e.schedule.fused_groups
        assert "admitted_shared" not in e.fusion_report.values()

    def test_bf16_group_engine_within_tolerance(self):
        g = erdos_renyi(80, 6.0, seed=9)
        bundle = _shared_passive_bundle()
        base = build_engine(g, bundle, "pgbsc", plan="dedup")
        e16 = build_engine(g, bundle, "pgbsc", plan="dedup",
                           fuse_spmm_ema=True, dtype=jnp.bfloat16,
                           reorder="rcm")
        assert e16.schedule.fused_groups
        batch = jnp.stack(
            [jnp.asarray(coloring_numpy(0, i, g.n, 5)) for i in range(2)])
        want, _ = base.count_colorful_batch(batch)
        got, _ = e16.count_colorful_batch(batch)
        want = np.asarray(want, np.float64)
        rel = np.abs(np.asarray(got, np.float64) - want) \
            / np.maximum(np.abs(want), 1.0)
        assert rel.max() <= 1e-2


class TestBf16Engine:
    @pytest.mark.parametrize("tname", ["u5", "u7"])
    @pytest.mark.parametrize("engine", ["fascia", "pfascia", "pgbsc"])
    def test_counts_within_tolerance(self, tname, engine):
        g = erdos_renyi(70, 5.0, seed=12)
        t = get_template(tname)
        base = build_engine(g, t, engine)
        e16 = build_engine(g, t, engine, dtype=jnp.bfloat16)
        batch = jnp.stack(
            [jnp.asarray(coloring_numpy(0, i, g.n, t.k)) for i in range(2)])
        want, _ = base.count_colorful_batch(batch)
        got, _ = e16.count_colorful_batch(batch)
        want = np.asarray(want, np.float64)
        rel = np.abs(np.asarray(got, np.float64) - want) \
            / np.maximum(np.abs(want), 1.0)
        assert rel.max() <= 1e-2

    def test_fused_bf16_matches_f32_within_tolerance(self):
        g = erdos_renyi(70, 5.0, seed=12)
        base = build_engine(g, "u5", "pgbsc")
        e16 = build_engine(g, "u5", "pgbsc", dtype=jnp.bfloat16,
                           fuse_spmm_ema=True)
        assert e16.schedule.fused, "bf16 must stay kernel-eligible"
        colors = coloring_numpy(0, 0, g.n, 5)
        want = float(base.count_colorful(colors)[0])
        got = float(e16.count_colorful(colors)[0])
        assert abs(got - want) / max(abs(want), 1.0) <= 1e-2
