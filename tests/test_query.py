"""Query API: TemplateSpec, fused multi-template plans, count/count_many,
canonical-hash identity through the service stack."""

import json
from math import factorial

import numpy as np
import pytest

from repro.api import (CountQuery, TemplateSpec, compile_query, count,
                       count_many)
from repro.core import (count_subgraphs_exact, compile_fused_plan,
                        get_template)
from repro.core.motif_features import motif_features
from repro.core.templates import STANDARD_TEMPLATES, TreeTemplate
from repro.graph import erdos_renyi
from repro.service import (CountingService, CountRequest, EngineCache,
                           EstimateCache)
from repro.service.cache import SCHEMA_VERSION

BUNDLE = ("u5", "u7", "path5", "star5")


def _graph(n=40, deg=4.0, seed=0):
    return erdos_renyi(n, deg, seed=seed)


class TestTemplateSpec:
    def test_json_roundtrip(self):
        spec = TemplateSpec(edges=((0, 1), (1, 2), (1, 3)), root=2,
                            name="chair")
        back = TemplateSpec.from_json(spec.to_json())
        assert back == spec
        assert back.canonical_hash == spec.canonical_hash
        assert back.k == 4 and back.root == 2

    def test_coercion_sugar(self):
        by_name = TemplateSpec.of("u5")
        assert by_name.k == 5 and by_name.name == "u5"
        by_tree = TemplateSpec.of(get_template("u5"))
        assert by_tree.canonical_hash == by_name.canonical_hash
        by_edges = TemplateSpec.of([(0, 1), (1, 2)])
        assert by_edges.k == 3
        assert TemplateSpec.of(by_edges) is by_edges

    def test_canonical_hash_ignores_labels_and_names(self):
        a = TemplateSpec.of("path5")
        b = TemplateSpec(edges=((4, 3), (3, 2), (2, 1), (1, 0)), root=4,
                         name="whatever")
        assert a.canonical_hash == b.canonical_hash
        assert a.canonical_hash != TemplateSpec.of("star5").canonical_hash

    def test_root_changes_rooted_identity(self):
        end = TemplateSpec(edges=((0, 1), (1, 2)), root=0)
        mid = TemplateSpec(edges=((0, 1), (1, 2)), root=1)
        assert end.canonical_hash != mid.canonical_hash

    def test_edge_string_parsing(self):
        spec = TemplateSpec.from_edge_string("0-1,1-2,1-3@1")
        assert spec.root == 1 and spec.k == 4
        with pytest.raises(ValueError):
            TemplateSpec.from_edge_string("0:1")

    def test_invalid_specs_raise_eagerly(self):
        with pytest.raises(ValueError):
            TemplateSpec.of([(0, 1), (1, 2), (2, 0)])


class TestTemplateValidation:
    """TreeTemplate.__init__ rejects garbage with clear errors (satellite)."""

    @pytest.mark.parametrize("edges,kw,fragment", [
        ([(0, 1), (1, 2), (2, 0)], {}, "cycle"),
        ([(0, 1), (0, 1)], {}, "cycle"),
        ([(0, 0)], {}, "self-loop"),
        ([(0, 1), (2, 3)], {}, "disconnected"),
        ([(0, 1)], {"root": 5}, "out of range"),
        ([(0, 1)], {"root": -1}, "out of range"),
        ([(0, -1)], {}, "negative"),
        ([(0, 2)], {}, "skips"),
    ])
    def test_rejections(self, edges, kw, fragment):
        with pytest.raises(ValueError, match=fragment):
            TreeTemplate(edges, **kw)

    def test_valid_edge_cases_still_build(self):
        assert TreeTemplate([]).k == 1            # single vertex
        assert TreeTemplate([(1, 0)]).k == 2      # orientation-insensitive


class TestDynamicTemplateNames:
    def test_dynamic_paths_and_stars(self):
        assert get_template("path6").k == 6
        assert get_template("star9").automorphisms == factorial(8)
        assert get_template("path6") is get_template("path6")  # memoized

    def test_registry_takes_precedence(self):
        assert get_template("path5") is STANDARD_TEMPLATES["path5"]

    def test_keyerror_mentions_dynamic_forms(self):
        with pytest.raises(KeyError) as ei:
            get_template("nope")
        assert "path{k}" in str(ei.value) and "star{k}" in str(ei.value)
        with pytest.raises(KeyError):
            get_template("path1")                 # k < 2 is not a template


class TestFusedPlan:
    def test_cross_template_sharing_shrinks_plan(self):
        trees = [get_template(n) for n in ("u5", "path5", "star5")]
        fp = compile_fused_plan(trees)
        assert fp.plan.n_nodes < sum(t.plan_optimized.n_nodes for t in trees)
        assert len(fp.roots) == 3
        for r, t in zip(fp.roots, trees):
            assert fp.plan.nodes[r].size == t.k

    def test_mixed_k_rejected(self):
        with pytest.raises(ValueError, match="equal k"):
            compile_fused_plan(["u5", "u7"])

    def test_duplicate_templates_share_one_root(self):
        fp = compile_fused_plan(["u5", "u5"])
        assert fp.roots[0] == fp.roots[1]


class TestCountManyAcceptance:
    """count_many over the u5/u7/path5/star5 bundle matches per-template
    count to 1e-6 while dispatching strictly fewer SpMM column-ops."""

    def test_matches_solo_with_fewer_spmm_cols(self):
        g = _graph(60, 5.0, seed=0)
        solo_results, solo_cols = [], 0
        for name in BUNDLE:
            cq = compile_query(g, CountQuery(templates=[name], max_iters=10,
                                             seed=3))
            solo_results.append(cq.run()[0])
            solo_cols += sum(e.n_spmm_cols_dispatched for e in cq.engines)
        fused = compile_query(g, CountQuery(templates=list(BUNDLE),
                                            max_iters=10, seed=3))
        fused_results = fused.run()
        fused_cols = sum(e.n_spmm_cols_dispatched for e in fused.engines)
        for fr, sr in zip(fused_results, solo_results):
            assert fr.iterations == sr.iterations == 10
            assert fr.estimate == pytest.approx(sr.estimate, rel=1e-6)
            assert fr.stderr == pytest.approx(sr.stderr, rel=1e-5, abs=1e-9)
        assert fused_cols < solo_cols, (fused_cols, solo_cols)
        # the k=5 trio shares one engine, u7 runs alone
        assert len(fused.engines) == 2

    def test_count_near_exact(self):
        g = _graph(30, 4.0, seed=0)
        t = get_template("u3")
        res = count(g, "u3", max_iters=150, seed=1)
        assert res.estimate == pytest.approx(count_subgraphs_exact(g, t),
                                             rel=0.25)

    def test_adaptive_target_and_cap(self):
        g = _graph()
        res = count(g, "u3", rel_stderr=0.5, max_iters=64, seed=0)
        assert res.target_met and res.iterations <= 64
        capped = count(g, "u3", max_iters=6, seed=0)
        assert capped.iterations == 6

    def test_engine_cache_shared_across_queries(self):
        g = _graph()
        cache = EngineCache()
        count(g, "u3", max_iters=4, engine_cache=cache)
        count(g, TemplateSpec(edges=((0, 1), (1, 2))), max_iters=4,
              engine_cache=cache)   # same tree, different spelling
        assert cache.stats()["builds"] == 1

    def test_count_many_mixed_inputs_in_order(self):
        g = _graph()
        results = count_many(
            g, ["u3", [(0, 1), (1, 2), (1, 3)], get_template("path4")],
            max_iters=4, seed=2)
        assert len(results) == 3
        assert all(np.isfinite(r.estimate) for r in results)
        # order is preserved across k-groups (k=3 and two k=4 templates)
        assert results[0].estimate == pytest.approx(
            count(g, "u3", max_iters=4, seed=2).estimate, rel=1e-6)


class TestMotifFeaturesFused:
    def test_matches_per_template_loop(self):
        g = _graph(30, 3.0, seed=2)
        fused = motif_features(g, ["path4", "star4"], n_iters=4, seed=5,
                               log1p=False)
        solo = np.stack([
            motif_features(g, [n], n_iters=4, seed=5, log1p=False)[:, 0]
            for n in ("path4", "star4")], axis=1)
        np.testing.assert_allclose(fused, solo, rtol=2e-5)


class TestServiceSpecRequests:
    def test_arbitrary_edge_list_round_trips(self, tmp_path):
        """An arbitrary edge-list template submitted through the service
        reaches a finished estimate end-to-end (acceptance)."""
        g = _graph()
        svc = CountingService(ledger_root=str(tmp_path), round_size=4)
        svc.add_graph("g", g)
        spec = TemplateSpec(edges=((0, 1), (1, 2), (1, 3)), name="chair")
        rid = svc.submit(CountRequest("g", spec, max_iters=6))
        res = svc.run()[rid]
        assert res.iterations == 6 and np.isfinite(res.estimate)
        direct = count(g, spec, max_iters=6, seed=0)
        assert res.estimate == pytest.approx(direct.estimate, rel=1e-6)

    def test_two_spellings_share_group_engine_and_ledger(self, tmp_path):
        g = _graph()
        svc = CountingService(ledger_root=str(tmp_path), round_size=4)
        svc.add_graph("g", g)
        relabeled = TemplateSpec(edges=((3, 2), (2, 1), (1, 0)), root=3)
        r1 = svc.submit(CountRequest("g", "path4", max_iters=4))
        r2 = svc.submit(CountRequest("g", relabeled, max_iters=4))
        res = svc.run()
        stats = svc.stats()
        assert stats["groups"] == 1
        assert stats["engine_cache"]["builds"] == 1
        assert res[r1].estimate == res[r2].estimate
        assert res[r2].shared_group

    def test_submit_rejects_malformed_templates(self, tmp_path):
        svc = CountingService(ledger_root=str(tmp_path))
        svc.add_graph("g", _graph())
        with pytest.raises(KeyError):
            svc.submit(CountRequest("g", "not-a-template", max_iters=4))
        with pytest.raises(ValueError, match="cycle"):
            svc.submit(CountRequest(
                "g", TemplateSpec(edges=((0, 1), (1, 2), (2, 0))),
                max_iters=4))


class TestEstimateCacheSchema:
    def test_stale_schema_ignored_not_crashed(self, tmp_path):
        p = tmp_path / "est.json"
        # pre-versioning layout: flat name-keyed entries
        p.write_text(json.dumps({"fp:u3:pgbsc:optimized:s0": {
            "estimate": 1.0, "stderr": 0.1, "rel_stderr": 0.1,
            "iterations": 8}}))
        cache = EstimateCache(str(p))
        assert len(cache) == 0

    def test_current_schema_roundtrips(self, tmp_path):
        p = str(tmp_path / "est.json")
        cache = EstimateCache(p)
        key = EstimateCache.key("fp", TemplateSpec.of("u3"), "pgbsc",
                                "optimized", 0)
        cache.put(key, {"estimate": 2.0, "stderr": 0.1, "rel_stderr": 0.05,
                        "iterations": 16})
        data = json.loads(open(p).read())
        assert data["schema"] == SCHEMA_VERSION
        again = EstimateCache(p)
        assert again.get(key)["estimate"] == 2.0

    def test_key_is_name_independent(self):
        a = EstimateCache.key("fp", "path4", "pgbsc", "optimized", 0)
        b = EstimateCache.key(
            "fp", TemplateSpec(edges=((3, 2), (2, 1), (1, 0)), root=3),
            "pgbsc", "optimized", 0)
        assert a == b
