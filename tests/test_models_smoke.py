"""Per-architecture smoke tests: reduced config, one forward + one train step
on CPU, asserting output shapes and no NaNs — for all 10 assigned archs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.data.synthetic import make_batch, statics_for
from repro.optim.optimizer import AdamWConfig
from repro.train.step import (build_serve_step, build_train_step,
                              concrete_train_state)

LM_ARCHS = [a for a in ARCH_IDS if get_config(a).family == "lm"]
GNN_ARCHS = [a for a in ARCH_IDS if get_config(a).family == "gnn"]
REC_ARCHS = [a for a in ARCH_IDS if get_config(a).family == "recsys"]


def _train_once(arch, cell_name, d_in=None):
    key = jax.random.PRNGKey(0)
    state = concrete_train_state(arch, key, d_in=d_in)
    statics = statics_for(arch, cell_name)
    batch = make_batch(arch, cell_name, key)
    step = build_train_step(arch, AdamWConfig(warmup_steps=1, total_steps=10),
                            statics=statics)
    state2, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"])), metrics
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    moved = jax.tree_util.tree_reduce(
        lambda acc, pq: acc + float(jnp.sum(jnp.abs(
            pq[0].astype(jnp.float32) - pq[1].astype(jnp.float32)))),
        jax.tree_util.tree_map(lambda a, b: (a, b), state["params"],
                               state2["params"]),
        0.0, is_leaf=lambda x: isinstance(x, tuple))
    assert moved > 0.0
    return state2, float(metrics["loss"])


class TestAllArchsRegistered:
    def test_registry_complete(self):
        assert set(ARCH_IDS) == {
            "smollm-360m", "llama3-8b", "gemma3-1b", "deepseek-moe-16b",
            "qwen3-moe-30b-a3b", "graphsage-reddit", "pna", "gatedgcn",
            "nequip", "autoint"}

    def test_full_configs_match_assignment(self):
        c = get_config("llama3-8b").model
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab_size) == (32, 4096, 32, 8, 14336, 128256)
        c = get_config("smollm-360m").model
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab_size) == (32, 960, 15, 5, 2560, 49152)
        c = get_config("gemma3-1b").model
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab_size) == (26, 1152, 4, 1, 6912, 262144)
        assert c.global_every == 6 and c.sliding_window
        c = get_config("deepseek-moe-16b").model
        assert (c.n_layers, c.d_model, c.moe.n_experts, c.moe.top_k,
                c.moe.n_shared) == (28, 2048, 64, 6, 2)
        c = get_config("qwen3-moe-30b-a3b").model
        assert (c.n_layers, c.moe.n_experts, c.moe.top_k,
                c.vocab_size) == (48, 128, 8, 151936)
        c = get_config("nequip").model
        assert (c.n_layers, c.d_hidden) == (5, 32)
        assert dict(c.extras)["l_max"] == 2
        c = get_config("autoint").model
        assert (c.n_sparse, c.embed_dim, c.n_attn_layers, c.n_heads,
                c.d_attn) == (39, 16, 3, 2, 32)

    def test_every_arch_has_four_cells(self):
        for a in ARCH_IDS:
            assert len(get_config(a).cells) == 4, a


class TestLMSmoke:
    @pytest.mark.parametrize("arch_id", LM_ARCHS)
    def test_train_step(self, arch_id):
        arch = reduced_config(arch_id)
        _train_once(arch, "smoke_train")

    @pytest.mark.parametrize("arch_id", LM_ARCHS)
    def test_prefill_and_decode(self, arch_id):
        arch = reduced_config(arch_id)
        key = jax.random.PRNGKey(1)
        state = concrete_train_state(arch, key)
        pre = build_serve_step(arch, "prefill")
        logits = jax.jit(pre)(state["params"],
                              make_batch(arch, "smoke_prefill", key))
        assert logits.shape == (1, 48, arch.model.vocab_size)
        assert np.isfinite(np.asarray(logits)).all()
        dec = build_serve_step(arch, "decode")
        batch = make_batch(arch, "smoke_decode", key)
        logits, cache = jax.jit(dec)(state["params"], batch)
        assert logits.shape == (2, 1, arch.model.vocab_size)
        assert int(cache["len"]) == int(batch["cache"]["len"]) + 1
        assert np.isfinite(np.asarray(logits)).all()


class TestGNNSmoke:
    @pytest.mark.parametrize("arch_id", GNN_ARCHS)
    @pytest.mark.parametrize("cell", ["smoke_full", "smoke_molecule"])
    def test_train_step(self, arch_id, cell):
        arch = reduced_config(arch_id)
        d_in = arch.cell(cell).dims["d_feat"]
        _train_once(arch, cell, d_in=d_in)


class TestRecsysSmoke:
    @pytest.mark.parametrize("arch_id", REC_ARCHS)
    def test_train_step(self, arch_id):
        arch = reduced_config(arch_id)
        _train_once(arch, "smoke_train")

    def test_retrieval(self):
        arch = reduced_config("autoint")
        key = jax.random.PRNGKey(2)
        state = concrete_train_state(arch, key)
        serve = build_serve_step(arch, "retrieval")
        scores = jax.jit(serve)(state["params"],
                                make_batch(arch, "smoke_retrieval", key))
        assert scores.shape == (2, 128)
        assert np.isfinite(np.asarray(scores)).all()

    def test_embedding_bag_modes(self):
        from repro.models.recsys import embedding_bag
        table = jnp.asarray(np.random.default_rng(0).normal(size=(10, 4))
                            .astype(np.float32))
        idx = jnp.asarray([[0, 1, -1], [2, -1, -1], [-1, -1, -1]])
        s = embedding_bag(table, idx, mode="sum")
        np.testing.assert_allclose(np.asarray(s[0]),
                                   np.asarray(table[0] + table[1]), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(s[2]), 0.0)
        m = embedding_bag(table, idx, mode="mean")
        np.testing.assert_allclose(
            np.asarray(m[0]), np.asarray((table[0] + table[1]) / 2), rtol=1e-6)
        mx = embedding_bag(table, idx, mode="max")
        np.testing.assert_allclose(
            np.asarray(mx[1]), np.asarray(table[2]), rtol=1e-6)
