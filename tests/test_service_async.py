"""Async serving front end: QoS policy, backpressure/shedding, bitwise
equivalence with the synchronous round scheduler, and the HTTP surface.

The core invariant under test: every sample is a deterministic function
of (seed, iteration id), so the continuously-admitting dispatcher —
whatever order QoS makes it dispatch groups in — must reproduce the
round scheduler's estimates bit-for-bit.
"""

import json
import threading
import urllib.error
import urllib.request

import pytest

from repro.graph import erdos_renyi
from repro.service import (AdmissionQueue, AsyncCountingService,
                           CountingService, CountRequest, EngineCache,
                           EstimateCache, FairScheduler, QoS, QoSClass,
                           RequestStatus)
from repro.service.qos import (SHED_CLOSED, SHED_MEMORY, SHED_QUEUE_FULL,
                               GroupView)

INF = float("inf")


def _graph(n=30, deg=4.0, seed=0):
    return erdos_renyi(n, deg, seed=seed)


def _asvc(tmp_path, name="async", **kw):
    kw.setdefault("round_size", 4)
    kw.setdefault("default_max_iters", 64)
    kw.setdefault("idle_wait_s", 0.01)
    return AsyncCountingService(ledger_root=str(tmp_path / name), **kw)


def _gv(key, rank, deadline=INF, tenants=(("t", 1.0),)):
    return GroupView(key=key, rank=rank, deadline=deadline, tenants=tenants)


class TestQoS:
    def test_coercion_and_defaults(self):
        q = QoS(klass="deadline")
        assert q.klass is QoSClass.DEADLINE
        assert q.deadline_s == 30.0          # deadline class gets a budget
        assert QoS().klass is QoSClass.INTERACTIVE

    def test_validation(self):
        with pytest.raises(ValueError):
            QoS(weight=0.0)
        with pytest.raises(ValueError):
            QoS(deadline_s=-1.0)
        with pytest.raises(ValueError):
            QoS(klass="platinum")


class TestFairScheduler:
    def test_strict_class_priority(self):
        pol = FairScheduler()
        b = _gv("b", QoSClass.BATCH.rank)
        i = _gv("i", QoSClass.INTERACTIVE.rank)
        d = _gv("d", QoSClass.DEADLINE.rank, deadline=99.0)
        assert pol.pick([b, i, d]) is d
        assert pol.pick([b, i]) is i

    def test_edf_within_deadline_class(self):
        pol = FairScheduler()
        early = _gv("early", 0, deadline=10.0, tenants=(("a", 1.0),))
        late = _gv("late", 0, deadline=20.0, tenants=(("b", 1.0),))
        assert pol.pick([late, early]) is early

    def test_fifo_on_exact_ties(self):
        pol = FairScheduler()
        a = _gv("a", 2, tenants=(("t1", 1.0),))
        b = _gv("b", 2, tenants=(("t2", 1.0),))
        assert pol.pick([a, b]) is a
        assert pol.pick([b, a]) is b

    def test_weighted_fair_share_is_proportional(self):
        # under sustained contention a weight-2 tenant gets exactly twice
        # the dispatches of a weight-1 tenant
        pol = FairScheduler()
        heavy = _gv("heavy", 2, tenants=(("heavy", 2.0),))
        light = _gv("light", 2, tenants=(("light", 1.0),))
        wins = {"heavy": 0, "light": 0}
        for _ in range(30):
            gv = pol.pick([heavy, light])
            wins[gv.key] += 1
            pol.charge(gv.tenants, 8)
        assert wins["heavy"] == 2 * wins["light"]

    def test_newcomer_starts_at_floor_no_banked_credit(self):
        pol = FairScheduler()
        pol.charge([("old", 1.0)], 100)
        old = _gv("old", 1, tenants=(("old", 1.0),))
        new = _gv("new", 1, tenants=(("new", 1.0),))
        # an idle newcomer starts at the current floor, not at zero: one
        # dispatch charged to it puts it *behind* the incumbent instead of
        # letting it monopolize with 100 units of banked credit
        pol.charge([("new", 1.0)], 8)
        assert pol.pick([new, old]) is old
        assert pol.virtual_times()["new"] > 100.0


class TestAdmissionQueue:
    def test_bounded_offer_and_drain(self):
        q = AdmissionQueue(2)
        assert q.offer("a") is None
        assert q.offer("b") is None
        assert q.offer("c") == SHED_QUEUE_FULL
        assert len(q) == 2
        assert q.drain() == ["a", "b"]
        assert len(q) == 0
        assert q.offer("c") is None      # capacity freed by the drain

    def test_maxsize_validated(self):
        with pytest.raises(ValueError):
            AdmissionQueue(0)


class TestBackpressure:
    def test_queue_full_sheds_with_reason(self, tmp_path):
        # dispatcher deliberately not started: the queue cannot drain
        svc = _asvc(tmp_path, max_queue_depth=1)
        svc.add_graph("g", _graph())
        r1 = svc.submit(CountRequest("g", "u3", max_iters=4))
        r2 = svc.submit(CountRequest("g", "u3", max_iters=4, seed=1))
        assert svc.status(r1) is RequestStatus.PENDING
        assert svc.status(r2) is RequestStatus.SHED
        assert svc.shed_reason(r2) == SHED_QUEUE_FULL
        assert svc.shed_reason(r1) is None
        with pytest.raises(RuntimeError):
            svc.result(r2)
        # SHED is terminal: waiters do not hang on it
        assert svc.wait([r2], timeout=5.0)
        assert svc.stats()["shed"] == 1

    def test_memory_budget_sheds_at_admission(self, tmp_path):
        svc = _asvc(tmp_path, memory_budget_bytes=1)
        svc.add_graph("g", _graph())
        rid = svc.submit(CountRequest("g", "u5", max_iters=4))
        assert svc.status(rid) is RequestStatus.SHED
        assert svc.shed_reason(rid) == SHED_MEMORY
        # admission control used the analytic model only: no build wasted
        assert svc.engine_cache.stats()["builds"] == 0

    def test_closed_service_sheds(self, tmp_path):
        svc = _asvc(tmp_path)
        svc.add_graph("g", _graph())
        svc.start()
        svc.close()
        rid = svc.submit(CountRequest("g", "u3", max_iters=4))
        assert svc.status(rid) is RequestStatus.SHED
        assert svc.shed_reason(rid) == SHED_CLOSED

    def test_saturated_queue_never_deadlocks(self, tmp_path):
        # many submitters against a 2-deep queue with the dispatcher live:
        # every request must reach a terminal status and close() must
        # return — shed requests shed, admitted ones finish
        g = _graph(seed=13)
        svc = _asvc(tmp_path, max_queue_depth=2)
        svc.add_graph("g", g)
        with svc:
            rids = [svc.submit(CountRequest("g", "u3", max_iters=4,
                                            seed=i % 2),
                               qos=QoS(tenant=f"t{i % 3}"))
                    for i in range(12)]
            assert svc.wait(rids, timeout=180.0)
        statuses = {svc.status(r) for r in rids}
        assert statuses <= {RequestStatus.DONE, RequestStatus.SHED}
        assert any(svc.status(r) is RequestStatus.DONE for r in rids)
        assert svc._thread is None       # dispatcher exited cleanly


class TestAsyncScheduling:
    def test_async_matches_sync_bitwise(self, tmp_path):
        g = _graph(36, 4.0, seed=11)
        cache = EngineCache()
        reqs = [dict(template="u3", rel_stderr=0.2, seed=3),
                dict(template="path4", max_iters=12, seed=4),
                dict(template="u3", rel_stderr=0.2, seed=3)]  # shares group

        sync = CountingService(ledger_root=str(tmp_path / "sync"),
                               round_size=4, engine_cache=cache)
        sync.add_graph("g", g)
        srids = [sync.submit(CountRequest("g", **r)) for r in reqs]
        sync.run()

        asvc = _asvc(tmp_path, engine_cache=cache)
        asvc.add_graph("g", g)
        with asvc:
            arids = [asvc.submit(CountRequest("g", **r),
                                 qos=QoS(tenant=f"t{i}"))
                     for i, r in enumerate(reqs)]
            assert asvc.drain(timeout=180.0)
        for sr, ar in zip(srids, arids):
            s, a = sync.result(sr), asvc.result(ar)
            assert a.estimate == s.estimate
            assert a.stderr == s.stderr
            assert a.iterations == s.iterations
        assert asvc.stats()["groups"] == 2

    def test_deadline_retires_before_batch_under_contention(self, tmp_path):
        # submit everything while the dispatcher is down, then start it:
        # all three groups contend from the first dispatch boundary, and
        # the deadline group must win every round until it retires
        g = _graph(seed=12)
        svc = _asvc(tmp_path)
        svc.add_graph("g", g)
        batch = [svc.submit(CountRequest("g", "u3", max_iters=24, seed=s),
                            qos=QoS(klass="batch", tenant="etl"))
                 for s in (0, 1)]
        dl = svc.submit(CountRequest("g", "path4", max_iters=8, seed=2),
                        qos=QoS(klass="deadline", deadline_s=60.0,
                                tenant="sla"))
        with svc:
            assert svc.drain(timeout=180.0)
        order = svc.retired_order()
        assert order.index(dl) < min(order.index(r) for r in batch)
        assert svc.result(dl).iterations == 8

    def test_cancel_while_queued_is_honored(self, tmp_path):
        svc = _asvc(tmp_path)
        svc.add_graph("g", _graph())
        rid = svc.submit(CountRequest("g", "u3", max_iters=4))
        svc.cancel(rid)
        assert svc.status(rid) is RequestStatus.CANCELLED
        with svc:
            assert svc.drain(timeout=60.0)
        # the dispatcher drained the queue without resurrecting it
        assert svc.status(rid) is RequestStatus.CANCELLED
        assert svc.stats()["groups"] == 0

    def test_sync_run_guarded_while_dispatcher_alive(self, tmp_path):
        svc = _asvc(tmp_path)
        with svc:
            with pytest.raises(RuntimeError, match="async dispatcher"):
                svc.run()


def _ent(iters):
    return {"estimate": float(iters), "stderr": 0.1,
            "rel_stderr": 0.1, "iterations": iters}


class TestEstimateCacheConcurrency:
    def test_concurrent_writers_single_instance(self, tmp_path):
        path = str(tmp_path / "est.json")
        cache = EstimateCache(path)

        def put_range(base):
            for i in range(20):
                cache.put(f"k{base + i}", _ent(base + i + 1))

        threads = [threading.Thread(target=put_range, args=(j * 20,))
                   for j in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        with open(path) as f:
            json.load(f)                 # the file is always valid JSON
        assert len(EstimateCache(path)) == 80

    def test_two_instances_same_path_union_survives(self, tmp_path):
        # two service processes sharing one cache file: read-modify-write
        # under the file lock merges, so neither clobbers the other
        path = str(tmp_path / "est.json")
        a, b = EstimateCache(path), EstimateCache(path)
        a.put("ka", _ent(4))
        b.put("kb", _ent(4))             # b never saw ka in memory
        a.put("shared", _ent(4))
        b.put("shared", _ent(8))         # more iterations wins the merge
        a.put("shared", _ent(2))         # stale lower-precision write loses
        fresh = EstimateCache(path)
        assert fresh.get("ka") is not None
        assert fresh.get("kb") is not None
        assert fresh.get("shared")["iterations"] == 8
        assert len(fresh) == 3


class TestHTTPFrontend:
    def test_count_result_and_health_end_to_end(self, tmp_path):
        from repro.service.frontend import make_server
        g = _graph(seed=14)
        svc = _asvc(tmp_path, name="http")
        svc.add_graph("g", g)
        svc.start()
        httpd = make_server(svc, "127.0.0.1", 0)   # ephemeral port
        port = httpd.server_address[1]
        threading.Thread(target=httpd.serve_forever, daemon=True).start()
        base = f"http://127.0.0.1:{port}"
        try:
            body = json.dumps({
                "graph": "g", "templates": ["u3"], "max_iters": 4,
                "qos": {"class": "interactive", "tenant": "alice"},
                "wait": True, "timeout_s": 120}).encode()
            req = urllib.request.Request(
                base + "/count", data=body,
                headers={"Content-Type": "application/json"})
            with urllib.request.urlopen(req, timeout=120) as resp:
                assert resp.status == 200
                payload = json.load(resp)
            (ent,) = payload["requests"]
            assert ent["status"] == "done"
            assert ent["result"]["iterations"] == 4

            with urllib.request.urlopen(f"{base}/result/{ent['id']}",
                                        timeout=30) as resp:
                again = json.load(resp)
            assert again["result"]["estimate"] == ent["result"]["estimate"]

            with urllib.request.urlopen(base + "/healthz",
                                        timeout=30) as resp:
                assert json.load(resp)["ok"]
            with urllib.request.urlopen(base + "/metrics.json",
                                        timeout=30) as resp:
                snap = json.load(resp)
            assert any("qos=" in k for k in snap["histograms"])
        finally:
            httpd.shutdown()
            svc.close()

    def test_bad_template_is_a_400_unknown_route_404(self, tmp_path):
        from repro.service.frontend import make_server
        svc = _asvc(tmp_path, name="http2")
        svc.add_graph("g", _graph())
        svc.start()
        httpd = make_server(svc, "127.0.0.1", 0)
        port = httpd.server_address[1]
        threading.Thread(target=httpd.serve_forever, daemon=True).start()
        base = f"http://127.0.0.1:{port}"
        try:
            req = urllib.request.Request(
                base + "/count",
                data=json.dumps({"templates": ["no-such-template"],
                                 "max_iters": 4}).encode(),
                headers={"Content-Type": "application/json"})
            with pytest.raises(urllib.error.HTTPError) as ei:
                urllib.request.urlopen(req, timeout=30)
            assert ei.value.code == 400
            with pytest.raises(urllib.error.HTTPError) as ei:
                urllib.request.urlopen(base + "/nope", timeout=30)
            assert ei.value.code == 404
        finally:
            httpd.shutdown()
            svc.close()
