"""Template partitioning, color-set indexing, automorphisms."""

from itertools import combinations
from math import comb, factorial

import pytest

from repro.core import (STANDARD_TEMPLATES, TreeTemplate, all_colorsets,
                        get_template, rank_colorset, split_tables,
                        tree_automorphisms, unrank_colorset)


class TestTemplates:
    def test_all_standard_templates_are_trees(self):
        for name, t in STANDARD_TEMPLATES.items():
            assert len(t.edges) == t.k - 1, name

    @pytest.mark.parametrize("name", sorted(STANDARD_TEMPLATES))
    def test_plan_structure(self, name):
        t = get_template(name)
        plan = t.plan
        # post-order: children precede parents; root node covers all vertices
        assert plan.nodes[-1].size == t.k
        sizes = set()
        for i, nd in enumerate(plan.nodes):
            if nd.is_leaf:
                assert nd.size == 1
            else:
                a, p = plan.nodes[nd.active], plan.nodes[nd.passive]
                assert a.size + p.size == nd.size
                assert nd.active < i and nd.passive < i
                # active child keeps the root
                assert a.root == nd.root
            sizes.add(nd.size)

    @pytest.mark.parametrize("name", ["u10", "u12", "u13", "u15-1", "u17"])
    def test_dedup_plan_is_smaller_and_consistent(self, name):
        t = get_template(name)
        assert t.plan_dedup.n_nodes <= t.plan.n_nodes
        assert t.plan_dedup.nodes[-1].size == t.k

    def test_invalid_templates_rejected(self):
        with pytest.raises(ValueError):
            TreeTemplate([(0, 1), (0, 1)])  # duplicate edge -> not a tree
        with pytest.raises(ValueError):
            TreeTemplate([(0, 1), (2, 3)])  # forest with a 4th vertex missing edge


class TestColorsets:
    @pytest.mark.parametrize("k,h", [(3, 1), (5, 2), (7, 3), (10, 5), (12, 6)])
    def test_rank_is_bijection(self, k, h):
        ranks = [rank_colorset(c) for c in combinations(range(k), h)]
        assert sorted(ranks) == list(range(comb(k, h)))

    @pytest.mark.parametrize("k,h", [(5, 2), (8, 4), (11, 3)])
    def test_unrank_inverts_rank(self, k, h):
        for c in combinations(range(k), h):
            assert unrank_colorset(rank_colorset(c), h, k) == tuple(c)

    def test_all_colorsets_ordering(self):
        sets = all_colorsets(6, 3)
        for i, s in enumerate(sets):
            assert rank_colorset(s) == i

    @pytest.mark.parametrize("k,t,ta", [(5, 3, 1), (7, 4, 2), (10, 6, 3)])
    def test_split_tables_partition_colorsets(self, k, t, ta):
        ia, ip = split_tables(k, t, ta)
        assert ia.shape == (comb(k, t), comb(t, ta))
        sets_t = all_colorsets(k, t)
        sets_a = all_colorsets(k, ta)
        sets_p = all_colorsets(k, t - ta)
        for j, cset in enumerate(sets_t):
            for l in range(ia.shape[1]):
                a = set(sets_a[ia[j, l]])
                p = set(sets_p[ip[j, l]])
                assert a | p == set(cset)
                assert not (a & p)


class TestAutomorphisms:
    @pytest.mark.parametrize("k", [2, 3, 5, 8])
    def test_path(self, k):
        edges = [(i, i + 1) for i in range(k - 1)]
        assert tree_automorphisms(edges, k) == 2 if k > 1 else 1

    @pytest.mark.parametrize("k", [3, 4, 6, 9])
    def test_star(self, k):
        edges = [(0, i) for i in range(1, k)]
        assert tree_automorphisms(edges, k) == factorial(k - 1)

    def test_spider(self):
        # 3 legs of length 2 from a hub: aut = 3! = 6
        edges = [(0, 1), (1, 2), (0, 3), (3, 4), (0, 5), (5, 6)]
        assert tree_automorphisms(edges, 7) == 6

    def test_bicentral_symmetric(self):
        # two stars joined by an edge: aut = 2 * (2!)^2
        edges = [(0, 1), (0, 2), (0, 3), (3, 4), (3, 5)]
        assert tree_automorphisms(edges, 6) == 8

    def test_matches_brute_force(self):
        # brute-force check on all trees of size <= 6 (Prüfer enumeration)
        from itertools import product

        def prufer_to_tree(seq, k):
            degree = [1] * k
            for v in seq:
                degree[v] += 1
            edges = []
            leaves = sorted(i for i in range(k) if degree[i] == 1)
            import heapq
            heapq.heapify(leaves)
            for v in seq:
                leaf = heapq.heappop(leaves)
                edges.append((leaf, v))
                degree[v] -= 1
                if degree[v] == 1:
                    heapq.heappush(leaves, v)
            u = heapq.heappop(leaves)
            w = heapq.heappop(leaves)
            edges.append((u, w))
            return edges

        def brute_aut(edges, k):
            from itertools import permutations
            eset = {frozenset(e) for e in edges}
            count = 0
            for perm in permutations(range(k)):
                if all(frozenset((perm[a], perm[b])) in eset for a, b in eset):
                    count += 1
            return count

        for k in (4, 5, 6):
            seen = set()
            for seq in product(range(k), repeat=k - 2):
                edges = tuple(sorted(tuple(sorted(e))
                                     for e in prufer_to_tree(list(seq), k)))
                if edges in seen:
                    continue
                seen.add(edges)
                assert tree_automorphisms(edges, k) == brute_aut(edges, k)
