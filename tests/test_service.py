"""Multi-tenant counting service: caches, adaptive stopping, group
batching equivalence, and ledger-based resume.

Every sample is a deterministic function of (seed, iteration id), so
service-level invariants are exact: shared groups and resumed services
reproduce solo runs bit-for-bit, not just statistically.
"""

import math
import os

import numpy as np
import pytest

from repro.core import build_engine, count_subgraphs_exact, get_template
from repro.graph import erdos_renyi
from repro.service import (CountingService, CountRequest, EngineCache,
                           EstimateCache, RequestStatus, RunningStat)


def _graph(n=30, deg=4.0, seed=0):
    return erdos_renyi(n, deg, seed=seed)


def _svc(tmp_path, name="svc", **kw):
    kw.setdefault("round_size", 8)
    kw.setdefault("default_max_iters", 64)
    return CountingService(ledger_root=str(tmp_path / name), **kw)


class TestRunningStat:
    def test_matches_numpy(self):
        xs = [3.0, 1.5, 4.25, -2.0, 7.5, 0.0]
        st = RunningStat()
        for x in xs:
            st.update(x)
        arr = np.asarray(xs)
        assert st.mean == pytest.approx(arr.mean())
        assert st.variance == pytest.approx(arr.var(ddof=1))
        assert st.stderr == pytest.approx(arr.std(ddof=1) / math.sqrt(len(xs)))
        lo, hi = st.ci95
        assert lo < st.mean < hi

    def test_degenerate_cases(self):
        st = RunningStat()
        assert st.rel_stderr == float("inf")
        st.update(0.0)
        st.update(0.0)
        # zero mean must not report a met target
        assert st.rel_stderr == float("inf")


class TestEngineCache:
    def test_hit_miss_and_content_keying(self, tmp_path):
        cache = EngineCache()
        g1 = _graph(seed=1)
        g2 = _graph(seed=1)     # same content, different object
        g3 = _graph(seed=2)     # different content
        e1 = cache.get(g1, "u3")
        assert cache.stats() == {"hits": 0, "misses": 1, "builds": 1,
                                 "evictions": 0, "resident": 1}
        assert cache.get(g2, "u3") is e1          # content hash, not identity
        assert cache.get(g1, "u3", plan="plain") is not e1
        assert cache.get(g3, "u3") is not e1
        assert cache.hits == 1 and cache.builds == 3

    def test_lru_eviction(self):
        cache = EngineCache(max_entries=2)
        g = _graph()
        e_u3 = cache.get(g, "u3")
        cache.get(g, "path4")
        cache.get(g, "u3")              # refresh u3
        cache.get(g, "u5")              # evicts u4 (least recent)
        assert len(cache) == 2
        assert cache.get(g, "u3") is e_u3
        cache.get(g, "path4")              # miss again -> rebuild
        assert cache.builds == 4

    def test_service_builds_once_for_repeats(self, tmp_path):
        svc = _svc(tmp_path)
        svc.add_graph("g", _graph())
        for _ in range(3):
            svc.submit(CountRequest("g", "u3", max_iters=4))
        svc.run()
        assert svc.engine_cache.stats()["builds"] == 1
        assert svc.stats()["groups"] == 1

    def test_idle_groups_release_engine_device_state(self, tmp_path):
        """Retired groups keep their sample history (late joiners) but must
        not pin device arrays of engines the bounded cache evicted; engines
        still cache-resident stay warm for repeated requests."""
        svc = _svc(tmp_path, engine_cache=EngineCache(max_entries=1))
        svc.add_graph("g", _graph())
        r1 = svc.submit(CountRequest("g", "u3", max_iters=4))
        svc.run()
        (grp_u3,) = svc._groups.values()
        # cache-resident: idle group must NOT release (warm repeats)
        assert not grp_u3.engine._released
        r2 = svc.submit(CountRequest("g", "path4", max_iters=4))
        svc.run()
        assert svc._requests[r2].status is RequestStatus.DONE
        # u3 engine was evicted by the 1-entry cache; its idle group must
        # not keep it resident
        assert grp_u3.engine._released
        # a late joiner to the idle group still gets a correct answer
        # (history serves the first 4 samples; the engine re-materializes
        # lazily for the 4 fresh iterations)
        r3 = svc.submit(CountRequest("g", "u3", max_iters=8))
        svc.run()
        assert svc.result(r3).iterations == 8
        assert svc.result(r1).estimate == pytest.approx(
            np.mean(grp_u3.history[:4]))


class TestEstimateCache:
    def test_persistent_roundtrip_serves_without_engine_build(self, tmp_path):
        cache_path = str(tmp_path / "estimates.json")
        g = _graph()
        svc1 = _svc(tmp_path, "a", estimate_cache=cache_path)
        svc1.add_graph("g", g)
        rid = svc1.submit(CountRequest("g", "u3", max_iters=8))
        first = svc1.run()[rid]
        assert os.path.isfile(cache_path)

        svc2 = _svc(tmp_path, "b", estimate_cache=cache_path)
        svc2.add_graph("other-name", g)   # keyed by content, not name
        rid2 = svc2.submit(CountRequest("other-name", "u3", max_iters=8))
        assert svc2.status(rid2) is RequestStatus.DONE
        res = svc2.result(rid2)
        assert res.from_cache
        assert res.estimate == first.estimate
        assert res.iterations == first.iterations
        assert svc2.engine_cache.stats()["builds"] == 0

    def test_insufficient_precision_is_a_miss(self, tmp_path):
        cache = EstimateCache()
        g = _graph()
        svc1 = _svc(tmp_path, "a", estimate_cache=cache)
        svc1.add_graph("g", g)
        rid = svc1.submit(CountRequest("g", "u3", max_iters=6))
        done = svc1.run()[rid]
        svc2 = _svc(tmp_path, "b", estimate_cache=cache)
        svc2.add_graph("g", g)
        # demands more iterations than cached -> must recompute
        rid2 = svc2.submit(CountRequest("g", "u3", max_iters=12))
        assert svc2.status(rid2) is RequestStatus.PENDING
        res = svc2.run()[rid2]
        assert not res.from_cache and res.iterations == 12
        # the tighter answer replaced the cached one
        assert done.iterations < 12 <= cache.get(list(
            cache._mem)[0])["iterations"]

    def test_min_iters_guard_applies_to_cache_hits(self, tmp_path):
        cache = EstimateCache()
        g = _graph()
        svc1 = _svc(tmp_path, "a", estimate_cache=cache)
        svc1.add_graph("g", g)
        # 2 lucky samples can cache a tiny rel_stderr...
        svc1.submit(CountRequest("g", "u3", max_iters=2))
        svc1.run()
        # ...but a request whose own guard demands >= 4 samples must not be
        # answered by that entry
        svc2 = _svc(tmp_path, "b", estimate_cache=cache)
        svc2.add_graph("g", g)
        rid = svc2.submit(CountRequest("g", "u3", rel_stderr=0.9,
                                       min_iters=4))
        assert svc2.status(rid) is RequestStatus.PENDING
        res = svc2.run()[rid]
        assert res.iterations >= 4 and not res.from_cache


class TestAdaptiveStopping:
    def test_tighter_target_runs_longer_same_stream(self, tmp_path):
        g = _graph(40, 4.0, seed=3)
        svc = _svc(tmp_path, round_size=16, default_max_iters=600)
        svc.add_graph("g", g)
        rid_loose = svc.submit(CountRequest("g", "u3", rel_stderr=0.2))
        rid_tight = svc.submit(CountRequest("g", "u3", rel_stderr=0.05))
        res = svc.run()
        loose, tight = res[rid_loose], res[rid_tight]
        assert loose.target_met and tight.target_met
        assert tight.rel_stderr <= 0.05
        assert tight.iterations > loose.iterations
        # both are prefix means of the same deterministic sample stream:
        # same estimator, different stopping points -> estimates agree in
        # expectation; check both against the exact count
        exact = count_subgraphs_exact(g, get_template("u3"))
        assert tight.estimate == pytest.approx(exact, rel=0.2)
        assert loose.estimate == pytest.approx(exact, rel=0.6)

    def test_estimate_is_prefix_mean_of_engine_samples(self, tmp_path):
        g = _graph(seed=4)
        svc = _svc(tmp_path)
        svc.add_graph("g", g)
        rid = svc.submit(CountRequest("g", "u3", rel_stderr=0.1, seed=5))
        res = svc.run()[rid]
        eng = build_engine(g, get_template("u3"), "pgbsc")
        est = eng.estimate(n_iters=res.iterations, seed=5)
        manual = np.asarray(est["samples"])
        assert res.estimate == pytest.approx(float(manual.mean()), rel=1e-6)
        want_se = float(manual.std(ddof=1)) / math.sqrt(len(manual))
        assert res.stderr == pytest.approx(want_se, rel=1e-6)
        assert res.stderr > 0.0

    def test_cap_bounds_adaptive_requests(self, tmp_path):
        # cap deliberately not a round_size multiple: the final round must
        # shrink to the remaining budget, not overshoot with wasted dispatch
        g = _graph(seed=6)
        svc = _svc(tmp_path, default_max_iters=12, round_size=8)
        svc.add_graph("g", g)
        # unreachable target -> runs to the cap, reported as target unmet
        rid = svc.submit(CountRequest("g", "u3", rel_stderr=1e-9))
        res = svc.run()[rid]
        assert res.iterations == 12
        assert not res.target_met
        assert svc.stats()["unique_iterations"] == 12


class TestGroupBatching:
    def test_shared_group_equals_solo_run_with_no_extra_device_work(
            self, tmp_path):
        g = _graph(36, 4.0, seed=7)
        req = dict(template="path4", rel_stderr=0.15, seed=2)

        solo_cache = EngineCache()
        solo = _svc(tmp_path, "solo", engine_cache=solo_cache)
        solo.add_graph("g", g)
        rid = solo.submit(CountRequest("g", **req))
        solo_res = solo.run()[rid]
        solo_eng = solo_cache.get(g, "path4")
        solo_cols = solo_eng.n_colorings_dispatched

        shared_cache = EngineCache()
        shared = _svc(tmp_path, "shared", engine_cache=shared_cache)
        shared.add_graph("g", g)
        rids = [shared.submit(CountRequest("g", **req)) for _ in range(3)]
        shared_res = shared.run()
        shared_eng = shared_cache.get(g, "path4")

        for r in rids:
            assert shared_res[r].estimate == solo_res.estimate
            assert shared_res[r].stderr == solo_res.stderr
            assert shared_res[r].iterations == solo_res.iterations
        # 3 tenants, 1 group, exactly the solo run's device work
        assert shared_eng.n_colorings_dispatched == solo_cols
        assert shared.stats()["groups"] == 1

    def test_different_seeds_do_not_share(self, tmp_path):
        svc = _svc(tmp_path)
        svc.add_graph("g", _graph())
        svc.submit(CountRequest("g", "u3", max_iters=4, seed=0))
        svc.submit(CountRequest("g", "u3", max_iters=4, seed=1))
        svc.run()
        assert svc.stats()["groups"] == 2
        # but one engine serves both groups
        assert svc.engine_cache.stats()["builds"] == 1


class TestLifecycleAndResume:
    def test_status_transitions_and_cancel(self, tmp_path):
        svc = _svc(tmp_path)
        svc.add_graph("g", _graph())
        rid = svc.submit(CountRequest("g", "u3", max_iters=32))
        dead = svc.submit(CountRequest("g", "path4", max_iters=32))
        assert svc.status(rid) is RequestStatus.PENDING
        svc.cancel(dead)
        assert svc.status(dead) is RequestStatus.CANCELLED
        svc.step()
        svc.run()
        assert svc.status(rid) is RequestStatus.DONE
        assert svc.status(dead) is RequestStatus.CANCELLED
        with pytest.raises(RuntimeError):
            svc.result(dead)

    def test_unknown_engine_fails_request_not_service(self, tmp_path):
        svc = _svc(tmp_path)
        svc.add_graph("g", _graph())
        bad = svc.submit(CountRequest("g", "u3", max_iters=4,
                                      engine="nonsense"))
        ok = svc.submit(CountRequest("g", "u3", max_iters=4))
        res = svc.run()
        assert svc.status(bad) is RequestStatus.FAILED
        assert bad not in res and ok in res

    def test_precision_contract_required(self, tmp_path):
        svc = _svc(tmp_path)
        svc.add_graph("g", _graph())
        with pytest.raises(ValueError):
            svc.submit(CountRequest("g", "u3"))
        with pytest.raises(KeyError):
            svc.submit(CountRequest("nograph", "u3", max_iters=4))

    def test_cancel_mid_dispatch_flushes_ledger_and_drains_group(
            self, tmp_path):
        """A cancel landing while a dispatch is in flight must not lose
        the dispatched samples (the ledger checkpoint still flushes; they
        serve future joiners) and must drain the group before the next
        round — not one round late."""
        g = _graph(seed=9)
        cache = EngineCache()
        eng = cache.get(g, "u3")
        inner = eng.count_iterations_batch
        dispatched: list[int] = []
        svc = CountingService(ledger_root=str(tmp_path / "led"),
                              engine_cache=cache, round_size=4)

        def spy(iterations, **kw):
            dispatched.extend(int(i) for i in iterations)
            svc.cancel(rid)          # lands while this dispatch is running
            return inner(iterations, **kw)

        eng.count_iterations_batch = spy
        svc.add_graph("g", g)
        rid = svc.submit(CountRequest("g", "u3", max_iters=12))
        svc.step()                   # dispatches one round; cancel mid-call
        assert svc.status(rid) is RequestStatus.CANCELLED
        assert dispatched == [0, 1, 2, 3]
        (grp,) = svc._groups.values()
        # in-flight samples were flushed to the ledger and group history
        assert sorted(grp.runner.completed_iterations()) == [0, 1, 2, 3]
        assert len(grp.history) == 4
        # the drained group never costs another device dispatch
        svc.step()
        svc.run()
        assert dispatched == [0, 1, 2, 3]
        # and a future joiner consumes the flushed samples for free
        r2 = svc.submit(CountRequest("g", "u3", max_iters=4))
        svc.run()
        assert svc.result(r2).iterations == 4
        assert dispatched == [0, 1, 2, 3]

    def test_resume_after_kill_reuses_ledger(self, tmp_path):
        g = _graph(seed=8)
        cache = EngineCache()
        eng = cache.get(g, "u3")
        fresh_ids: list[int] = []
        inner = eng.count_iterations_batch

        def spy(iterations, **kw):
            fresh_ids.extend(int(i) for i in iterations)
            return inner(iterations, **kw)

        eng.count_iterations_batch = spy
        ledger_root = str(tmp_path / "led")

        svc1 = CountingService(ledger_root=ledger_root, engine_cache=cache,
                               round_size=4)
        svc1.add_graph("g", g)
        svc1.submit(CountRequest("g", "u3", max_iters=12))
        svc1.step()          # one round = 4 iterations, then "killed"
        assert sorted(fresh_ids) == [0, 1, 2, 3]

        svc2 = CountingService(ledger_root=ledger_root, engine_cache=cache,
                               round_size=4)
        svc2.add_graph("g", g)
        rid = svc2.submit(CountRequest("g", "u3", max_iters=12))
        res = svc2.run()[rid]
        # the restarted service computed only the missing iterations
        assert sorted(fresh_ids) == list(range(12))
        assert res.iterations == 12

        # and matches a never-killed service exactly
        svc3 = _svc(tmp_path, "straight", engine_cache=EngineCache())
        svc3.add_graph("g", g)
        rid3 = svc3.submit(CountRequest("g", "u3", max_iters=12))
        straight = svc3.run()[rid3]
        assert res.estimate == straight.estimate
        assert res.stderr == straight.stderr
