"""HLO parser + roofline units, and validation of the dry-run artifacts
(reads results/dryrun JSONs — the compile sweep itself runs out-of-band via
`python -m repro.launch.dryrun`)."""

import json
import os

import pytest

from repro.analysis.hlo import collective_summary, count_ops
from repro.analysis.roofline import RooflineTerms, model_flops
from repro.configs import ARCH_IDS, get_config

_BASE = os.path.join(os.path.dirname(__file__), "..", "results")
RESULTS = (os.path.join(_BASE, "dryrun_final")
           if os.path.isdir(os.path.join(_BASE, "dryrun_final"))
           else os.path.join(_BASE, "dryrun"))

# Full-batch-giant / replicated-head / capacity-buffer cells documented as
# not fitting a single v5e chip (EXPERIMENTS.md §Memory-fit status)
_MEMORY_EXEMPT = {
    ("nequip", "ogb_products"), ("gatedgcn", "ogb_products"),
    ("pna", "ogb_products"), ("smollm-360m", "train_4k"),
    ("qwen3-moe-30b-a3b", "prefill_32k"),
}


class TestHloParser:
    def test_sync_forms(self):
        text = """
  %p = f32[2,8]{1,0} collective-permute(%a), source_target_pairs={{0,1}}
  %g = bf16[16,4]{1,0} all-gather(%b), replica_groups=[4,4]<=[16]
  %r = f32[128]{0} all-reduce(%c), replica_groups={{0,1}}, to_apply=%add
  %s = f32[4]{0} reduce-scatter(%d), replica_groups=[2,8]<=[16]
  %x = f32[9]{0} add(%a, %b)
"""
        s = collective_summary(text)
        assert s["collective-permute"]["bytes"] == 64
        assert s["all-gather"]["bytes"] == 128
        assert s["all-reduce"]["bytes"] == 512
        assert s["reduce-scatter"]["bytes"] == 4 * 4 * 8
        assert "add" not in s

    def test_async_tuple_counts_once(self):
        text = """
  %st = (f32[4]{0}, f32[16]{0}) all-gather-start(%a), replica_groups=[1,4]<=[4]
  %dn = f32[16]{0} all-gather-done(%st)
"""
        s = collective_summary(text)
        assert s["all-gather"]["count"] == 1
        assert s["all-gather"]["bytes"] == 64

    def test_count_ops(self):
        text = "%f = f32[8]{0} fusion(%a), kind=kLoop\n" \
               "%d = f32[8,8]{1,0} dot(%a, %b)\n"
        c = count_ops(text)
        assert c["fusion"] == 1 and c["dot"] == 1


class TestRooflineTerms:
    def test_dominance_and_bounds(self):
        t = RooflineTerms(flops=197e12, bytes_accessed=819e9,
                          collective_bytes=0, chips=1)
        assert t.compute_s == pytest.approx(1.0)
        assert t.memory_s == pytest.approx(1.0)
        assert t.step_time_s == pytest.approx(1.0)
        t2 = RooflineTerms(flops=1, bytes_accessed=1, collective_bytes=50e9,
                           chips=1)
        assert t2.dominant == "collective"

    def test_model_flops_sane(self):
        for arch_id in ARCH_IDS:
            arch = get_config(arch_id)
            for cell in arch.cells:
                mf = model_flops(arch, cell)
                assert mf > 0, (arch_id, cell.name)

    def test_moe_active_params_less_than_total(self):
        m = get_config("qwen3-moe-30b-a3b").model
        assert m.active_param_count() < m.param_count() / 5
        # ~30B total / ~3B active per the model card
        assert 25e9 < m.param_count() < 36e9
        assert 2e9 < m.active_param_count() < 4.5e9

    def test_llama3_param_count(self):
        m = get_config("llama3-8b").model
        assert 7.5e9 < m.param_count() < 8.6e9


@pytest.mark.skipif(not os.path.isdir(RESULTS),
                    reason="dry-run artifacts not present")
class TestDryrunArtifacts:
    def _records(self):
        recs = []
        for f in os.listdir(RESULTS):
            if f.endswith(".json"):
                with open(os.path.join(RESULTS, f)) as fh:
                    recs.append(json.load(fh))
        return recs

    def test_all_cells_present_and_ok(self):
        recs = self._records()
        seen = {(r["arch"], r["cell"], r["mesh"]) for r in recs}
        for arch_id in ARCH_IDS:
            for cell in get_config(arch_id).cells:
                for mesh in ("single", "multi"):
                    assert (arch_id, cell.name, mesh) in seen, \
                        (arch_id, cell.name, mesh)
        bad = [r for r in recs if not r.get("ok")]
        assert not bad, [(r["arch"], r["cell"], r["mesh"]) for r in bad]

    def test_roofline_terms_positive(self):
        for r in self._records():
            rf = r["roofline"]
            assert rf["flops"] > 0
            assert rf["bytes"] > 0
            assert rf["dominant"] in ("compute", "memory", "collective")

    def test_memory_fits_hbm(self):
        # v5e: 16 GiB HBM per chip; arguments+temp must fit (documented
        # full-batch-infeasible cells exempted — EXPERIMENTS.md §Memory-fit).
        # Allow 1.25x slack for XLA:CPU's pessimistic temp accounting.
        for r in self._records():
            if (r["arch"], r["cell"]) in _MEMORY_EXEMPT:
                continue
            m = r["memory"]
            if m["argument_bytes"] is None:
                continue
            total = (m["argument_bytes"] + (m["temp_bytes"] or 0))
            assert total < 16 * 2**30 * 1.25, \
                (r["arch"], r["cell"], r["mesh"], total / 2**30)
