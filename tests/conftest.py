import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def x64():
    """Enable float64 for one test; restores the previous setting."""
    import jax
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)
