"""Distributed PGBSC + fault-tolerant runner (8 simulated devices).

This module re-execs itself with XLA_FLAGS to get 8 host devices without
polluting the rest of the test session (jax locks device count at first init).
"""

import os
import subprocess
import sys

import pytest

_WORKER = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np
from repro.core import get_template, count_subgraphs_exact
from repro.core.colorsets import colorful_probability
from repro.core.distributed import DistributedPgbsc
from repro.core.runner import EstimatorRunner, distributed_counter
from repro.graph import erdos_renyi
from repro.launch.mesh import make_mesh

assert len(jax.devices()) == 8

g = erdos_renyi(90, 5.0, seed=4)
t = get_template("u5")
mesh = make_mesh((4, 2), ("data", "model"))

dist = DistributedPgbsc(g, t, mesh)
step, args, shardings = dist.count_step_fn()
out = np.asarray(jax.jit(step)(*args))
assert out.shape == (1,) and np.isfinite(out).all(), out

# multi-pod mesh: per-pod independent iterations
mesh3 = make_mesh((2, 2, 2), ("pod", "data", "model"))
dist3 = DistributedPgbsc(g, t, mesh3)
step3, args3, _ = dist3.count_step_fn()
out3 = np.asarray(jax.jit(step3)(*args3))
assert out3.shape == (2,) and np.isfinite(out3).all()

# determinism: same iteration ids -> same results
tot_a, per_a = dist3.count_iterations([0, 1, 2, 3], seed=5)
tot_b, per_b = dist3.count_iterations([0, 1, 2, 3], seed=5)
assert tot_a == tot_b and per_a == per_b

# mesh-shape independence: single-pod mesh reproduces multi-pod results
tot_c, per_c = dist.count_iterations([0, 1, 2, 3], seed=5)
assert per_a == per_c, (per_a, per_c)

# exact agreement with the single-device engine for the same coloring
from repro.core import build_engine
from repro.core.distributed import coloring_for_seed
eng = build_engine(g, t, "pgbsc")
it0_seed = 5 * 1_000_003 + 0
colors = np.asarray(coloring_for_seed(it0_seed, dist.n_pad, g.n, t.k))[:g.n]
want, _ = eng.count_colorful(colors)
assert float(want) == per_a[0], (float(want), per_a[0])

# estimator statistically matches the exact count
exact = count_subgraphs_exact(g, t)
total, per = dist3.count_iterations(list(range(64)), seed=3)
est = total / 64 / (t.automorphisms * colorful_probability(t.k))
rel = abs(est - exact) / exact
assert rel < 0.35, (est, exact, rel)

# ---- fault-tolerant runner: interrupt + resume == uninterrupted ----
import tempfile, shutil
tmp = tempfile.mkdtemp()
try:
    counter = distributed_counter(dist3, seed=3)
    r1 = EstimatorRunner(counter, k=t.k, automorphisms=t.automorphisms,
                         n_iterations=12, ledger_dir=tmp + "/a",
                         checkpoint_every=4, seed=3)
    partial = r1.run(max_iterations_this_call=5)   # simulated preemption
    assert len(partial.completed) >= 5
    r2 = EstimatorRunner(counter, k=t.k, automorphisms=t.automorphisms,
                         n_iterations=12, ledger_dir=tmp + "/a",
                         checkpoint_every=4, seed=3)
    resumed = r2.run()
    assert len(resumed.completed) == 12
    assert resumed.restarts >= 1

    r3 = EstimatorRunner(counter, k=t.k, automorphisms=t.automorphisms,
                         n_iterations=12, ledger_dir=tmp + "/b",
                         checkpoint_every=4, seed=3)
    straight = r3.run()
    assert abs(straight.count - resumed.count) < 1e-9, \
        (straight.count, resumed.count)

    # elastic scaling: finish remaining work on a *different* mesh
    r4 = EstimatorRunner(distributed_counter(dist, seed=3), k=t.k,
                         automorphisms=t.automorphisms, n_iterations=16,
                         ledger_dir=tmp + "/a", checkpoint_every=4, seed=3)
    elastic = r4.run()
    assert len(elastic.completed) == 16
finally:
    shutil.rmtree(tmp)

print("DISTRIBUTED-OK")
"""


@pytest.mark.slow
def test_distributed_pgbsc_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _WORKER], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "DISTRIBUTED-OK" in proc.stdout


_DDP_WORKER = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import reduced_config
from repro.data.synthetic import make_batch
from repro.optim.optimizer import AdamWConfig
from repro.train.ddp import build_ddp_step, init_ddp_state
from repro.train.step import concrete_train_state

arch = reduced_config("smollm-360m")
from repro.launch.mesh import make_mesh
mesh = make_mesh((8,), ("data",))
ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=30)

def run(compress):
    state0 = concrete_train_state(arch, jax.random.PRNGKey(0))
    state = init_ddp_state(state0["params"])
    step = jax.jit(build_ddp_step(arch, mesh, ocfg, compress=compress))
    losses = []
    for it in range(12):
        batch = make_batch(arch, "smoke_train",
                           jax.random.fold_in(jax.random.PRNGKey(5), it))
        # batch dim 2 -> tile to 8 for the 8-way data axis
        batch = jax.tree_util.tree_map(
            lambda x: jnp.tile(x, (4,) + (1,) * (x.ndim - 1)), batch)
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    return losses, state

l_plain, s_plain = run(False)
l_comp, s_comp = run(True)
assert l_plain[-1] < l_plain[0], l_plain
assert l_comp[-1] < l_comp[0], l_comp
# compressed training tracks uncompressed closely (error feedback)
assert abs(l_comp[-1] - l_plain[-1]) < 0.35 * abs(l_plain[0]), \
    (l_plain[-1], l_comp[-1])
print("DDP-OK")
"""


@pytest.mark.slow
def test_ddp_compressed_training_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _DDP_WORKER], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "DDP-OK" in proc.stdout
