"""Edge-list / npz-cache IO round-trips."""

import os

import numpy as np

from repro.graph import Graph, erdos_renyi
from repro.graph.io import (load_cached, load_edge_list, load_graph_npz,
                            save_edge_list, save_graph_npz)


def test_edge_list_roundtrip(tmp_path):
    g = erdos_renyi(60, 5.0, seed=2)
    p = str(tmp_path / "g.txt")
    save_edge_list(g, p)
    g2 = load_edge_list(p, n=g.n)
    assert g2.n == g.n and g2.m == g.m
    np.testing.assert_array_equal(g.indptr, g2.indptr)
    np.testing.assert_array_equal(g.indices, g2.indices)


def test_npz_roundtrip(tmp_path):
    g = erdos_renyi(50, 4.0, seed=3)
    p = str(tmp_path / "g.npz")
    save_graph_npz(g, p)
    g2 = load_graph_npz(p)
    assert g2.n == g.n
    np.testing.assert_array_equal(g.indices, g2.indices)


def test_cached_loader(tmp_path):
    g = erdos_renyi(40, 4.0, seed=4)
    p = str(tmp_path / "g.txt")
    save_edge_list(g, p)
    g1 = load_cached(p)
    cache = p + ".cache.npz"
    assert os.path.isfile(cache)
    mtime = os.path.getmtime(cache)
    g2 = load_cached(p)   # second load hits the cache
    assert os.path.getmtime(cache) == mtime
    np.testing.assert_array_equal(g1.indices, g2.indices)
    assert g1.m == g.m


def test_comments_and_blank_lines(tmp_path):
    p = str(tmp_path / "g.txt")
    with open(p, "w") as f:
        f.write("# header\n\n0 1\n1 2\n# trailing\n")
    g = load_edge_list(p)
    assert g.n == 3 and g.m == 4
