"""Edge-list / npz-cache IO round-trips."""

import os

import numpy as np

from repro.graph import erdos_renyi
from repro.graph.io import (load_cached, load_edge_list, load_graph_npz,
                            save_edge_list, save_graph_npz)


def test_edge_list_roundtrip(tmp_path):
    g = erdos_renyi(60, 5.0, seed=2)
    p = str(tmp_path / "g.txt")
    save_edge_list(g, p)
    g2 = load_edge_list(p, n=g.n)
    assert g2.n == g.n and g2.m == g.m
    np.testing.assert_array_equal(g.indptr, g2.indptr)
    np.testing.assert_array_equal(g.indices, g2.indices)


def test_npz_roundtrip(tmp_path):
    g = erdos_renyi(50, 4.0, seed=3)
    p = str(tmp_path / "g.npz")
    save_graph_npz(g, p)
    g2 = load_graph_npz(p)
    assert g2.n == g.n
    np.testing.assert_array_equal(g.indices, g2.indices)


def test_cached_loader(tmp_path):
    g = erdos_renyi(40, 4.0, seed=4)
    p = str(tmp_path / "g.txt")
    save_edge_list(g, p)
    g1 = load_cached(p)
    cache = p + ".cache.npz"
    assert os.path.isfile(cache)
    mtime = os.path.getmtime(cache)
    g2 = load_cached(p)   # second load hits the cache
    assert os.path.getmtime(cache) == mtime
    np.testing.assert_array_equal(g1.indices, g2.indices)
    assert g1.m == g.m


def test_comments_and_blank_lines(tmp_path):
    p = str(tmp_path / "g.txt")
    with open(p, "w") as f:
        f.write("# header\n\n0 1\n1 2\n# trailing\n")
    g = load_edge_list(p)
    assert g.n == 3 and g.m == 4


def test_fingerprint_is_content_identity():
    g1 = erdos_renyi(40, 4.0, seed=5)
    g2 = erdos_renyi(40, 4.0, seed=5)   # same content, fresh arrays
    g3 = erdos_renyi(40, 4.0, seed=6)
    assert g1.fingerprint == g2.fingerprint
    assert g1.fingerprint != g3.fingerprint
    assert len(g1.fingerprint) == 32
    # padding changes vertex count -> different identity
    assert g1.padded(64).fingerprint != g1.fingerprint


def test_cached_loader_invalidates_on_source_rewrite(tmp_path):
    g = erdos_renyi(40, 4.0, seed=4)
    p = str(tmp_path / "g.txt")
    save_edge_list(g, p)
    assert load_cached(p).fingerprint == g.fingerprint

    # rewrite the source with a different graph but force the cache file's
    # mtime to stay newer — mtime ordering alone would (wrongly) keep it
    g2 = erdos_renyi(40, 4.0, seed=7)
    save_edge_list(g2, p)
    cache = p + ".cache.npz"
    os.utime(cache, (os.path.getmtime(p) + 100,) * 2)
    assert load_cached(p).fingerprint == g2.fingerprint

    # and a cache refreshed from the new source is reused, not rebuilt
    mtime = os.path.getmtime(cache)
    assert load_cached(p).fingerprint == g2.fingerprint
    assert os.path.getmtime(cache) == mtime


def test_cached_loader_rebuilds_corrupt_cache(tmp_path):
    g = erdos_renyi(30, 3.0, seed=2)
    p = str(tmp_path / "g.txt")
    save_edge_list(g, p)
    cache = p + ".cache.npz"
    with open(cache, "wb") as f:          # truncated/garbage "cache"
        f.write(b"PK\x03\x04 not a real zip")
    os.utime(cache, (os.path.getmtime(p) + 100,) * 2)
    assert load_cached(p).fingerprint == g.fingerprint
    # and it was replaced with a valid cache
    assert load_graph_npz(cache).fingerprint == g.fingerprint


def test_npz_records_fingerprint_and_source(tmp_path):
    g = erdos_renyi(30, 3.0, seed=1)
    src = str(tmp_path / "g.txt")
    save_edge_list(g, src)
    p = str(tmp_path / "g.npz")
    save_graph_npz(g, p, source=src)
    z = np.load(p)
    assert str(z["fingerprint"]) == g.fingerprint
    assert int(z["src_size"]) == os.path.getsize(src)
    assert load_graph_npz(p).fingerprint == g.fingerprint
