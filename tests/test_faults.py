"""Chaos suite: the failure-containment subsystem under injected faults.

Three layers of assertions:

* **primitives** — the fault harness is deterministic, the watchdog
  abandons hung work, the ladder/breaker state machines transition as
  documented, corrupt state files quarantine instead of raising;
* **containment** — injected dispatch/build/loop/handler faults never
  orphan a request (every admitted request reaches a terminal status)
  and never deadlock the service;
* **invariance** — requests that survive chaos produce *bitwise-identical*
  estimates to a clean run, because samples are pure functions of
  ``(seed, iteration id)`` and containment only ever re-runs them.
"""

import json
import os
import threading
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.graph.structure import Graph
from repro.obs import metrics as _metrics
from repro.resilience import faults, recovery
from repro.resilience.degradation import (BreakerBoard, CircuitBreaker,
                                          DegradationState)
from repro.resilience.retry import (DispatchTimeout, RetryPolicy,
                                    run_with_timeout)
from repro.service.async_loop import (TERMINAL_STATUSES,
                                      AsyncCountingService)
from repro.service.cache import EstimateCache
from repro.service.requests import CountRequest, RequestStatus
from repro.service.scheduler import CountingService


@pytest.fixture(autouse=True)
def _no_leftover_plan():
    """A test that dies mid-chaos must not poison the rest of the run."""
    yield
    faults.clear_plan()


@pytest.fixture(scope="module")
def graph():
    rng = np.random.default_rng(0)
    n = 48
    edges = set()
    for _ in range(140):
        a, b = rng.integers(0, n, 2)
        if a != b:
            edges.add((min(a, b), max(a, b)))
    return Graph.from_edges(n, sorted(edges))


def _service(tmp_path, **kw):
    kw.setdefault("round_size", 4)
    kw.setdefault("default_max_iters", 8)
    kw.setdefault("ledger_root", str(tmp_path / "ledgers"))
    return CountingService(**kw)


def _run_one(svc, graph, template="path3", **req_kw):
    svc.add_graph("g", graph)
    req_kw.setdefault("max_iters", 8)
    rid = svc.submit(CountRequest("g", template, **req_kw))
    svc.run()
    return rid, svc._requests[rid]


def _counter_total(prefix: str) -> float:
    snap = _metrics.snapshot()
    return sum(v for k, v in snap["counters"].items()
               if k.split("{")[0] == prefix)


# ---------------------------------------------------------------- harness
class TestHarness:
    def test_same_seed_same_schedule(self):
        fires = []
        for _ in range(2):
            plan = faults.FaultPlan.parse("kernel.dispatch:raise:0.5",
                                          seed=42)
            pattern = []
            with faults.active_plan(plan):
                for _ in range(40):
                    try:
                        faults.inject("kernel.dispatch")
                        pattern.append(0)
                    except faults.InjectedFault:
                        pattern.append(1)
            fires.append(pattern)
        assert fires[0] == fires[1]
        assert 0 < sum(fires[0]) < 40        # rate actually partial

    def test_times_budget_and_after(self):
        plan = faults.FaultPlan(
            [faults.FaultSpec("kernel.dispatch", times=2, after=1)])
        raised = []
        with faults.active_plan(plan):
            for _ in range(6):
                try:
                    faults.inject("kernel.dispatch")
                    raised.append(0)
                except faults.InjectedFault:
                    raised.append(1)
        # first hit skipped, then exactly `times` firings
        assert raised == [0, 1, 1, 0, 0, 0]

    def test_match_scopes_to_one_context(self):
        plan = faults.FaultPlan(
            [faults.FaultSpec("kernel.dispatch", match="poison")])
        with faults.active_plan(plan):
            faults.inject("kernel.dispatch", context="healthy-group")
            with pytest.raises(faults.InjectedFault):
                faults.inject("kernel.dispatch", context="poison-group")

    def test_no_plan_is_noop(self):
        faults.clear_plan()
        faults.inject("kernel.dispatch")     # must not raise

    def test_parse_rejects_unknown_point_and_mode(self):
        with pytest.raises(ValueError):
            faults.FaultPlan.parse("not.a.point:raise")
        with pytest.raises(ValueError):
            faults.FaultPlan.parse("kernel.dispatch:explode")

    def test_parse_json_file(self, tmp_path):
        p = tmp_path / "plan.json"
        p.write_text(json.dumps({"seed": 9, "faults": [
            {"point": "ledger.write", "mode": "corrupt", "rate": 1.0}]}))
        plan = faults.FaultPlan.parse(str(p))
        assert plan.seed == 9
        assert plan.specs[0].mode == "corrupt"

    def test_corrupt_bytes_truncates_deterministically(self):
        payload = b"x" * 256
        cuts = []
        for _ in range(2):
            plan = faults.FaultPlan(
                [faults.FaultSpec("ledger.write", mode="corrupt")], seed=5)
            with faults.active_plan(plan):
                cuts.append(faults.corrupt_bytes("ledger.write", payload))
        assert cuts[0] == cuts[1]
        assert 0 < len(cuts[0]) < len(payload)

    def test_injected_fault_is_plain_runtime_error(self):
        # containment code must not (and cannot meaningfully) special-case
        assert issubclass(faults.InjectedFault, RuntimeError)


# ----------------------------------------------------------- retry/watchdog
class TestWatchdog:
    def test_no_timeout_runs_inline(self):
        assert run_with_timeout(lambda c: 7, None) == 7
        assert threading.active_count() < 50

    def test_timeout_abandons_hung_worker(self):
        woke = threading.Event()

        def hang(cancelled):
            time.sleep(1.5)
            if cancelled.is_set():
                woke.set()               # abandoned worker: no side effects
                return None
            return "too late"

        t0 = time.monotonic()
        with pytest.raises(DispatchTimeout):
            run_with_timeout(hang, 0.2, name="test")
        assert time.monotonic() - t0 < 1.0   # did not wait the full sleep
        assert woke.wait(3.0)                # worker saw its cancel flag

    def test_worker_exception_reraises(self):
        with pytest.raises(KeyError):
            run_with_timeout(lambda c: {}["missing"], 1.0)

    def test_backoff_shape(self):
        pol = RetryPolicy(base_delay_s=0.1, max_delay_s=0.5, jitter=0.0)
        assert [pol.delay(a) for a in (1, 2, 3, 4)] == \
            [0.1, 0.2, 0.4, 0.5]
        with pytest.raises(ValueError):
            RetryPolicy(max_attempts=0)


# -------------------------------------------------------- ladder / breaker
class TestDegradation:
    def test_ladder_steps_and_applies(self):
        clk = [0.0]
        lad = DegradationState(step_after=2, cooldown_s=10.0,
                               clock=lambda: clk[0])
        base = {"fuse_spmm_ema": True, "autotune_blocks": True,
                "spmm_method": "bsr"}
        assert lad.apply(base) == base           # level 0: untouched
        assert not lad.on_failure()
        assert lad.on_failure()                  # 2nd consecutive: step
        assert lad.level_name == "unfused"
        kw = lad.apply(base)
        assert "fuse_spmm_ema" not in kw and "autotune_blocks" not in kw
        assert lad.on_failure() is False and lad.on_failure()
        assert lad.level_name == "xla"
        assert lad.apply(base)["spmm_method"] == "segment"

    def test_ladder_promotes_one_rung_per_cooldown(self):
        clk = [0.0]
        lad = DegradationState(step_after=1, cooldown_s=5.0,
                               clock=lambda: clk[0])
        lad.on_failure(); lad.on_failure()
        assert lad.level == 2
        assert not lad.maybe_promote()           # cooldown not elapsed
        clk[0] = 6.0
        assert lad.maybe_promote() and lad.level == 1
        assert not lad.maybe_promote()           # one rung per cooldown
        clk[0] = 12.0
        assert lad.maybe_promote() and lad.level == 0

    def test_breaker_state_machine(self):
        clk = [0.0]
        br = CircuitBreaker(threshold=2, cooldown_s=5.0,
                            clock=lambda: clk[0])
        assert br.allow()
        br.on_failure()
        assert br.state == br.CLOSED and br.allow()
        br.on_failure()
        assert br.state == br.OPEN and not br.allow()
        clk[0] = 6.0
        assert br.allow()                        # half-open trial admitted
        assert br.state == br.HALF_OPEN and not br.allow()
        br.on_success()
        assert br.state == br.CLOSED and br.allow()

    def test_board_snapshot_reports_unhealthy(self):
        board = BreakerBoard(threshold=1, cooldown_s=60.0)
        board.get(("k",), label="grp").on_failure()
        snap = board.snapshot()
        assert snap["counts"]["open"] == 1
        assert snap["unhealthy"]["grp"]["state"] == "open"


# ---------------------------------------------------------------- recovery
class TestRecovery:
    def test_roundtrip(self, tmp_path):
        p = str(tmp_path / "state.json")
        recovery.write_checked(p, {"a": 1})
        payload, status = recovery.load_checked(p, kind="t")
        assert (payload, status) == ({"a": 1}, "ok")

    def test_missing_is_clean_cold_start(self, tmp_path):
        payload, status = recovery.load_checked(
            str(tmp_path / "nope.json"), kind="t")
        assert payload is None and status == "missing"

    @pytest.mark.parametrize("content,reason", [
        (b"{\"envelope\": 1, \"crc\": 0, \"payl", "json"),   # torn write
        (b"\x00\x01garbage", "json"),
        (b"[1, 2, 3]", "schema"),
        (b"{\"envelope\": 1, \"crc\": 123, \"payload\": {}}", "crc"),
    ])
    def test_bad_state_quarantined_not_raised(self, tmp_path,
                                              content, reason):
        p = tmp_path / "state.json"
        p.write_bytes(content)
        payload, status = recovery.load_checked(str(p), kind="t")
        assert payload is None and status == reason
        assert not p.exists()                    # moved aside...
        assert p.with_suffix(".json.corrupt").exists()   # ...as evidence

    def test_legacy_pre_envelope_dict_loads(self, tmp_path):
        p = tmp_path / "old.json"
        p.write_text(json.dumps({"completed": {"0": 1.0}, "seed": 0}))
        payload, status = recovery.load_checked(str(p), kind="t")
        assert status == "ok" and payload["completed"] == {"0": 1.0}

    def test_injected_corrupt_write_quarantines_on_next_load(self,
                                                             tmp_path):
        p = str(tmp_path / "state.json")
        plan = faults.FaultPlan(
            [faults.FaultSpec("ledger.write", mode="corrupt", times=1)])
        with faults.active_plan(plan):
            recovery.write_checked(p, {"a": 1}, fault_point="ledger.write")
        payload, status = recovery.load_checked(p, kind="t")
        assert payload is None and status == "json"


# --------------------------------------------------- state-file containment
class TestStateContainment:
    def test_torn_ledger_restarts_cold(self, tmp_path, graph):
        """A ledger torn mid-checkpoint must cost recomputation, never a
        crash — and the recomputed estimate is bitwise-identical."""
        clean = _service(tmp_path / "clean")
        _, st_clean = _run_one(clean, graph)
        base = st_clean.result.estimate

        root = tmp_path / "torn"
        plan = faults.FaultPlan(
            [faults.FaultSpec("ledger.write", mode="corrupt", after=1,
                              times=1)], seed=3)
        with faults.active_plan(plan):
            svc = _service(root)
            _, st = _run_one(svc, graph)
        assert st.result.estimate == base
        # the torn file is found (and quarantined) by the next process
        svc2 = _service(root)
        _, st2 = _run_one(svc2, graph)
        assert st2.result.estimate == base
        corrupt = [f for _, _, fs in os.walk(root / "ledgers") for f in fs
                   if f.endswith(".corrupt")]
        assert corrupt, "torn ledger was not quarantined"

    def test_garbage_estimate_cache_starts_cold(self, tmp_path):
        p = tmp_path / "estimates.json"
        p.write_bytes(b"\x00not json at all")
        cache = EstimateCache(str(p))
        assert cache.get("anything") is None     # no raise, cold start
        assert p.with_suffix(".json.corrupt").exists()
        cache.put("k", {"estimate": 1.0, "stderr": 0.1,
                        "rel_stderr": 0.1, "iterations": 4})
        assert EstimateCache(str(p)).get("k")["estimate"] == 1.0

    def test_truncated_estimate_cache_starts_cold(self, tmp_path):
        p = tmp_path / "estimates.json"
        cache = EstimateCache(str(p))
        cache.put("k", {"estimate": 1.0, "stderr": 0.1,
                        "rel_stderr": 0.1, "iterations": 4})
        p.write_bytes(p.read_bytes()[:10])       # torn write
        assert EstimateCache(str(p)).get("k") is None


# ------------------------------------------------------- scheduler containment
class TestSchedulerChaos:
    def test_retried_dispatch_is_bitwise_identical(self, tmp_path, graph):
        clean = _service(tmp_path / "clean")
        _, st_clean = _run_one(clean, graph)
        base = st_clean.result.estimate

        plan = faults.FaultPlan.parse("kernel.dispatch:raise:1.0:2", seed=7)
        before = _counter_total("dispatch_retries_total")
        with faults.active_plan(plan):
            svc = _service(tmp_path / "chaos")
            _, st = _run_one(svc, graph)
        assert st.status is RequestStatus.DONE
        assert st.result.estimate == base
        assert plan.stats()["kernel.dispatch:raise"]["fired"] == 2
        assert _counter_total("dispatch_retries_total") > before

    def test_exhausted_budget_fails_with_structured_error(self, tmp_path,
                                                          graph):
        plan = faults.FaultPlan.parse("kernel.dispatch:raise:1.0", seed=7)
        with faults.active_plan(plan):
            svc = _service(tmp_path / "x",
                           retry_policy=RetryPolicy(max_attempts=2,
                                                    base_delay_s=0.01))
            _, st = _run_one(svc, graph)
        assert st.status is RequestStatus.FAILED
        assert st.error_class == "InjectedFault"
        assert "kernel.dispatch" in st.error

    def test_ladder_steps_down_under_repeated_failure(self, tmp_path,
                                                      graph):
        plan = faults.FaultPlan.parse("kernel.dispatch:raise:1.0:2", seed=7)
        with faults.active_plan(plan):
            svc = _service(tmp_path / "lad", degrade_after=2)
            _, st = _run_one(svc, graph)
        assert st.status is RequestStatus.DONE
        state = svc.resilience_state()
        assert state["degraded_ladders"], "ladder never stepped"
        (snap,) = state["degraded_ladders"].values()
        assert snap["level_name"] == "unfused"

    def test_breaker_quarantines_poison_group(self, tmp_path, graph):
        plan = faults.FaultPlan.parse("kernel.dispatch:raise:1.0", seed=7)
        svc = _service(tmp_path / "br",
                       retry_policy=RetryPolicy(max_attempts=1),
                       breaker_threshold=2, breaker_cooldown_s=300.0)
        svc.add_graph("g", graph)
        with faults.active_plan(plan):
            statuses = []
            for _ in range(3):
                rid = svc.submit(CountRequest("g", "path3", max_iters=8))
                svc.run()
                st = svc._requests[rid]
                statuses.append(st.error_class)
        # two real failures open the circuit; the third fails *fast*
        assert statuses[:2] == ["InjectedFault", "InjectedFault"]
        assert statuses[2] == "CircuitOpen"
        assert svc.resilience_state()["breakers"]["counts"]["open"] == 1
        # rewind the open timestamp (= cooldown elapsed): a clean
        # half-open trial dispatch closes the circuit
        (br,) = svc._breakers._breakers.values()
        br._opened_at -= 600.0
        rid = svc.submit(CountRequest("g", "path3", max_iters=8))
        svc.run()
        assert svc._requests[rid].status is RequestStatus.DONE
        assert svc.resilience_state()["breakers"]["counts"]["closed"] == 1

    def test_hung_dispatch_caught_by_watchdog(self, tmp_path, graph):
        clean = _service(tmp_path / "clean")
        _, st_clean = _run_one(clean, graph)
        base = st_clean.result.estimate

        plan = faults.FaultPlan(
            [faults.FaultSpec("dispatch.hang", mode="hang", hang_s=5.0,
                              times=1)], seed=1)
        with faults.active_plan(plan):
            svc = _service(tmp_path / "hang",
                           retry_policy=RetryPolicy(max_attempts=3,
                                                    base_delay_s=0.01,
                                                    timeout_s=0.5))
            t0 = time.monotonic()
            _, st = _run_one(svc, graph)
        assert st.status is RequestStatus.DONE
        assert st.result.estimate == base
        assert time.monotonic() - t0 < 5.0       # did not sit out the hang

    def test_unaffected_group_untouched_by_scoped_chaos(self, tmp_path,
                                                        graph):
        clean = _service(tmp_path / "clean")
        clean.add_graph("g", graph)
        r1 = clean.submit(CountRequest("g", "path3", max_iters=8))
        r2 = clean.submit(CountRequest("g", "star4", max_iters=8))
        clean.run()
        base3 = clean._requests[r1].result.estimate
        base_s = clean._requests[r2].result.estimate

        svc = _service(tmp_path / "scoped",
                       retry_policy=RetryPolicy(max_attempts=1))
        svc.add_graph("g", graph)
        # poison only the path3 group (match on its template hash prefix)
        from repro.core.templates import TemplateSpec
        h3 = TemplateSpec.of("path3").canonical_hash[:8]
        plan = faults.FaultPlan([faults.FaultSpec(
            "kernel.dispatch", match=h3)], seed=7)
        with faults.active_plan(plan):
            r1 = svc.submit(CountRequest("g", "path3", max_iters=8))
            r2 = svc.submit(CountRequest("g", "star4", max_iters=8))
            svc.run()
        assert svc._requests[r1].status is RequestStatus.FAILED
        assert svc._requests[r2].status is RequestStatus.DONE
        assert svc._requests[r2].result.estimate == base_s
        assert plan.stats()["kernel.dispatch:raise"]["fired"] >= 1
        assert base3 != base_s                   # the two groups differ


# ---------------------------------------------------------- async containment
class TestAsyncChaos:
    def _async(self, tmp_path, **kw):
        kw.setdefault("round_size", 4)
        kw.setdefault("default_max_iters", 8)
        kw.setdefault("idle_wait_s", 0.01)
        kw.setdefault("warm_pool", False)
        kw.setdefault("ledger_root", str(tmp_path / "ledgers"))
        return AsyncCountingService(**kw)

    def test_dispatcher_crash_restarts_and_finishes(self, tmp_path, graph):
        sync = _service(tmp_path / "sync")
        _, st_sync = _run_one(sync, graph)
        base = st_sync.result.estimate

        plan = faults.FaultPlan.parse("dispatch.loop:raise:1.0:2", seed=3)
        with faults.active_plan(plan):
            svc = self._async(tmp_path / "crash")
            svc.add_graph("g", graph)
            with svc:
                rid = svc.submit(CountRequest("g", "path3", max_iters=8))
                assert svc.wait([rid], timeout=90)
                res = svc.result(rid)
        assert res.estimate == base
        assert svc.stats()["dispatcher_crashes"] == 2

    def test_restart_budget_exhaustion_orphans_nothing(self, tmp_path,
                                                       graph):
        plan = faults.FaultPlan.parse("dispatch.loop:raise:1.0", seed=3)
        with faults.active_plan(plan):
            svc = self._async(tmp_path / "dead", max_dispatcher_restarts=2)
            svc.add_graph("g", graph)
            with svc:
                rids = [svc.submit(CountRequest("g", "path3", max_iters=8))
                        for _ in range(3)]
                assert svc.wait(rids, timeout=30)
            for rid in rids:
                st = svc._requests[rid]
                assert st.status in TERMINAL_STATUSES
                if st.status is RequestStatus.FAILED:
                    assert st.error_class == "DispatcherDead"
            # the dead service sheds instead of silently queueing
            rid = svc.submit(CountRequest("g", "path3", max_iters=8))
            assert svc._requests[rid].status in TERMINAL_STATUSES
        assert not svc.resilience_state()["dispatcher"]["alive"]

    def test_mixed_chaos_every_request_terminal(self, tmp_path, graph):
        """The headline containment contract: a request admitted under
        multi-point chaos always reaches a terminal status — and every
        DONE answer matches the clean run bitwise."""
        sync = _service(tmp_path / "sync")
        sync.add_graph("g", graph)
        base = {}
        for tpl in ("path3", "star3"):
            r = sync.submit(CountRequest("g", tpl, max_iters=8, seed=1))
            sync.run()
            base[tpl] = sync._requests[r].result.estimate

        plan = faults.FaultPlan.parse(
            "kernel.dispatch:raise:0.25,engine.build:raise:0.3:2,"
            "dispatch.loop:raise:1.0:1,http.handler:raise:0.5:2", seed=13)
        with faults.active_plan(plan):
            svc = self._async(tmp_path / "mixed", degrade_after=1,
                              retry_policy=RetryPolicy(
                                  max_attempts=3, base_delay_s=0.01,
                                  timeout_s=30.0))
            svc.add_graph("g", graph)
            with svc:
                rids = {}
                for i in range(8):
                    tpl = ("path3", "star3")[i % 2]
                    rids[svc.submit(CountRequest(
                        "g", tpl, max_iters=8, seed=1))] = tpl
                assert svc.wait(list(rids), timeout=120), \
                    "chaos deadlocked the service"
        for rid, tpl in rids.items():
            st = svc._requests[rid]
            assert st.status in TERMINAL_STATUSES, f"{rid} orphaned"
            if st.status is RequestStatus.DONE and not st.from_cache:
                assert st.result.estimate == base[tpl]
        fired = sum(v["fired"] for v in plan.stats().values())
        assert fired > 0, "chaos plan never fired — test is vacuous"


# -------------------------------------------------------------- HTTP hardening
class TestHttpContainment:
    def test_structured_500_and_resilient_healthz(self, tmp_path, graph):
        from repro.service.frontend import serve_forever

        svc = AsyncCountingService(
            round_size=4, default_max_iters=8, idle_wait_s=0.01,
            warm_pool=False, ledger_root=str(tmp_path / "ledgers"))
        svc.add_graph("g", graph)
        httpd = serve_forever(svc, port=0)
        port = httpd.server_address[1]
        base = f"http://127.0.0.1:{port}"
        try:
            plan = faults.FaultPlan(
                [faults.FaultSpec("http.handler", match="POST", times=1)],
                seed=2)
            with faults.active_plan(plan):
                req = urllib.request.Request(
                    f"{base}/count",
                    data=json.dumps({"graph": "g", "templates": ["path3"],
                                     "max_iters": 8}).encode(),
                    headers={"Content-Type": "application/json"})
                with pytest.raises(urllib.error.HTTPError) as ei:
                    urllib.request.urlopen(req, timeout=30)
                assert ei.value.code == 500
                body = json.loads(ei.value.read())
                assert body["error_class"] == "InjectedFault"
                assert body["request_id"].startswith("h")
                # the pool survived: same request now succeeds
                with urllib.request.urlopen(
                        urllib.request.Request(
                            f"{base}/count", data=req.data,
                            headers=dict(req.headers)), timeout=60) as r:
                    assert r.status == 200
            with urllib.request.urlopen(f"{base}/healthz", timeout=10) as r:
                h = json.loads(r.read())
            assert h["ok"] is True
            assert h["resilience"]["dispatcher"]["alive"] is True
            assert "breakers" in h["resilience"]
            assert "degraded_ladders" in h["resilience"]
        finally:
            httpd.shutdown()
            svc.close()

    def test_wait_clamped_by_server_budget(self, tmp_path, graph):
        from repro.service.frontend import make_server
        import threading as th

        svc = AsyncCountingService(
            round_size=4, default_max_iters=8, idle_wait_s=0.01,
            warm_pool=False, ledger_root=str(tmp_path / "ledgers"))
        svc.add_graph("g", graph)
        svc.start()
        httpd = make_server(svc, port=0, max_wait_s=0.2)
        th.Thread(target=httpd.serve_forever, daemon=True).start()
        port = httpd.server_address[1]
        # hang every dispatch: without the clamp this request would park
        # the handler thread for the client's full 600s ask
        plan = faults.FaultPlan(
            [faults.FaultSpec("dispatch.hang", mode="hang", hang_s=60.0)],
            seed=2)
        try:
            with faults.active_plan(plan):
                req = urllib.request.Request(
                    f"http://127.0.0.1:{port}/count",
                    data=json.dumps({"graph": "g", "templates": ["path3"],
                                     "max_iters": 8,
                                     "timeout_s": 600}).encode(),
                    headers={"Content-Type": "application/json"})
                t0 = time.monotonic()
                with urllib.request.urlopen(req, timeout=30) as r:
                    assert r.status == 202       # accepted, not finished
                assert time.monotonic() - t0 < 10.0
        finally:
            httpd.shutdown()
            svc.close(timeout=1.0)


# ------------------------------------------------------------- metrics audit
def test_containment_metrics_are_labeled(tmp_path, graph):
    """Every fault class fired in a chaos run is accounted for by a
    labeled containment metric (the ISSUE acceptance criterion)."""
    plan = faults.FaultPlan.parse("kernel.dispatch:raise:1.0:2", seed=7)
    before_inj = _counter_total("fault_injections_total")
    before_ret = _counter_total("dispatch_retries_total")
    with faults.active_plan(plan):
        svc = _service(tmp_path / "m")
        _, st = _run_one(svc, graph)
    assert st.status is RequestStatus.DONE
    snap = _metrics.snapshot()
    inj = {k: v for k, v in snap["counters"].items()
           if k.startswith("fault_injections_total")}
    assert any("kernel.dispatch" in k for k in inj)
    assert _counter_total("fault_injections_total") - before_inj == 2
    assert _counter_total("dispatch_retries_total") - before_ret >= 1
    assert any(k.startswith("degradation_level") for k in snap["gauges"])
