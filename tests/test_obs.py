"""Observability subsystem: tracing, metrics, export, and the memory-model
watermark validation.

The obs layer is pure stdlib, so most tests run with no device work; the
watermark and kernel-counter tests drive real engines/kernels to check the
instrumentation fires on the paths it claims to cover.
"""

import json
import time

import numpy as np
import pytest

from repro.core import build_engine
from repro.graph import erdos_renyi
from repro.obs import metrics, tracing
from repro.obs.validate import validate_snapshot
from repro.service import CountingService, CountRequest, EstimateCache


@pytest.fixture
def tracer():
    """Fresh enabled tracer for one test; restores the disabled default."""
    t = tracing.set_tracer(tracing.Tracer(enabled=True))
    yield t
    tracing.set_tracer(tracing.Tracer(enabled=False))


@pytest.fixture
def registry():
    """Fresh registry for one test; restores a clean default after."""
    r = metrics.set_registry(metrics.MetricsRegistry())
    yield r
    metrics.set_registry(metrics.MetricsRegistry())


def _graph(n=30, deg=4.0, seed=0):
    return erdos_renyi(n, deg, seed=seed)


# --------------------------------------------------------------- tracing
class TestTracing:
    def test_nesting_and_timing(self, tracer):
        with tracing.span("outer", kind="test") as outer:
            time.sleep(0.002)
            with tracing.span("inner") as inner:
                time.sleep(0.002)
            inner2 = tracing.span("inner")
            with inner2:
                pass
        assert len(tracer.roots) == 1
        root = tracer.roots[0]
        assert root is outer
        assert [c.name for c in root.children] == ["inner", "inner"]
        assert root.children[0] is inner and root.children[1] is inner2
        assert root.seconds >= inner.seconds >= 0.002
        assert root.attrs == {"kind": "test"}
        d = root.to_dict()
        assert d["name"] == "outer" and len(d["children"]) == 2

    def test_set_attrs_mid_span(self, tracer):
        with tracing.span("s") as sp:
            sp.set(result=7)
        assert tracer.roots[0].attrs["result"] == 7

    def test_breakdown_aggregates(self, tracer):
        for _ in range(3):
            with tracing.span("a"):
                with tracing.span("b"):
                    pass
        agg = tracer.breakdown()
        assert agg["a"]["count"] == 3 and agg["b"]["count"] == 3
        assert agg["a"]["seconds"] >= agg["b"]["seconds"] >= 0.0

    def test_disabled_is_shared_noop(self):
        assert not tracing.enabled()
        s1 = tracing.span("x", a=1)
        s2 = tracing.span("y")
        assert s1 is s2                     # one shared null span
        with s1 as got:
            assert got.set(z=3) is got
        assert tracing.get_tracer().roots == []

    def test_disabled_overhead_bound(self):
        """50k disabled spans must stay well under half a second — the
        micro-scale version of the <2% bench_engines regression budget."""
        assert not tracing.enabled()
        t0 = time.perf_counter()
        for _ in range(50_000):
            with tracing.span("hot", i=1):
                pass
        dt = time.perf_counter() - t0
        assert dt < 0.5, f"disabled-span overhead too high: {dt:.3f}s"

    def test_reset_and_max_roots(self, tracer):
        tracer.max_roots = 5
        for _ in range(9):
            with tracing.span("r"):
                pass
        assert len(tracer.roots) == 5
        tracer.reset()
        assert tracer.roots == []


# --------------------------------------------------------------- metrics
class TestMetrics:
    def test_counter_gauge_identity(self, registry):
        c = metrics.counter("c_total", kind="a")
        c.inc()
        c.inc(2.5)
        assert metrics.counter("c_total", kind="a") is c
        assert metrics.counter("c_total", kind="b") is not c
        assert c.value == 3.5
        g = metrics.gauge("g_bytes")
        g.set(42)
        assert metrics.gauge("g_bytes").value == 42.0

    def test_histogram_percentiles_vs_numpy(self, registry, rng):
        """Interpolated percentile error is bounded by the bucket width."""
        width = 0.01
        buckets = tuple(np.arange(width, 1.0 + width, width))
        h = metrics.histogram("lat_seconds", buckets=buckets)
        xs = rng.uniform(0.0, 1.0, size=2000)
        for x in xs:
            h.observe(float(x))
        for q in (0.50, 0.95, 0.99):
            got = h.percentile(q)
            want = float(np.quantile(xs, q))
            assert abs(got - want) <= 2 * width, (q, got, want)

    def test_histogram_overflow_and_empty(self, registry):
        h = metrics.histogram("h", buckets=(1.0, 2.0))
        assert h.percentile(0.5) == 0.0
        h.observe(100.0)
        assert h.bucket_counts == [0, 0, 1]
        assert h.percentile(0.5) == 2.0     # clamped to the last edge
        assert h.count == 1 and h.sum == 100.0

    def test_snapshot_schema_and_validation(self, registry):
        metrics.counter("req_total", status="done").inc(3)
        metrics.gauge("mem_bytes").set(1024)
        metrics.histogram("t_seconds").observe(0.05)
        snap = metrics.snapshot()
        validate_snapshot(snap)             # must not raise
        assert snap["schema"] == metrics.SNAPSHOT_SCHEMA
        assert snap["counters"]['req_total{status="done"}'] == 3.0
        assert snap["gauges"]["mem_bytes"] == 1024.0
        h = snap["histograms"]["t_seconds"]
        assert h["count"] == 1 and sum(h["bucket_counts"]) == 1
        assert set(h) >= {"le", "bucket_counts", "p50", "p95", "p99", "sum"}
        # the snapshot is JSON round-trippable and stays valid
        validate_snapshot(json.loads(json.dumps(snap)))

    def test_validate_rejects_corruption(self, registry):
        metrics.histogram("t_seconds").observe(0.05)
        snap = metrics.snapshot()
        bad = json.loads(json.dumps(snap))
        bad["schema"] = 99
        with pytest.raises(ValueError, match="schema"):
            validate_snapshot(bad)
        bad = json.loads(json.dumps(snap))
        bad["histograms"]["t_seconds"]["bucket_counts"][0] += 1
        with pytest.raises(ValueError, match="count"):
            validate_snapshot(bad)
        bad = json.loads(json.dumps(snap))
        bad["counters"]["x"] = float("inf")
        with pytest.raises(ValueError, match="finite"):
            validate_snapshot(bad)

    def test_prometheus_text(self, registry):
        metrics.counter("req_total", status="done").inc(2)
        metrics.histogram("t_seconds", buckets=(0.1, 1.0)).observe(0.05)
        text = metrics.to_prometheus()
        assert "# TYPE req_total counter" in text
        assert 'req_total{status="done"} 2' in text
        assert "# TYPE t_seconds histogram" in text
        assert 't_seconds_bucket{le="0.1"} 1' in text
        assert 't_seconds_bucket{le="+Inf"} 1' in text
        assert "t_seconds_count 1" in text


# -------------------------------------------------------- kernel counters
class TestKernelCounters:
    def test_ema_dtype_fallback_and_paths(self, registry):
        import jax.numpy as jnp
        from repro.kernels.ema import ops as ema_ops
        m_a = jnp.ones((6, 4), jnp.int32)
        y_p = jnp.ones((6, 4), jnp.int32)
        ia = jnp.zeros((3, 2), jnp.int32)
        ip = jnp.zeros((3, 2), jnp.int32)
        ema_ops.ema(m_a, y_p, ia, ip, use_pallas=True, interpret=True)
        snap = metrics.snapshot()["counters"]
        assert snap['kernel_fallbacks_total{kernel="ema",'
                    'reason="dtype_unsupported"}'] >= 1
        assert snap['kernel_launches_total{kernel="ema",path="xla"}'] >= 1

    def test_ema_vmem_fallback(self, registry):
        import jax.numpy as jnp
        from repro.kernels.ema import ops as ema_ops
        # rows >> VMEM budget at the default block sizes -> vmem_overflow
        m_a = jnp.ones((40_000, 8), jnp.float32)
        y_p = jnp.ones((40_000, 8), jnp.float32)
        ia = jnp.zeros((4, 2), jnp.int32)
        ip = jnp.zeros((4, 2), jnp.int32)
        ema_ops.ema(m_a, y_p, ia, ip, use_pallas=True, interpret=True)
        snap = metrics.snapshot()["counters"]
        assert snap['kernel_fallbacks_total{kernel="ema",'
                    'reason="vmem_overflow"}'] >= 1

    def test_spmm_dtype_fallback(self, registry):
        import jax.numpy as jnp
        from repro.kernels.spmm import ops as spmm_ops
        g = _graph()
        prep = spmm_ops.prepare(g, "pallas_gather", interpret=True)
        out = spmm_ops.spmm(jnp.ones((3, g.n), jnp.int32), prep)
        assert out.shape == (3, g.n)
        snap = metrics.snapshot()["counters"]
        assert snap['kernel_fallbacks_total{kernel="spmm",'
                    'reason="dtype_unsupported"}'] >= 1
        assert snap['kernel_launches_total{kernel="spmm",path="xla"}'] >= 1

    def test_fusion_report_and_counters(self, registry):
        eng = build_engine(_graph(60), "u5", "pgbsc", fuse_spmm_ema=True)
        allowed = {"admitted", "admitted_shared", "dtype_unsupported",
                   "multi_consumer", "vmem_overflow"}
        assert eng.fusion_report                      # every internal node
        assert set(eng.fusion_report.values()) <= allowed
        snap = metrics.snapshot()["counters"]
        fusion = {k: v for k, v in snap.items()
                  if k.startswith("fusion_admissions_total")}
        assert sum(fusion.values()) == len(eng.fusion_report)


# ------------------------------------------------- memory-model watermark
class TestWatermark:
    @pytest.mark.parametrize("tpl", ["u5", "u7", "u10"])
    def test_measured_peak_within_model(self, registry, tpl):
        """The traced live-table watermark never exceeds the PR 3 analytic
        peak prediction that drives budget-based batching."""
        eng = build_engine(_graph(50), tpl, "pgbsc", batch_size=4)
        eng.count_iterations_batch(list(range(4)), seed=0)
        assert 0 < eng.measured_peak_bytes <= eng.peak_table_bytes
        gauges = metrics.snapshot()["gauges"]
        meas = [v for k, v in gauges.items()
                if k.startswith("memory_measured_peak_bytes")]
        model = [v for k, v in gauges.items()
                 if k.startswith("memory_model_peak_bytes")]
        assert meas and model and meas[0] <= model[0]


# ------------------------------------------------------- service plumbing
class TestServiceObservability:
    def test_estimate_cache_stats_contract(self, registry):
        cache = EstimateCache()
        assert cache.stats() == {"hits": 0, "misses": 0, "writes": 0,
                                 "invalidations": 0, "resident": 0}
        assert cache.satisfies("k", 0.1, None) is None
        cache.put("k", {"estimate": 1.0, "stderr": 0.01,
                        "rel_stderr": 0.01, "iterations": 32})
        assert cache.satisfies("k", 0.1, None) is not None
        st = cache.stats()
        assert st["hits"] == 1 and st["misses"] == 1
        assert st["writes"] == 1 and st["resident"] == 1
        snap = metrics.snapshot()["counters"]
        assert snap['estimate_cache_lookups_total{result="hit"}'] == 1
        assert snap['estimate_cache_lookups_total{result="miss"}'] == 1
        assert snap["estimate_cache_writes_total"] == 1

    def test_estimate_cache_schema_invalidation(self, registry, tmp_path):
        p = tmp_path / "est.json"
        p.write_text(json.dumps({"old_key": {"estimate": 1.0}}))
        cache = EstimateCache(str(p))
        assert len(cache) == 0
        assert cache.stats()["invalidations"] == 1

    def test_scheduler_stats_and_breakdown(self, registry, tmp_path):
        svc = CountingService(ledger_root=str(tmp_path / "svc"),
                              round_size=8, default_max_iters=16)
        svc.add_graph("g", _graph())
        rid = svc.submit(CountRequest("g", "u3", max_iters=8))
        svc.run()
        res = svc.result(rid)

        st = svc.stats()
        assert st["estimate_cache"]["writes"] == 1
        assert st["engine_cache"]["builds"] == 1

        b = res.breakdown
        assert b is not None
        assert set(b) == {"queue_s", "compile_s", "execute_s", "total_s"}
        accounted = b["queue_s"] + b["compile_s"] + b["execute_s"]
        assert b["total_s"] > 0
        assert accounted >= 0.95 * b["total_s"]
        assert res.to_dict()["breakdown"] == b

        snap = metrics.snapshot()
        c = snap["counters"]
        assert c['service_requests_total{status="done"}'] == 1
        assert c["service_dispatches_total"] >= 1
        assert c["runner_checkpoints_total"] >= 1
        h = snap["histograms"]["service_request_total_seconds"]
        assert h["count"] == 1 and h["sum"] == pytest.approx(
            b["total_s"], rel=0.05)

    def test_cached_request_counted(self, registry, tmp_path):
        svc = CountingService(ledger_root=str(tmp_path / "svc"),
                              round_size=8, default_max_iters=16)
        svc.add_graph("g", _graph())
        svc.submit(CountRequest("g", "u3", max_iters=8))
        svc.run()
        rid2 = svc.submit(CountRequest("g", "u3", max_iters=8))
        res = svc.result(rid2)
        assert res.from_cache and res.breakdown is None
        c = metrics.snapshot()["counters"]
        assert c['service_requests_total{status="cached"}'] == 1

    def test_service_round_spans(self, registry, tracer, tmp_path):
        svc = CountingService(ledger_root=str(tmp_path / "svc"),
                              round_size=8, default_max_iters=8)
        svc.add_graph("g", _graph())
        svc.submit(CountRequest("g", "u3", max_iters=8))
        svc.run()
        agg = tracer.breakdown()
        assert agg["service.round"]["count"] >= 1
        assert agg["service.dispatch"]["count"] >= 1
        assert agg["engine_cache.build"]["count"] == 1
        assert agg["runner.checkpoint"]["count"] >= 1
