"""Memory-aware plan executor: equivalence, liveness safety, memory model.

The executor refactor must be invisible to results: every engine x plan x
batched/single combination still matches the exact oracle / the unchunked
path to float-reassociation error. The memory model must be sound: the
schedule never frees a table before its last consumer, measured peak live
table bytes stay under the model's prediction, and the budget knob actually
changes what runs (batch sizes, colorset chunking for k=12).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import build_engine, count_colorful_embeddings, get_template
from repro.core import executor as ex
from repro.core.templates import TreeTemplate
from repro.graph import erdos_renyi
from repro.graph.coloring import coloring_numpy
from repro.kernels.ema import ops as ema_ops
from repro.kernels.spmm import ops as spmm_ops

ENGINES = ("fascia", "pfascia", "pgbsc")
PLANS = ("plain", "dedup", "optimized")

# Binary tree on 12 vertices: the k=12 template whose wide passive subtrees
# make the SpMM output the memory hog (the colorset-chunking target).
BINARY12 = TreeTemplate([((i - 1) // 2, i) for i in range(1, 12)],
                        name="b12")


def _graph(n=18, deg=3.5, seed=10):
    return erdos_renyi(n, deg, seed=seed)


class TestExecutorEquivalence:
    """All 3 engines x 3 plans, single and batched, vs the exact oracle.

    Counts stay < 2^24 so float32 sums of integers are exact; the oracle
    comparison is therefore the strongest possible pre-refactor check."""

    @pytest.mark.parametrize("engine", ENGINES)
    @pytest.mark.parametrize("plan", PLANS)
    def test_single_matches_oracle(self, engine, plan):
        g = _graph()
        t = get_template("u5")
        colors = coloring_numpy(0, 0, g.n, t.k)
        oracle = count_colorful_embeddings(g, t, colors)
        e = build_engine(g, t, engine, plan=plan)
        total, root = e.count_colorful(colors)
        assert float(total) == oracle, (engine, plan)
        assert not np.isnan(np.asarray(root)).any()

    @pytest.mark.parametrize("engine", ENGINES)
    @pytest.mark.parametrize("plan", PLANS)
    def test_batched_matches_single(self, engine, plan):
        g = _graph()
        t = get_template("u5")
        colorings = np.stack([coloring_numpy(3, i, g.n, t.k)
                              for i in range(5)])
        e = build_engine(g, t, engine, plan=plan)
        per = [float(e.count_colorful(c)[0]) for c in colorings]
        tot, _ = e.count_colorful_batch(jnp.asarray(colorings), batch_size=2)
        np.testing.assert_allclose(np.asarray(tot), per, rtol=1e-6)


def _check_schedule_safety(plan, sched):
    """No table/y entry is consumed after its scheduled free; root survives;
    everything else is eventually freed (no silent keep-alives)."""
    root = plan.n_nodes - 1
    chunks = sched.chunk_map
    avail: set[int] = set()
    y_avail: set[int] = set()
    freed_tables: set[int] = set()
    freed_y: set[int] = set()
    for step, idx in enumerate(sched.order):
        node = plan.nodes[idx]
        if not node.is_leaf:
            assert node.active in avail, f"active of {idx} freed too early"
            direct = (not sched.passive_cache) or chunks.get(idx, 1) > 1
            if direct:
                assert node.passive in avail, \
                    f"passive of {idx} freed too early"
            elif node.passive not in y_avail:
                assert node.passive in avail, \
                    f"passive of {idx} freed before its SpMM"
                y_avail.add(node.passive)
        avail.add(idx)
        for i in sched.free_tables[step]:
            assert i != root, "root table must never be freed"
            avail.discard(i)
            freed_tables.add(i)
        for p in sched.free_y[step]:
            y_avail.discard(p)
            freed_y.add(p)
    assert root in avail
    assert freed_tables == set(range(plan.n_nodes)) - {root}, \
        "liveness must retire every non-root table"
    assert not y_avail, "every y-cache entry must be retired"


class TestLivenessSafety:
    @pytest.mark.parametrize("tname", ["u5", "u7", "u10", "u13"])
    @pytest.mark.parametrize("plan_name", PLANS)
    @pytest.mark.parametrize("passive_cache", [True, False])
    def test_never_frees_before_last_use(self, tname, plan_name,
                                         passive_cache):
        t = get_template(tname)
        plan = {"plain": t.plan, "dedup": t.plan_dedup,
                "optimized": t.plan_optimized}[plan_name]
        for mode in ("program", "greedy", "auto"):
            sched = ex.compute_schedule(plan, t.k,
                                        passive_cache=passive_cache,
                                        order_mode=mode)
            _check_schedule_safety(plan, sched)

    def test_chunked_schedule_safety(self):
        plan = BINARY12.plan_dedup
        internal = [i for i, nd in enumerate(plan.nodes) if not nd.is_leaf]
        sched = ex.compute_schedule(plan, 12, chunks={internal[-1]: 4})
        _check_schedule_safety(plan, sched)

    def test_rejects_non_topological_order(self):
        plan = get_template("u5").plan
        with pytest.raises(ValueError):
            ex.liveness(plan, tuple(reversed(range(plan.n_nodes))))


class TestMemoryModel:
    @pytest.mark.parametrize("tname", ["u5", "u7", "u10"])
    def test_measured_peak_le_model(self, tname):
        """Eagerly run the executor with the engine's own callbacks and a
        live-bytes probe; the analytic model must be an upper bound."""
        g = _graph(24, 3.0, seed=1)
        t = get_template(tname)
        e = build_engine(g, t, "pgbsc", plan="optimized")
        colors = jnp.asarray(coloring_numpy(0, 0, g.n, t.k))
        model = ex.peak_table_bytes(e.plan, t.k, g.n, batch=1,
                                    dtype=np.float32, schedule=e.schedule)
        peaks = []
        runner = ex.PlanExecutor(e.plan, e.schedule)
        prep = e._spmm_prep
        root = runner.run(
            e._leaf_table_cn(colors),
            passive_op=lambda p, m: spmm_ops.spmm(m, prep),
            combine=lambda i, a, y: ema_ops.ema(a, y, *e._splits[i]),
            on_step=lambda step, nbytes: peaks.append(nbytes))
        assert float(root.sum()) == count_colorful_embeddings(
            g, t, np.asarray(colors))
        assert max(peaks) <= model, (max(peaks), model)

    def test_liveness_beats_keep_everything_2x_on_u10(self):
        t = get_template("u10")
        plan = t.plan_optimized
        sched = ex.compute_schedule(plan, t.k)
        keep = ex.keep_everything_bytes(plan, t.k, n=1)
        managed = ex.peak_table_bytes(plan, t.k, n=1, schedule=sched)
        assert keep >= 2 * managed, (keep, managed)

    def test_budget_to_batch_monotone(self):
        t = get_template("u5")
        plan = t.plan_dedup
        n = 100
        per1 = ex.peak_table_bytes(plan, t.k, n)
        prev = 0
        for mult in (1, 3, 7, 16):
            ch = ex.pick_execution(plan, t.k, n,
                                   memory_budget_bytes=per1 * mult)
            assert ch.fits
            assert ch.batch_size == mult  # largest B with B * peak <= budget
            assert ch.batch_size * ch.peak_bytes_per_coloring \
                <= ch.budget_bytes
            assert ch.batch_size >= prev
            prev = ch.batch_size
        capped = ex.pick_execution(plan, t.k, n,
                                   memory_budget_bytes=per1 * 10_000)
        assert capped.batch_size == ex.MAX_AUTO_BATCH

    def test_batch_scales_model_linearly(self):
        plan = get_template("u7").plan_dedup
        one = ex.peak_table_bytes(plan, 7, 50, batch=1)
        four = ex.peak_table_bytes(plan, 7, 50, batch=4)
        assert four == 4 * one


class TestColorsetChunking:
    """Acceptance: a k=12 template counts under a budget where both the
    always-live executor and the liveness-managed unchunked path exceed it,
    matching the unchunked result to ~1e-6."""

    def test_k12_under_budget_unchunked_cannot(self):
        g = erdos_renyi(48, 3.0, seed=3)
        plan = BINARY12.plan_dedup
        ref = build_engine(g, BINARY12, "pgbsc", plan="dedup")
        assert not ref.schedule.chunk_map       # default budget: unchunked
        budget = 2200 * g.n * 4                 # rows x N x itemsize
        assert ex.keep_everything_bytes(plan, 12, g.n) > budget
        assert ex.peak_table_bytes(plan, 12, g.n,
                                   schedule=ref.schedule) > budget
        e = build_engine(g, BINARY12, "pgbsc", plan="dedup",
                         memory_budget_bytes=budget)
        assert e.batch_size == 1
        assert e.schedule.chunk_map, "budget must force colorset chunking"
        assert e.exec_choice.fits
        assert e.exec_choice.peak_bytes <= budget
        colors = coloring_numpy(0, 0, g.n, 12)
        want, _ = ref.count_colorful(colors)
        got, _ = e.count_colorful(colors)
        np.testing.assert_allclose(float(got), float(want), rtol=1e-6)

    def test_chunked_batched_matches(self):
        g = erdos_renyi(30, 3.0, seed=5)
        budget = 2200 * g.n * 4
        e = build_engine(g, BINARY12, "pgbsc", plan="dedup",
                         memory_budget_bytes=budget)
        assert e.schedule.chunk_map
        ref = build_engine(g, BINARY12, "pgbsc", plan="dedup")
        per = ref.count_iterations_batch([0, 1, 2], seed=7)
        got = e.count_iterations_batch([0, 1, 2], seed=7)
        for it in per:
            assert got[it] == pytest.approx(per[it], rel=1e-6)


class TestWorkEstimate:
    def test_table_bytes_dtype_and_batch_aware(self):
        g = _graph()
        t = get_template("u5")
        base = build_engine(g, t, "pgbsc", batch_size=4)
        twice_batch = build_engine(g, t, "pgbsc", batch_size=8)
        half_dtype = build_engine(g, t, "pgbsc", batch_size=4,
                                  dtype=jnp.float16)
        # per-coloring fields share units (valid flops/bytes ratios) ...
        assert twice_batch.work.table_bytes == base.work.table_bytes
        assert twice_batch.work.total_flops == base.work.total_flops
        assert half_dtype.work.table_bytes == base.work.table_bytes // 2
        # ... and the dispatch_* properties carry the batch dimension
        assert base.work.batch == 4 and twice_batch.work.batch == 8
        assert twice_batch.work.dispatch_table_bytes \
            == 2 * base.work.dispatch_table_bytes
        assert twice_batch.work.dispatch_flops == 2 * base.work.dispatch_flops


class TestEngineRelease:
    def test_eviction_releases_and_engine_rebuilds(self):
        from repro.service.cache import EngineCache
        g = _graph(seed=2)
        t = get_template("u3")
        colors = coloring_numpy(0, 0, g.n, t.k)
        cache = EngineCache(max_entries=1)
        e1 = cache.get(g, "u3")
        want = float(e1.count_colorful(colors)[0])
        cache.get(g, "path4")                    # evicts + releases u3
        assert cache.evictions == 1
        assert e1._released
        assert e1._spmm_prep is None and e1._count_fn is None
        # a held reference to an evicted engine lazily re-materializes
        assert float(e1.count_colorful(colors)[0]) == want
        assert not e1._released

    def test_default_cache_is_bounded(self):
        from repro.service.cache import EngineCache, DEFAULT_MAX_ENTRIES
        assert EngineCache().max_entries == DEFAULT_MAX_ENTRIES
        assert EngineCache(max_entries=None).max_entries is None
