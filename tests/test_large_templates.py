"""Coverage for the paper's larger templates (u10-u17): plan consistency,
engine agreement across plan variants, and estimator self-consistency."""

import numpy as np
import pytest

from repro.core import build_engine, get_template
from repro.graph import erdos_renyi
from repro.graph.coloring import coloring_numpy

BIG = ["u10", "u12", "u13", "u14", "u15-1", "u15-2", "u16", "u17"]


class TestLargeTemplatePlans:
    @pytest.mark.parametrize("name", BIG)
    def test_plan_variants_cover_template(self, name):
        t = get_template(name)
        for plan in (t.plan, t.plan_dedup, t.plan_optimized):
            assert plan.nodes[-1].size == t.k
            # every internal node partitions exactly
            for nd in plan.nodes:
                if not nd.is_leaf:
                    a = plan.nodes[nd.active]
                    p = plan.nodes[nd.passive]
                    assert a.size + p.size == nd.size

    @pytest.mark.parametrize("name", BIG)
    def test_optimized_plan_work_not_worse(self, name):
        from math import comb
        t = get_template(name)

        def ema_work(plan):
            w = 0
            for nd in plan.nodes:
                if nd.is_leaf:
                    continue
                ta = plan.nodes[nd.active].size
                w += comb(t.k, nd.size) * comb(nd.size, ta)
            return w

        assert ema_work(t.plan_optimized) <= ema_work(t.plan_dedup)


class TestLargeTemplateCounting:
    @pytest.mark.parametrize("name", ["u10", "u12"])
    def test_plan_variants_agree_exactly(self, name):
        # small graph so the run is quick; counts stay < 2^24 (exact f32)
        g = erdos_renyi(60, 3.0, seed=12)
        t = get_template(name)
        colors = coloring_numpy(8, 0, g.n, t.k)
        vals = []
        for plan in ("plain", "dedup", "optimized"):
            e = build_engine(g, t, "pgbsc", plan=plan)
            vals.append(float(e.count_colorful(colors)[0]))
        assert vals[0] == vals[1] == vals[2], (name, vals)

    def test_u13_binary_tree_runs(self):
        g = erdos_renyi(40, 3.0, seed=13)
        t = get_template("u13")
        e = build_engine(g, t, "pgbsc", plan="optimized")
        colors = coloring_numpy(9, 0, g.n, t.k)
        total, root = e.count_colorful(colors)
        assert np.isfinite(float(total))
        assert root.shape == (1, g.n)  # C(13,13) = 1 combo at the root

    def test_dedup_shrinks_all_big_plans(self):
        for name in BIG:
            t = get_template(name)
            assert t.plan_dedup.n_nodes < t.plan.n_nodes, name
