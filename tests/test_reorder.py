"""Locality reordering: permutation invariance, BSR stats, cache identity.

An engine built with ``reorder=`` must be a drop-in replacement: callers
pass colorings and read root tables in THEIR vertex ids, and the counts
match the unreordered engine exactly (the plan walk is a sum over
automorphism-fixed terms, so a vertex relabeling only reassociates
floats). RCM must actually help where it can: on a bandable graph with
scrambled labels it has to cut the number of occupied 128x128 BSR tiles.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import CountQuery, count
from repro.core import build_engine
from repro.graph import Graph, erdos_renyi, grid_2d
from repro.graph.coloring import iteration_key, random_coloring
from repro.graph.reorder import (ORDERINGS, apply_order, degree_order,
                                 inverse_order, rcm_order)
from repro.obs import metrics as _metrics
from repro.service.cache import EngineCache


def _colorings(g, k, b=4, seed=0):
    return jnp.stack([random_coloring(iteration_key(seed, it), g.n, k)
                      for it in range(b)])


def _scrambled_grid(rows=40, cols=40, seed=3):
    g = grid_2d(rows, cols)
    rng = np.random.default_rng(seed)
    return apply_order(g, rng.permutation(g.n))


class TestOrderings:
    @pytest.mark.parametrize("name", sorted(ORDERINGS))
    def test_order_is_permutation(self, name):
        g = erdos_renyi(90, 6.0, seed=1)
        order = ORDERINGS[name](g)
        assert sorted(order) == list(range(g.n))

    def test_inverse_order_roundtrip(self):
        g = erdos_renyi(50, 4.0, seed=2)
        order = rcm_order(g)
        inv = inverse_order(order)
        np.testing.assert_array_equal(order[inv], np.arange(g.n))
        np.testing.assert_array_equal(inv[order], np.arange(g.n))

    def test_apply_order_rejects_non_permutation(self):
        g = erdos_renyi(20, 3.0, seed=0)
        with pytest.raises(ValueError):
            apply_order(g, np.zeros(g.n, np.int64))
        with pytest.raises(ValueError):
            apply_order(g, np.arange(g.n - 1))

    def test_apply_order_preserves_degrees(self):
        g = erdos_renyi(60, 5.0, seed=4)
        order = degree_order(g)
        gp = apply_order(g, order)
        assert gp.m == g.m
        np.testing.assert_array_equal(np.asarray(gp.degrees),
                                      np.asarray(g.degrees)[order])

    def test_apply_order_refreshes_bsr_state(self):
        # derived state must be recomputed for the new labeling, not
        # carried over from the source graph
        g = _scrambled_grid()
        order = rcm_order(g)
        gp = apply_order(g, order)
        assert gp.fingerprint != g.fingerprint
        s0, s1 = g.bsr_block_stats(), gp.bsr_block_stats()
        assert s1["occupied_blocks"] != s0["occupied_blocks"]

    def test_rcm_reduces_occupied_blocks_on_bandable_graph(self):
        g = _scrambled_grid()
        before = g.bsr_block_stats()
        after = apply_order(g, rcm_order(g)).bsr_block_stats()
        assert after["occupied_blocks"] < before["occupied_blocks"]
        assert after["block_density"] < before["block_density"]
        assert after["nnz_per_block"] > before["nnz_per_block"]

    def test_block_stats_empty_graph(self):
        g = Graph.from_edges(100, np.zeros((0, 2), np.int64))
        s = g.bsr_block_stats()
        assert s["occupied_blocks"] == 0


class TestReorderedEngines:
    @pytest.mark.parametrize("engine", ["fascia", "pfascia", "pgbsc"])
    @pytest.mark.parametrize("reorder", sorted(ORDERINGS))
    def test_counts_invariant_single_and_batched(self, engine, reorder):
        g = erdos_renyi(110, 6.0, seed=5)
        base = build_engine(g, "u5", engine=engine)
        perm = build_engine(g, "u5", engine=engine, reorder=reorder)
        cols = _colorings(g, base.k, b=3)
        t0, r0 = base.count_colorful_batch(cols)
        t1, r1 = perm.count_colorful_batch(cols)
        np.testing.assert_allclose(np.asarray(t1), np.asarray(t0),
                                   rtol=1e-6)
        # root tables come back in the CALLER's vertex ids
        np.testing.assert_allclose(np.asarray(r1), np.asarray(r0),
                                   rtol=1e-6)
        ts, _ = perm.count_colorful(cols[0])
        np.testing.assert_allclose(np.asarray(ts), np.asarray(t0)[0],
                                   rtol=1e-6)

    def test_invariant_with_fusion_and_multi_template(self):
        g = erdos_renyi(100, 6.0, seed=6)
        bundle = ("u5", "path5", "star5")
        base = build_engine(g, bundle, engine="pgbsc", plan="dedup")
        perm = build_engine(g, bundle, engine="pgbsc", plan="dedup",
                            reorder="rcm", fuse_spmm_ema=True)
        cols = _colorings(g, base.k, b=3)
        t0, _ = base.count_colorful_batch(cols)
        t1, _ = perm.count_colorful_batch(cols)
        np.testing.assert_allclose(np.asarray(t1), np.asarray(t0),
                                   rtol=1e-6)

    def test_engine_rejects_unknown_reorder(self):
        g = erdos_renyi(30, 3.0, seed=0)
        with pytest.raises(ValueError):
            build_engine(g, "u3", reorder="nope")

    def test_block_gauges_published(self):
        reg = _metrics.set_registry(_metrics.MetricsRegistry())
        try:
            g = _scrambled_grid()
            build_engine(g, "u3", engine="pgbsc", reorder="rcm")
            snap = reg.snapshot()["gauges"]
            b = snap['reorder_bsr_occupied_blocks{reorder="rcm",'
                     'stage="before"}']
            a = snap['reorder_bsr_occupied_blocks{reorder="rcm",'
                     'stage="after"}']
            assert a < b
            assert snap['reorder_bsr_block_density{reorder="rcm",'
                        'stage="after"}'] > 0
        finally:
            _metrics.set_registry(_metrics.MetricsRegistry())


class TestReorderIdentity:
    def test_engine_cache_none_kwarg_aliases_absent(self):
        g = erdos_renyi(40, 4.0, seed=7)
        k0 = EngineCache.key(g, "u3", "pgbsc", "optimized")
        k_none = EngineCache.key(g, "u3", "pgbsc", "optimized", reorder=None)
        k_rcm = EngineCache.key(g, "u3", "pgbsc", "optimized", reorder="rcm")
        assert k0 == k_none
        assert k_rcm != k0

    def test_engine_cache_separates_reorder_and_dtype(self):
        g = erdos_renyi(40, 4.0, seed=7)
        cache = EngineCache()
        e1 = cache.get(g, "u3", reorder="rcm")
        e2 = cache.get(g, "u3")
        e3 = cache.get(g, "u3", reorder="rcm")
        e4 = cache.get(g, "u3", dtype=jnp.bfloat16)
        assert e1 is e3 and e1 is not e2 and e4 is not e2
        assert cache.builds == 3

    def test_api_reorder_matches_unreordered(self):
        g = erdos_renyi(80, 5.0, seed=8)
        r0 = count(g, "u5", max_iters=6)
        r1 = count(g, "u5", max_iters=6, reorder="rcm")
        assert r1.estimate == pytest.approx(r0.estimate, rel=1e-6)
        assert r1.iterations == r0.iterations

    def test_query_carries_reorder(self):
        q = CountQuery(templates=("u3",), max_iters=2, reorder="degree")
        g = erdos_renyi(30, 3.0, seed=9)
        from repro.api import compile_query
        cq = compile_query(g, q)
        assert all(e.reorder == "degree" for e in cq.engines)
