"""Property-based tests (hypothesis) on system invariants."""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dev dep (requirements-dev.txt); skip, don't error")
from hypothesis import given, settings, strategies as st

from repro.core import (TemplateSpec, build_engine,
                        count_colorful_embeddings, get_template,
                        rank_colorset, tree_automorphisms, unrank_colorset)
from repro.core.colorsets import colorful_probability, split_tables
from repro.core.templates import TreeTemplate
from repro.graph import Graph
from repro.graph.coloring import coloring_numpy

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


# ------------------------------------------------------------- strategies
@st.composite
def random_tree(draw, min_k=2, max_k=8):
    """Random tree via random parent assignment (valid by construction)."""
    k = draw(st.integers(min_k, max_k))
    edges = []
    for v in range(1, k):
        parent = draw(st.integers(0, v - 1))
        edges.append((parent, v))
    return TreeTemplate(edges, name=f"rand{k}")


@st.composite
def random_graph(draw, min_n=4, max_n=14):
    n = draw(st.integers(min_n, max_n))
    m = draw(st.integers(0, n * 3))
    edges = [(draw(st.integers(0, n - 1)), draw(st.integers(0, n - 1)))
             for _ in range(m)]
    return Graph.from_edges(n, np.asarray(edges, np.int64).reshape(-1, 2))


# ------------------------------------------------------------- properties
class TestColorsetProperties:
    @given(st.integers(2, 12), st.data())
    def test_rank_unrank_roundtrip(self, k, data):
        h = data.draw(st.integers(1, k))
        from math import comb
        idx = data.draw(st.integers(0, comb(k, h) - 1))
        cs = unrank_colorset(idx, h, k)
        assert len(cs) == h and len(set(cs)) == h
        assert all(0 <= c < k for c in cs)
        assert rank_colorset(cs) == idx

    @given(st.integers(2, 10), st.data())
    def test_split_tables_are_valid_indices(self, k, data):
        from math import comb
        t = data.draw(st.integers(2, k))
        ta = data.draw(st.integers(1, t - 1))
        ia, ip = split_tables(k, t, ta)
        assert ia.max() < comb(k, ta) and ia.min() >= 0
        assert ip.max() < comb(k, t - ta) and ip.min() >= 0


class TestTemplateProperties:
    @given(random_tree())
    def test_plan_sizes_partition(self, t):
        plan = t.plan
        for nd in plan.nodes:
            if not nd.is_leaf:
                a, p = plan.nodes[nd.active], plan.nodes[nd.passive]
                assert set(a.vertices) | set(p.vertices) == set(nd.vertices)
                assert not set(a.vertices) & set(p.vertices)

    @given(random_tree())
    def test_automorphisms_divide_factorial(self, t):
        from math import factorial
        aut = tree_automorphisms(t.edges, t.k)
        assert aut >= 1
        assert factorial(t.k) % aut == 0

    @given(random_tree())
    def test_dedup_preserves_root(self, t):
        assert t.plan_dedup.nodes[-1].size == t.k
        assert t.plan_dedup.n_nodes <= t.plan.n_nodes


class TestTemplateSpecProperties:
    @given(random_tree(), st.integers(0, 10))
    def test_json_roundtrip(self, t, root_draw):
        root = root_draw % t.k
        spec = TemplateSpec(edges=t.edges, root=root, name=t.name)
        back = TemplateSpec.from_json(spec.to_json())
        assert back == spec
        assert back.canonical_hash == spec.canonical_hash
        assert back.k == t.k and back.root == root

    @given(random_tree())
    def test_canonical_hash_is_label_invariant(self, t):
        # reverse the vertex labels (and map the root along): same rooted
        # tree, so the canonical content hash must not move
        relabel = {v: t.k - 1 - v for v in range(t.k)}
        spec = TemplateSpec(edges=t.edges, root=0)
        mirrored = TemplateSpec(
            edges=tuple((relabel[u], relabel[v]) for u, v in t.edges),
            root=relabel[0])
        assert mirrored.canonical_hash == spec.canonical_hash

    @given(random_tree(min_k=2, max_k=7))
    @settings(max_examples=20, deadline=None)
    def test_automorphisms_match_brute_force(self, t):
        from itertools import permutations
        eset = {frozenset(e) for e in t.edges}
        brute = sum(
            1 for perm in permutations(range(t.k))
            if all(frozenset((perm[a], perm[b])) in eset for a, b in eset))
        assert t.automorphisms == brute


class TestEngineProperties:
    @given(random_graph(), st.integers(0, 3))
    @settings(max_examples=10, deadline=None)
    def test_pgbsc_matches_oracle_on_u3(self, g, it):
        t = get_template("u3")
        colors = coloring_numpy(11, it, g.n, t.k)
        eng = build_engine(g, t, "pgbsc")
        total, _ = eng.count_colorful(colors)
        assert float(total) == count_colorful_embeddings(g, t, colors)

    @given(random_tree(min_k=2, max_k=5))
    @settings(max_examples=8, deadline=None)
    def test_engines_agree_on_random_trees(self, t):
        g = Graph.from_edges(
            10, np.asarray([(i, (i + 1) % 10) for i in range(10)]
                           + [(i, (i + 3) % 10) for i in range(10)]))
        colors = coloring_numpy(5, 0, g.n, t.k)
        vals = []
        for eng in ("fascia", "pfascia", "pgbsc"):
            e = build_engine(g, t, eng)
            vals.append(float(e.count_colorful(colors)[0]))
        assert vals[0] == vals[1] == vals[2]

    @given(st.integers(1, 12))
    def test_colorful_probability_bounds(self, k):
        p = colorful_probability(k)
        assert 0 < p <= 1
        if k > 1:
            assert p < 1


class TestGraphStructureProperties:
    @given(random_graph())
    def test_csr_is_symmetric_simple(self, g):
        a = g.to_dense()
        assert (a == a.T).all()
        assert np.trace(a) == 0
        assert set(np.unique(a)) <= {0.0, 1.0}

    @given(random_graph())
    def test_edge_chunks_cover_all_edges(self, g):
        ch = g.padded(128).edge_chunks(tile=128, chunk_size=64)
        assert int(ch.mask.sum()) == g.m
        # every dst tile present
        assert set(ch.dst_tile.tolist()) == set(range(ch.n_tiles))

    @given(random_graph())
    def test_bsr_nnz_matches(self, g):
        bs = g.padded(128).bsr(tile=128)
        assert int(sum(b.sum() for b in bs.blocks)) == g.m

    @given(random_graph())
    def test_rcm_is_permutation(self, g):
        from repro.graph.reorder import apply_order, rcm_order
        order = rcm_order(g)
        assert sorted(order.tolist()) == list(range(g.n))
        g2 = apply_order(g, order)
        assert g2.m == g.m
