"""Optimizer, schedules, gradient compression, checkpointing."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.optimizer import (AdamWConfig, adamw_update,
                                   clip_by_global_norm, compress_int8,
                                   cosine_schedule, decompress_int8,
                                   init_adamw)
from repro.train.checkpoint import (available_steps, latest_step,
                                    restore_checkpoint, save_checkpoint)


class TestAdamW:
    def test_quadratic_converges(self):
        cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                          weight_decay=0.0, clip_norm=100.0)
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = init_adamw(params)

        def loss(p):
            return jnp.sum(p["w"] ** 2)

        for _ in range(150):
            g = jax.grad(loss)(params)
            params, state, _ = adamw_update(cfg, params, g, state)
        assert float(loss(params)) < 1e-2

    def test_clip(self):
        g = {"a": jnp.asarray([3.0, 4.0])}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(5.0)
        np.testing.assert_allclose(np.asarray(clipped["a"]),
                                   [0.6, 0.8], rtol=1e-5)

    def test_schedule_shape(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
        s = [float(cosine_schedule(cfg, jnp.float32(t)))
             for t in (0, 5, 10, 55, 100)]
        assert s[0] == 0.0
        assert s[1] == pytest.approx(0.5)
        assert s[2] == pytest.approx(1.0)
        assert 0 < s[3] < 1.0
        assert s[4] == pytest.approx(0.0, abs=1e-6)

    def test_weight_decay_shrinks(self):
        cfg = AdamWConfig(lr=0.1, warmup_steps=0, weight_decay=0.5,
                          total_steps=10)
        params = {"w": jnp.asarray([10.0])}
        state = init_adamw(params)
        g = {"w": jnp.asarray([0.0])}
        p2, _, _ = adamw_update(cfg, params, g, state)
        assert float(p2["w"][0]) < 10.0


class TestCompression:
    def test_int8_roundtrip_error_bounded(self):
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
        q, s = compress_int8(g)
        back = decompress_int8(q, s)
        err = np.abs(np.asarray(back - g)).max()
        assert err <= float(s) * 0.5 + 1e-6

    def test_compressed_psum_with_error_feedback(self):
        # single-device shard_map still exercises the psum path
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.optim.optimizer import compressed_psum
        mesh = make_mesh((1,), ("d",))
        g = {"w": jnp.asarray(np.random.default_rng(1)
                              .normal(size=(64,)).astype(np.float32))}
        r = {"w": jnp.zeros((64,), jnp.float32)}

        def f(g, r):
            return compressed_psum(g, "d", r)

        out, res = shard_map(f, mesh=mesh, in_specs=(P(), P()),
                             out_specs=(P(), P()), check_rep=False)(g, r)
        # sum over 1 device == dequantized value; error feedback bounded
        np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                                   atol=0.05)
        assert float(jnp.abs(res["w"]).max()) < 0.05

    def test_error_feedback_converges_over_steps(self):
        # repeated compression of a CONSTANT gradient: error feedback makes
        # the time-average exact
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.optim.optimizer import compressed_psum
        mesh = make_mesh((1,), ("d",))
        g = {"w": jnp.asarray([0.3, -0.7, 1.234, 0.001])}
        r = {"w": jnp.zeros((4,))}
        f = shard_map(lambda g, r: compressed_psum(g, "d", r), mesh=mesh,
                      in_specs=(P(), P()), out_specs=(P(), P()),
                      check_rep=False)
        acc = np.zeros(4)
        for t in range(50):
            out, r = f(g, r)
            acc += np.asarray(out["w"])
        np.testing.assert_allclose(acc / 50, np.asarray(g["w"]), atol=2e-3)


class TestCheckpoint:
    def test_save_restore_roundtrip(self):
        tree = {"a": jnp.arange(10, dtype=jnp.float32),
                "b": {"c": jnp.ones((3, 4), jnp.bfloat16),
                      "d": jnp.zeros((), jnp.int32)},
                "lst": [jnp.asarray([1.0]), jnp.asarray([2.0])]}
        with tempfile.TemporaryDirectory() as tmp:
            save_checkpoint(tmp, 7, tree, extras={"note": "hi"})
            out, extras = restore_checkpoint(tmp, tree)
            assert extras["note"] == "hi"
            for a, b in zip(jax.tree_util.tree_leaves(tree),
                            jax.tree_util.tree_leaves(out)):
                assert np.asarray(a).dtype == np.asarray(b).dtype
                np.testing.assert_array_equal(
                    np.asarray(a, dtype=np.float64),
                    np.asarray(b, dtype=np.float64))

    def test_latest_pointer_and_retention(self):
        tree = {"x": jnp.ones((4,))}
        with tempfile.TemporaryDirectory() as tmp:
            for step in (1, 2, 3, 4, 5):
                save_checkpoint(tmp, step, tree, keep=3)
            assert latest_step(tmp) == 5
            assert available_steps(tmp) == [3, 4, 5]

    def test_corrupt_tmp_ignored(self):
        tree = {"x": jnp.ones((4,))}
        with tempfile.TemporaryDirectory() as tmp:
            save_checkpoint(tmp, 1, tree)
            # simulate a crashed mid-write checkpoint
            os.makedirs(os.path.join(tmp, "step_000000009.tmp"))
            assert latest_step(tmp) == 1
            out, _ = restore_checkpoint(tmp, tree)
            assert out is not None

    def test_structure_mismatch_raises(self):
        with tempfile.TemporaryDirectory() as tmp:
            save_checkpoint(tmp, 1, {"x": jnp.ones((4,))})
            with pytest.raises(AssertionError):
                restore_checkpoint(tmp, {"x": jnp.ones((4,)),
                                         "y": jnp.ones((2,))})
