"""GNN correctness beyond smoke: aggregator semantics, NequIP equivariance."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.models.equivariant import (bessel_basis, init_nequip,
                                      nequip_forward, sym_traceless)
from repro.models.gnn import segment_mean, segment_std


class TestSegmentOps:
    def test_segment_mean(self):
        data = jnp.asarray([[1.0], [3.0], [5.0]])
        seg = jnp.asarray([0, 0, 1])
        out = segment_mean(data, seg, 2)
        np.testing.assert_allclose(np.asarray(out), [[2.0], [5.0]])

    def test_segment_std(self):
        data = jnp.asarray([[1.0], [3.0]])
        seg = jnp.asarray([0, 0])
        out = segment_std(data, seg, 1)
        np.testing.assert_allclose(np.asarray(out), [[1.0]], atol=1e-2)

    def test_empty_segment_is_zero(self):
        data = jnp.asarray([[2.0]])
        seg = jnp.asarray([1])
        out = segment_mean(data, seg, 3)
        np.testing.assert_allclose(np.asarray(out[0]), 0.0)
        np.testing.assert_allclose(np.asarray(out[2]), 0.0)


def _random_molecule(key, n=12, e=40):
    kp, ke, ks = jax.random.split(key, 3)
    pos = jax.random.normal(kp, (n, 3)) * 2.0
    src = jax.random.randint(ke, (e,), 0, n)
    dst = jax.random.randint(jax.random.fold_in(ke, 1), (e,), 0, n)
    species = jax.random.randint(ks, (n,), 0, 8)
    return {
        "positions": pos, "species": species,
        "edge_index": jnp.stack([src, dst]),
        "node_graph": jnp.zeros((n,), jnp.int32),
        "labels": jnp.zeros((1,), jnp.float32),
        "n_graphs": 1,
    }


def _rotation(key):
    """Random proper rotation via QR."""
    a = jax.random.normal(key, (3, 3))
    q, r = jnp.linalg.qr(a)
    q = q * jnp.sign(jnp.diag(r))
    det = jnp.linalg.det(q)
    return q.at[:, 0].multiply(jnp.sign(det))  # force det=+1


class TestNequipEquivariance:
    def test_energy_rotation_invariant(self):
        arch = reduced_config("nequip")
        cfg = arch.model
        key = jax.random.PRNGKey(0)
        params = init_nequip(key, cfg)
        batch = _random_molecule(jax.random.PRNGKey(1))
        e0 = nequip_forward(params, cfg, batch)
        for i in range(3):
            rot = _rotation(jax.random.PRNGKey(10 + i))
            b2 = dict(batch, positions=batch["positions"] @ rot.T)
            e1 = nequip_forward(params, cfg, b2)
            np.testing.assert_allclose(np.asarray(e0), np.asarray(e1),
                                       rtol=2e-4, atol=2e-5)

    def test_energy_translation_invariant(self):
        arch = reduced_config("nequip")
        cfg = arch.model
        params = init_nequip(jax.random.PRNGKey(0), cfg)
        batch = _random_molecule(jax.random.PRNGKey(2))
        e0 = nequip_forward(params, cfg, batch)
        b2 = dict(batch, positions=batch["positions"] + 7.5)
        e1 = nequip_forward(params, cfg, b2)
        np.testing.assert_allclose(np.asarray(e0), np.asarray(e1),
                                   rtol=1e-4, atol=1e-5)

    def test_energy_depends_on_geometry(self):
        arch = reduced_config("nequip")
        cfg = arch.model
        params = init_nequip(jax.random.PRNGKey(0), cfg)
        batch = _random_molecule(jax.random.PRNGKey(3))
        e0 = nequip_forward(params, cfg, batch)
        b2 = dict(batch, positions=batch["positions"] * 1.5)  # stretch
        e1 = nequip_forward(params, cfg, b2)
        assert abs(float(e0[0]) - float(e1[0])) > 1e-6

    def test_forces_via_grad(self):
        arch = reduced_config("nequip")
        cfg = arch.model
        params = init_nequip(jax.random.PRNGKey(0), cfg)
        batch = _random_molecule(jax.random.PRNGKey(4))

        def energy(pos):
            return nequip_forward(params, cfg, dict(batch, positions=pos))[0]

        forces = -jax.grad(energy)(batch["positions"])
        assert forces.shape == batch["positions"].shape
        assert np.isfinite(np.asarray(forces)).all()


class TestEquivariantPrimitives:
    def test_sym_traceless(self):
        m = jnp.asarray(np.random.default_rng(0).normal(size=(5, 3, 3))
                        .astype(np.float32))
        st = sym_traceless(m)
        np.testing.assert_allclose(np.asarray(st),
                                   np.asarray(jnp.swapaxes(st, -1, -2)),
                                   atol=1e-6)
        np.testing.assert_allclose(
            np.asarray(jnp.trace(st, axis1=-2, axis2=-1)), 0.0, atol=1e-6)

    def test_bessel_cutoff(self):
        r = jnp.asarray([0.5, 2.0, 4.9, 5.0, 6.0])
        b = bessel_basis(r, 8, 5.0)
        assert b.shape == (5, 8)
        np.testing.assert_allclose(np.asarray(b[3]), 0.0, atol=1e-5)
        np.testing.assert_allclose(np.asarray(b[4]), 0.0, atol=1e-3)


class TestPnaAggregators:
    def test_pna_uses_all_aggregators(self):
        # a graph where mean/max/min/std of messages all differ
        from repro.models.gnn import gnn_forward, init_gnn
        arch = reduced_config("pna")
        cfg = arch.model
        key = jax.random.PRNGKey(0)
        params = init_gnn(key, cfg, d_in=4)
        n = 10
        batch = {
            "x": jax.random.normal(key, (n, 4)),
            "edge_index": jnp.stack([
                jax.random.randint(key, (30,), 0, n),
                jax.random.randint(jax.random.fold_in(key, 1), (30,), 0, n)]),
            "node_graph": jnp.zeros((n,), jnp.int32),
        }
        out = gnn_forward(params, cfg, dict(batch, pool=False, n_graphs=1))
        assert out.shape == (n, cfg.n_classes)
        assert np.isfinite(np.asarray(out)).all()

    def test_isolated_nodes_finite(self):
        from repro.models.gnn import gnn_forward, init_gnn
        arch = reduced_config("pna")
        cfg = arch.model
        params = init_gnn(jax.random.PRNGKey(0), cfg, d_in=4)
        batch = {
            "x": jnp.ones((6, 4)),
            "edge_index": jnp.asarray([[0, 1], [1, 0]]),  # nodes 2..5 isolated
            "node_graph": jnp.zeros((6,), jnp.int32),
        }
        out = gnn_forward(params, cfg, dict(batch, pool=False, n_graphs=1))
        assert np.isfinite(np.asarray(out)).all()
