"""Batched estimation pipeline: equivalence with the per-coloring path.

The batched plan execution reassociates floating point (batch folded into
kernel rows / vmap), so agreement is asserted to the documented ~1e-6
relative tolerance rather than exactly. On these small integer-valued
counts the results are in practice bitwise identical.
"""

import os

import numpy as np
import pytest

from repro.core import build_engine, get_template
from repro.core.runner import EstimatorRunner, engine_counter
from repro.graph import erdos_renyi
from repro.graph.coloring import batch_colorings, coloring_numpy

ENGINES = ("fascia", "pfascia", "pgbsc")
RTOL = 1e-6


def _graph():
    return erdos_renyi(24, 3.5, seed=1)


def _colorings(g, t, b=6, seed=7):
    return np.stack([coloring_numpy(seed, i, g.n, t.k) for i in range(b)])


class TestBatchedEquivalence:
    @pytest.mark.parametrize("engine", ENGINES)
    def test_batch_matches_sequential(self, engine):
        g, t = _graph(), get_template("u5")
        colorings = _colorings(g, t)
        e = build_engine(g, t, engine)
        seq = np.array([float(e.count_colorful(c)[0]) for c in colorings])
        tot, roots = e.count_colorful_batch(colorings)
        np.testing.assert_allclose(np.asarray(tot), seq, rtol=RTOL)
        assert roots.shape[0] == colorings.shape[0]

    @pytest.mark.parametrize("method", ["segment", "ell", "dense"])
    def test_batch_across_spmm_backends(self, method):
        g, t = _graph(), get_template("u5")
        colorings = _colorings(g, t)
        e = build_engine(g, t, "pgbsc", spmm_method=method)
        seq = np.array([float(e.count_colorful(c)[0]) for c in colorings])
        tot, _ = e.count_colorful_batch(colorings)
        np.testing.assert_allclose(np.asarray(tot), seq, rtol=RTOL)

    def test_batch_pallas_ema(self):
        g, t = _graph(), get_template("u3")
        colorings = _colorings(g, t, b=3)
        ref = build_engine(g, t, "pgbsc")
        e = build_engine(g, t, "pgbsc", use_pallas_ema=True)
        want, _ = ref.count_colorful_batch(colorings)
        got, _ = e.count_colorful_batch(colorings)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=RTOL)

    def test_chunking_is_invisible(self):
        # batch_size chunking (incl. padded ragged tail) must not change
        # per-element results — the basis of resume-equals-straight.
        g, t = _graph(), get_template("u5")
        colorings = _colorings(g, t, b=7)
        e = build_engine(g, t, "pgbsc")
        whole, _ = e.count_colorful_batch(colorings, batch_size=7)
        chunked, _ = e.count_colorful_batch(colorings, batch_size=3)
        np.testing.assert_array_equal(np.asarray(whole), np.asarray(chunked))

    @pytest.mark.parametrize("engine", ENGINES)
    def test_device_side_colorings_match_host(self, engine):
        # fold_in(seed, it) inside the jit == host-side coloring_numpy
        g, t = _graph(), get_template("u3")
        e = build_engine(g, t, engine, batch_size=4)
        per = e.count_iterations_batch(range(6), seed=11)
        for it in range(6):
            colors = coloring_numpy(11, it, g.n, t.k)
            want = float(e.count_colorful(colors)[0])
            assert per[it] == pytest.approx(want, rel=RTOL)

    def test_batch_colorings_rows_match_sequential(self):
        got = np.asarray(batch_colorings(3, np.arange(5), 17, 4))
        for it in range(5):
            np.testing.assert_array_equal(got[it],
                                          coloring_numpy(3, it, 17, 4))

    def test_estimate_batched_equals_manual_loop(self):
        g, t = _graph(), get_template("u5")
        e = build_engine(g, t, "pgbsc")
        est = e.estimate(n_iters=9, seed=2, batch_size=4)
        manual = []
        for it in range(9):
            colors = coloring_numpy(2, it, g.n, t.k)
            manual.append(float(e.count_colorful(colors)[0]))
        manual = np.asarray(manual) / (t.automorphisms *
                                       est["colorful_probability"])
        assert est["count"] == pytest.approx(float(manual.mean()), rel=RTOL)

    def test_rejects_unbatched_shape(self):
        g, t = _graph(), get_template("u3")
        e = build_engine(g, t, "pgbsc")
        with pytest.raises(ValueError):
            e.count_colorful_batch(np.zeros(g.n, np.int32))


class TestRunnerBatchedResume:
    def _runner(self, eng, t, ledger_dir, counter=None, n_iters=10):
        return EstimatorRunner(
            counter or engine_counter(eng, seed=9, batch_size=4), k=t.k,
            automorphisms=t.automorphisms, n_iterations=n_iters,
            ledger_dir=ledger_dir, checkpoint_every=4, seed=9)

    def test_resume_runs_only_pending_and_matches_unbatched(self, tmp_path):
        g, t = _graph(), get_template("u3")
        eng = build_engine(g, t, "pgbsc")
        led = str(tmp_path / "a")

        # interrupted run: 5 of 10 iterations, ledger written mid-run
        partial = self._runner(eng, t, led).run(max_iterations_this_call=5)
        assert sorted(partial.completed) == [0, 1, 2, 3, 4]
        assert os.path.isfile(os.path.join(led, "ledger.json"))

        # restart with an instrumented batched counter: only pending ids run
        requested: list[int] = []
        inner = engine_counter(eng, seed=9, batch_size=4)

        def spy(iterations):
            requested.extend(int(i) for i in iterations)
            return inner(iterations)

        resumed = self._runner(eng, t, led, counter=spy).run()
        assert sorted(requested) == [5, 6, 7, 8, 9]
        assert len(resumed.completed) == 10
        assert resumed.restarts >= 1

        # matches the unbatched per-coloring estimate
        per = []
        for it in range(10):
            colors = coloring_numpy(9, it, g.n, t.k)
            per.append(float(eng.count_colorful(colors)[0]))
        from repro.core.colorsets import colorful_probability
        want = (np.mean(per) /
                (t.automorphisms * colorful_probability(t.k)))
        assert resumed.count == pytest.approx(float(want), rel=RTOL)

    def test_checkpoint_batches_are_single_dispatch_groups(self, tmp_path):
        # one counter call per checkpoint batch, whole batch handed over
        g, t = _graph(), get_template("u3")
        eng = build_engine(g, t, "pgbsc")
        calls: list[list[int]] = []
        inner = engine_counter(eng, seed=9, batch_size=8)

        def spy(iterations):
            calls.append([int(i) for i in iterations])
            return inner(iterations)

        self._runner(eng, t, str(tmp_path / "b"), counter=spy).run()
        assert calls == [[0, 1, 2, 3], [4, 5, 6, 7], [8, 9]]
