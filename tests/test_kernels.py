"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes, graph families, and block sizes."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.graph import erdos_renyi, grid_2d, rmat, star
from repro.graph.reorder import apply_order, rcm_order
from repro.kernels.ema.ops import ema, ema_xla
from repro.kernels.ema.pallas_ema import ema_pallas
from repro.kernels.ema.ref import ema_ref
from repro.kernels.spmm import ops as spmm_ops
from repro.kernels.spmm.pallas_bsr import spmm_bsr_pallas
from repro.kernels.spmm.pallas_gather import spmm_gather_pallas
from repro.kernels.spmm.ref import spmm_dense, spmm_segment_ref


def _rand_table(rng, c, n, dtype=np.float32):
    return jnp.asarray(rng.integers(0, 4, size=(c, n)).astype(dtype))


GRAPHS = {
    "er_small": lambda: erdos_renyi(96, 4.0, seed=0),
    "er_uneven": lambda: erdos_renyi(130, 7.0, seed=1),   # n % 128 != 0
    "grid": lambda: grid_2d(12, 11),
    "star_skew": lambda: star(150),
    "rmat": lambda: rmat(8, 8, seed=2),
}


class TestSpmmXlaBackends:
    @pytest.mark.parametrize("gname", sorted(GRAPHS))
    @pytest.mark.parametrize("method", ["segment", "ell"])
    @pytest.mark.parametrize("c", [1, 5, 33])
    def test_matches_dense_oracle(self, gname, method, c):
        g = GRAPHS[gname]()
        rng = np.random.default_rng(42)
        m = _rand_table(rng, c, g.n)
        want = spmm_dense(m, jnp.asarray(g.to_dense()))
        prep = spmm_ops.prepare(g, method)
        got = spmm_ops.spmm(m, prep)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0)

    def test_segment_ref_matches_dense(self):
        g = GRAPHS["er_small"]()
        rng = np.random.default_rng(0)
        m = _rand_table(rng, 7, g.n)
        src, dst = g.edges_by_dst
        got = spmm_segment_ref(m, jnp.asarray(src), jnp.asarray(dst), g.n)
        want = spmm_dense(m, jnp.asarray(g.to_dense()))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0)


class TestSpmmPallas:
    @pytest.mark.parametrize("gname", sorted(GRAPHS))
    @pytest.mark.parametrize("method", ["pallas_gather", "pallas_bsr"])
    @pytest.mark.parametrize("c", [3, 20])
    def test_matches_dense_oracle(self, gname, method, c):
        g = GRAPHS[gname]()
        rng = np.random.default_rng(7)
        m = _rand_table(rng, c, g.n)
        want = spmm_dense(m, jnp.asarray(g.to_dense()))
        prep = spmm_ops.prepare(g, method)
        got = spmm_ops.spmm(m, prep)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0)

    @pytest.mark.parametrize("tile,chunk", [(128, 128), (128, 512), (256, 256)])
    def test_gather_tile_chunk_sweep(self, tile, chunk):
        g = erdos_renyi(100, 6.0, seed=3)
        gp = g.padded(tile)
        ch = gp.edge_chunks(tile=tile, chunk_size=chunk)
        rng = np.random.default_rng(1)
        m = _rand_table(rng, 9, gp.n)
        got = spmm_gather_pallas(
            m, jnp.asarray(ch.src), jnp.asarray(ch.dst_local),
            jnp.asarray(ch.mask), jnp.asarray(ch.src_tile),
            jnp.asarray(ch.dst_tile), n_tiles=ch.n_tiles, tile=tile,
            c_block=8)
        want = spmm_dense(m, jnp.asarray(gp.to_dense()))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0)

    @pytest.mark.parametrize("method", ["pallas_gather", "pallas_bsr"])
    def test_c_smaller_than_c_block(self, method):
        g = GRAPHS["er_small"]()
        rng = np.random.default_rng(11)
        m = _rand_table(rng, 3, g.n)
        got = spmm_ops.spmm(m, spmm_ops.prepare(g, method), c_block=64)
        want = spmm_dense(m, jnp.asarray(g.to_dense()))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0)

    def test_bsr_after_rcm_has_fewer_blocks(self):
        g = grid_2d(32, 32)
        base = g.bsr(tile=128)
        rcm = apply_order(g, rcm_order(g)).bsr(tile=128)
        assert rcm.n_blocks <= base.n_blocks

    def test_bsr_kernel_direct(self):
        g = erdos_renyi(300, 5.0, seed=5).padded(128)
        bs = g.bsr(tile=128)
        rng = np.random.default_rng(2)
        m = _rand_table(rng, 16, g.n)
        got = spmm_bsr_pallas(m, jnp.asarray(bs.blocks),
                              jnp.asarray(bs.src_tile),
                              jnp.asarray(bs.dst_tile),
                              n_tiles=bs.n_tiles, tile=128, c_block=16)
        want = spmm_dense(m, jnp.asarray(g.to_dense()))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0)


class TestEma:
    @pytest.mark.parametrize("k,t,ta", [(5, 2, 1), (5, 3, 1), (7, 4, 2),
                                        (9, 5, 2)])
    @pytest.mark.parametrize("n", [64, 130, 512])
    def test_xla_matches_ref(self, k, t, ta, n):
        from repro.core.colorsets import split_tables
        from math import comb
        ia, ip = split_tables(k, t, ta)
        rng = np.random.default_rng(k * 100 + t)
        m_a = _rand_table(rng, comb(k, ta), n)
        y_p = _rand_table(rng, comb(k, t - ta), n)
        want = ema_ref(m_a, y_p, jnp.asarray(ia), jnp.asarray(ip))
        got = ema_xla(m_a, y_p, jnp.asarray(ia), jnp.asarray(ip))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0)

    @pytest.mark.parametrize("k,t,ta", [(5, 3, 1), (7, 4, 2)])
    @pytest.mark.parametrize("n", [128, 300])
    @pytest.mark.parametrize("s_block", [4, 8])
    def test_pallas_matches_ref(self, k, t, ta, n, s_block):
        from repro.core.colorsets import split_tables
        from math import comb
        ia, ip = split_tables(k, t, ta)
        rng = np.random.default_rng(k * 10 + ta)
        m_a = _rand_table(rng, comb(k, ta), n)
        y_p = _rand_table(rng, comb(k, t - ta), n)
        want = ema_ref(m_a, y_p, jnp.asarray(ia), jnp.asarray(ip))
        got = ema_pallas(m_a, y_p, jnp.asarray(ia), jnp.asarray(ip),
                         s_block=s_block, n_block=256)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0)

    def test_dispatch_fallback(self):
        # huge tables skip the pallas path but remain correct
        from repro.core.colorsets import split_tables
        ia, ip = split_tables(5, 3, 1)
        rng = np.random.default_rng(3)
        m_a = _rand_table(rng, 5, 64)
        y_p = _rand_table(rng, 10, 64)
        want = ema_ref(m_a, y_p, jnp.asarray(ia), jnp.asarray(ip))
        got = ema(m_a, y_p, jnp.asarray(ia), jnp.asarray(ip), use_pallas=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0)


def _split_pair(k, t, ta):
    from repro.core.colorsets import split_tables
    ia, ip = split_tables(k, t, ta)
    return jnp.asarray(ia), jnp.asarray(ip)


class TestBatchedKernels:
    """The Pallas kernels fold leading batch dims into the grid — no
    ``lax.map`` loop over colorings."""

    @pytest.mark.parametrize("b", [1, 3])
    @pytest.mark.parametrize("n", [130, 300])
    def test_ema_pallas_batched(self, b, n):
        from math import comb
        ia, ip = _split_pair(7, 4, 2)
        rng = np.random.default_rng(b * 10 + n)
        m_a = jnp.asarray(
            rng.integers(0, 4, size=(b, comb(7, 2), n)).astype(np.float32))
        y_p = jnp.asarray(
            rng.integers(0, 4, size=(b, comb(7, 2), n)).astype(np.float32))
        got = ema_pallas(m_a, y_p, ia, ip, s_block=8, n_block=256)
        assert got.shape == (b, comb(7, 4), n)
        for i in range(b):
            want = ema_ref(m_a[i], y_p[i], ia, ip)
            np.testing.assert_allclose(np.asarray(got[i]),
                                       np.asarray(want), rtol=0)

    def test_ema_dispatch_batched(self):
        ia, ip = _split_pair(5, 3, 1)
        rng = np.random.default_rng(4)
        m_a = jnp.asarray(
            rng.integers(0, 4, size=(2, 5, 200)).astype(np.float32))
        y_p = jnp.asarray(
            rng.integers(0, 4, size=(2, 10, 200)).astype(np.float32))
        got = ema(m_a, y_p, ia, ip, use_pallas=True)
        for i in range(2):
            want = ema_ref(m_a[i], y_p[i], ia, ip)
            np.testing.assert_allclose(np.asarray(got[i]),
                                       np.asarray(want), rtol=0)

    def test_ema_chunked_batched(self):
        from math import comb
        from repro.kernels.ema.ops import ema_chunked, pack_chunked_splits
        from repro.kernels.spmm.ref import spmm_dense
        g = GRAPHS["er_uneven"]()
        ia, ip = _split_pair(5, 3, 2)
        pack = pack_chunked_splits(np.asarray(ia), np.asarray(ip),
                                   comb(5, 1), 2)
        rng = np.random.default_rng(5)
        m_a = jnp.asarray(
            rng.integers(0, 4, size=(3, comb(5, 2), g.n)).astype(np.float32))
        m_p = jnp.asarray(
            rng.integers(0, 4, size=(3, comb(5, 1), g.n)).astype(np.float32))
        adj = jnp.asarray(g.to_dense())
        got = ema_chunked(m_a, m_p, pack, lambda m: spmm_dense(m, adj))
        for i in range(3):
            want = ema_ref(m_a[i], spmm_dense(m_p[i], adj), ia, ip)
            np.testing.assert_allclose(np.asarray(got[i]),
                                       np.asarray(want), rtol=0)


class TestKernelDtypes:
    """dtype is threaded through out_shape, accumulators, and casts —
    unsupported dtypes take the XLA path explicitly, never a silent
    float32 downcast."""

    def test_ema_pallas_float64(self, x64):
        ia, ip = _split_pair(5, 3, 2)
        rng = np.random.default_rng(1)
        m_a = jnp.asarray(
            rng.integers(0, 4, size=(10, 200)).astype(np.float64))
        y_p = jnp.asarray(
            rng.integers(0, 4, size=(5, 200)).astype(np.float64))
        got = ema_pallas(m_a, y_p, ia, ip, s_block=8, n_block=256)
        assert got.dtype == jnp.float64
        want = ema_ref(m_a, y_p, ia, ip)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0)

    @pytest.mark.parametrize("method", ["pallas_gather", "pallas_bsr"])
    def test_spmm_pallas_float64(self, x64, method):
        g = GRAPHS["er_uneven"]()
        rng = np.random.default_rng(2)
        m = jnp.asarray(rng.integers(0, 4, size=(9, g.n)).astype(np.float64))
        prep = spmm_ops.prepare(g, method)
        got = spmm_ops.spmm(m, prep)
        assert got.dtype == jnp.float64
        want = spmm_dense(m, jnp.asarray(g.to_dense()).astype(jnp.float64))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0)

    @pytest.mark.parametrize("method", ["pallas_gather", "pallas_bsr"])
    def test_spmm_unsupported_dtype_falls_back(self, method):
        # float16 is outside the interpret dtype set: dispatch must use the
        # segment-sum fallback and preserve the dtype
        g = GRAPHS["er_small"]()
        rng = np.random.default_rng(3)
        m = jnp.asarray(rng.integers(0, 4, size=(5, g.n)).astype(np.float16))
        got = spmm_ops.spmm(m, spmm_ops.prepare(g, method))
        assert got.dtype == jnp.float16
        want = spmm_dense(m.astype(jnp.float32), jnp.asarray(g.to_dense()))
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want), rtol=1e-3)

    def test_pallas_supports_dtype_sets(self):
        from repro.kernels.ema.ops import pallas_supports_dtype
        assert pallas_supports_dtype(jnp.float32, True)
        assert pallas_supports_dtype(jnp.float64, True)
        assert pallas_supports_dtype(jnp.bfloat16, True)
        assert not pallas_supports_dtype(jnp.float16, True)
        # the compiled TPU path is f32-only until widened deliberately
        assert pallas_supports_dtype(jnp.float32, False)
        assert not pallas_supports_dtype(jnp.float64, False)

    def test_engine_f64_pallas_matches_xla(self, x64):
        # the headline regression: a dtype=float64 engine on the Pallas
        # kernel paths must agree with the XLA path at f64 — before the
        # fix the kernels silently downcast to f32
        from repro.core import build_engine
        from repro.graph.coloring import coloring_numpy
        g = GRAPHS["er_small"]()
        colors = coloring_numpy(0, 0, g.n, 5)
        xla = build_engine(g, "u5", "pgbsc", dtype=jnp.float64)
        pal = build_engine(g, "u5", "pgbsc", dtype=jnp.float64,
                           spmm_method="pallas_bsr", use_pallas_ema=True)
        want, _ = xla.count_colorful(colors)
        got, _ = pal.count_colorful(colors)
        assert want.dtype == got.dtype == jnp.float64
        assert float(got) == float(want)
