"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes, graph families, and block sizes."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.graph import erdos_renyi, grid_2d, rmat, star
from repro.graph.reorder import apply_order, rcm_order
from repro.kernels.ema.ops import ema, ema_xla
from repro.kernels.ema.pallas_ema import ema_pallas
from repro.kernels.ema.ref import ema_ref
from repro.kernels.spmm import ops as spmm_ops
from repro.kernels.spmm.pallas_bsr import spmm_bsr_pallas
from repro.kernels.spmm.pallas_gather import spmm_gather_pallas
from repro.kernels.spmm.ref import spmm_dense, spmm_segment_ref


def _rand_table(rng, c, n, dtype=np.float32):
    return jnp.asarray(rng.integers(0, 4, size=(c, n)).astype(dtype))


GRAPHS = {
    "er_small": lambda: erdos_renyi(96, 4.0, seed=0),
    "er_uneven": lambda: erdos_renyi(130, 7.0, seed=1),   # n % 128 != 0
    "grid": lambda: grid_2d(12, 11),
    "star_skew": lambda: star(150),
    "rmat": lambda: rmat(8, 8, seed=2),
}


class TestSpmmXlaBackends:
    @pytest.mark.parametrize("gname", sorted(GRAPHS))
    @pytest.mark.parametrize("method", ["segment", "ell"])
    @pytest.mark.parametrize("c", [1, 5, 33])
    def test_matches_dense_oracle(self, gname, method, c):
        g = GRAPHS[gname]()
        rng = np.random.default_rng(42)
        m = _rand_table(rng, c, g.n)
        want = spmm_dense(m, jnp.asarray(g.to_dense()))
        prep = spmm_ops.prepare(g, method)
        got = spmm_ops.spmm(m, prep)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0)

    def test_segment_ref_matches_dense(self):
        g = GRAPHS["er_small"]()
        rng = np.random.default_rng(0)
        m = _rand_table(rng, 7, g.n)
        src, dst = g.edges_by_dst
        got = spmm_segment_ref(m, jnp.asarray(src), jnp.asarray(dst), g.n)
        want = spmm_dense(m, jnp.asarray(g.to_dense()))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0)


class TestSpmmPallas:
    @pytest.mark.parametrize("gname", sorted(GRAPHS))
    @pytest.mark.parametrize("method", ["pallas_gather", "pallas_bsr"])
    @pytest.mark.parametrize("c", [3, 20])
    def test_matches_dense_oracle(self, gname, method, c):
        g = GRAPHS[gname]()
        rng = np.random.default_rng(7)
        m = _rand_table(rng, c, g.n)
        want = spmm_dense(m, jnp.asarray(g.to_dense()))
        prep = spmm_ops.prepare(g, method)
        got = spmm_ops.spmm(m, prep)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0)

    @pytest.mark.parametrize("tile,chunk", [(128, 128), (128, 512), (256, 256)])
    def test_gather_tile_chunk_sweep(self, tile, chunk):
        g = erdos_renyi(100, 6.0, seed=3)
        gp = g.padded(tile)
        ch = gp.edge_chunks(tile=tile, chunk_size=chunk)
        rng = np.random.default_rng(1)
        m = _rand_table(rng, 9, gp.n)
        got = spmm_gather_pallas(
            m, jnp.asarray(ch.src), jnp.asarray(ch.dst_local),
            jnp.asarray(ch.mask), jnp.asarray(ch.src_tile),
            jnp.asarray(ch.dst_tile), n_tiles=ch.n_tiles, tile=tile,
            c_block=8)
        want = spmm_dense(m, jnp.asarray(gp.to_dense()))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0)

    def test_bsr_after_rcm_has_fewer_blocks(self):
        g = grid_2d(32, 32)
        base = g.bsr(tile=128)
        rcm = apply_order(g, rcm_order(g)).bsr(tile=128)
        assert rcm.n_blocks <= base.n_blocks

    def test_bsr_kernel_direct(self):
        g = erdos_renyi(300, 5.0, seed=5).padded(128)
        bs = g.bsr(tile=128)
        rng = np.random.default_rng(2)
        m = _rand_table(rng, 16, g.n)
        got = spmm_bsr_pallas(m, jnp.asarray(bs.blocks),
                              jnp.asarray(bs.src_tile),
                              jnp.asarray(bs.dst_tile),
                              n_tiles=bs.n_tiles, tile=128, c_block=16)
        want = spmm_dense(m, jnp.asarray(g.to_dense()))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0)


class TestEma:
    @pytest.mark.parametrize("k,t,ta", [(5, 2, 1), (5, 3, 1), (7, 4, 2),
                                        (9, 5, 2)])
    @pytest.mark.parametrize("n", [64, 130, 512])
    def test_xla_matches_ref(self, k, t, ta, n):
        from repro.core.colorsets import split_tables
        from math import comb
        ia, ip = split_tables(k, t, ta)
        rng = np.random.default_rng(k * 100 + t)
        m_a = _rand_table(rng, comb(k, ta), n)
        y_p = _rand_table(rng, comb(k, t - ta), n)
        want = ema_ref(m_a, y_p, jnp.asarray(ia), jnp.asarray(ip))
        got = ema_xla(m_a, y_p, jnp.asarray(ia), jnp.asarray(ip))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0)

    @pytest.mark.parametrize("k,t,ta", [(5, 3, 1), (7, 4, 2)])
    @pytest.mark.parametrize("n", [128, 300])
    @pytest.mark.parametrize("s_block", [4, 8])
    def test_pallas_matches_ref(self, k, t, ta, n, s_block):
        from repro.core.colorsets import split_tables
        from math import comb
        ia, ip = split_tables(k, t, ta)
        rng = np.random.default_rng(k * 10 + ta)
        m_a = _rand_table(rng, comb(k, ta), n)
        y_p = _rand_table(rng, comb(k, t - ta), n)
        want = ema_ref(m_a, y_p, jnp.asarray(ia), jnp.asarray(ip))
        got = ema_pallas(m_a, y_p, jnp.asarray(ia), jnp.asarray(ip),
                         s_block=s_block, n_block=256)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0)

    def test_dispatch_fallback(self):
        # huge tables skip the pallas path but remain correct
        from repro.core.colorsets import split_tables
        from math import comb
        ia, ip = split_tables(5, 3, 1)
        rng = np.random.default_rng(3)
        m_a = _rand_table(rng, 5, 64)
        y_p = _rand_table(rng, 10, 64)
        want = ema_ref(m_a, y_p, jnp.asarray(ia), jnp.asarray(ip))
        got = ema(m_a, y_p, jnp.asarray(ia), jnp.asarray(ip), use_pallas=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0)
