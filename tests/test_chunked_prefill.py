"""Chunked prefill (Sarathi-style): equality with full forward + decode
handoff. MoE archs route per chunk (capacity groups differ from full-batch
routing), so their check is directional, not exact — same as production
chunked-prefill systems."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models.transformer import (init_decode_cache, init_lm, lm_forward,
                                      lm_decode_step, lm_prefill_chunked)


def _setup(arch_id, B=2, S=32):
    arch = reduced_config(arch_id)
    cfg = arch.model
    key = jax.random.PRNGKey(0)
    params = init_lm(key, cfg)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return cfg, params, toks


class TestChunkedPrefill:
    @pytest.mark.parametrize("arch_id", ["smollm-360m", "gemma3-1b"])
    @pytest.mark.parametrize("chunk", [8, 16])
    def test_matches_full_forward(self, arch_id, chunk):
        cfg, params, toks = _setup(arch_id)
        B, S = toks.shape
        full, _ = lm_forward(params, cfg, toks)
        cache = init_decode_cache(cfg, B, S + 4, dtype=jnp.float32)
        out, cache = lm_prefill_chunked(params, cfg, toks, cache, chunk=chunk)
        np.testing.assert_allclose(np.asarray(full[:, -chunk:]),
                                   np.asarray(out), rtol=2e-4, atol=2e-4)
        assert int(cache["len"]) == S

    @pytest.mark.parametrize("arch_id", ["smollm-360m", "gemma3-1b"])
    def test_decode_handoff(self, arch_id):
        cfg, params, toks = _setup(arch_id)
        B, S = toks.shape
        cache = init_decode_cache(cfg, B, S + 4, dtype=jnp.float32)
        _, cache = lm_prefill_chunked(params, cfg, toks, cache, chunk=8)
        nxt = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 0,
                                 cfg.vocab_size)
        dec, cache = lm_decode_step(params, cfg, cache, nxt)
        full, _ = lm_forward(params, cfg, jnp.concatenate([toks, nxt], 1))
        np.testing.assert_allclose(np.asarray(full[:, -1:]),
                                   np.asarray(dec), rtol=2e-3, atol=2e-3)
        assert int(cache["len"]) == S + 1

    @pytest.mark.parametrize("arch_id", ["deepseek-moe-16b",
                                         "qwen3-moe-30b-a3b"])
    def test_moe_chunked_runs_and_correlates(self, arch_id):
        # per-chunk routing != full-batch routing; assert structural sanity
        # and strong correlation rather than exact equality
        cfg, params, toks = _setup(arch_id)
        B, S = toks.shape
        full, _ = lm_forward(params, cfg, toks)
        cache = init_decode_cache(cfg, B, S + 4, dtype=jnp.float32)
        out, cache = lm_prefill_chunked(params, cfg, toks, cache, chunk=8)
        a = np.asarray(full[:, -8:]).ravel()
        b = np.asarray(out).ravel()
        assert np.isfinite(b).all()
        corr = np.corrcoef(a, b)[0, 1]
        # smoke configs drop aggressively (capacity = 1.25*8*k/E with 8-token
        # groups), so chunk-vs-full routing diverges more than at production
        # scale where drops are ~0; 0.8 catches real wiring bugs
        assert corr > 0.8, corr
        assert int(cache["len"]) == S
