"""Engine correctness: FASCIA = PFASCIA = PGBSC = brute-force oracle.

Counts stay < 2^24 so float32 arithmetic is exact (every intermediate is an
integer-valued sum/product); equality against the combinatorial oracle is
asserted exactly.
"""

import numpy as np
import pytest

from repro.core import (build_engine,
                        count_colorful_embeddings, count_subgraphs_exact,
                        get_template)
from repro.graph import Graph, erdos_renyi, grid_2d, path_graph, star
from repro.graph.coloring import coloring_numpy

ENGINES = ("fascia", "pfascia", "pgbsc")


def _check_all_engines(g, tname, seed=0, iteration=0):
    t = get_template(tname)
    colors = coloring_numpy(seed, iteration, g.n, t.k)
    oracle = count_colorful_embeddings(g, t, colors)
    for eng in ENGINES:
        e = build_engine(g, t, eng)
        total, root = e.count_colorful(colors)
        assert float(total) == oracle, (eng, tname, float(total), oracle)
        assert root.shape[-1] == g.n or root.shape[0] == g.n
        assert not np.isnan(np.asarray(root)).any()
    return oracle


class TestEngineExactness:
    @pytest.mark.parametrize("tname", ["u3", "path4", "star4", "u5", "path5"])
    def test_erdos_renyi(self, tname):
        g = erdos_renyi(18, 3.5, seed=10)
        _check_all_engines(g, tname)

    @pytest.mark.parametrize("tname", ["u3", "path4", "u5"])
    def test_grid(self, tname):
        g = grid_2d(4, 4)
        _check_all_engines(g, tname)

    def test_star_graph(self):
        # star template in star graph: stress automorphism handling
        g = star(10)
        _check_all_engines(g, "star4")

    def test_path_graph_endpoints(self):
        g = path_graph(12)
        _check_all_engines(g, "path5")

    @pytest.mark.parametrize("iteration", range(4))
    def test_multiple_colorings(self, iteration):
        g = erdos_renyi(15, 3.0, seed=4)
        _check_all_engines(g, "u5", seed=2, iteration=iteration)

    def test_dedup_plan_matches(self):
        g = erdos_renyi(20, 3.0, seed=5)
        t = get_template("u7")
        colors = coloring_numpy(1, 0, g.n, t.k)
        base = build_engine(g, t, "pgbsc", dedup=False)
        dedup = build_engine(g, t, "pgbsc", dedup=True)
        a, _ = base.count_colorful(colors)
        b, _ = dedup.count_colorful(colors)
        assert float(a) == float(b)
        assert dedup.plan.n_nodes < base.plan.n_nodes

    def test_disconnected_graph(self):
        edges = np.array([[0, 1], [1, 2], [4, 5], [5, 6], [6, 7]])
        g = Graph.from_edges(8, edges)
        _check_all_engines(g, "u3")

    def test_empty_graphish(self):
        g = Graph.from_edges(6, np.array([[0, 1]]))
        t = get_template("u3")
        e = build_engine(g, t, "pgbsc")
        colors = coloring_numpy(0, 0, g.n, t.k)
        total, _ = e.count_colorful(colors)
        assert float(total) == count_colorful_embeddings(g, t, colors)


class TestSpmmBackendsInEngine:
    @pytest.mark.parametrize("method", ["segment", "ell", "dense",
                                        "pallas_gather", "pallas_bsr"])
    def test_backend_exactness(self, method):
        g = erdos_renyi(140, 5.0, seed=6)
        t = get_template("u5")
        colors = coloring_numpy(3, 1, g.n, t.k)
        ref = build_engine(g, t, "pgbsc", spmm_method="dense")
        want, _ = ref.count_colorful(colors)
        e = build_engine(g, t, "pgbsc", spmm_method=method)
        got, _ = e.count_colorful(colors)
        assert float(got) == float(want)

    def test_pallas_ema_exactness(self):
        g = erdos_renyi(140, 5.0, seed=7)
        t = get_template("u5")
        colors = coloring_numpy(5, 0, g.n, t.k)
        ref = build_engine(g, t, "pgbsc")
        want, _ = ref.count_colorful(colors)
        e = build_engine(g, t, "pgbsc", spmm_method="pallas_gather",
                         use_pallas_ema=True)
        got, _ = e.count_colorful(colors)
        assert float(got) == float(want)


class TestEstimator:
    def test_estimator_converges(self):
        g = erdos_renyi(30, 4.0, seed=3)
        t = get_template("path4")
        exact = count_subgraphs_exact(g, t)
        e = build_engine(g, t, "pgbsc")
        est = e.estimate(n_iters=200, seed=11)
        assert est["count"] == pytest.approx(exact, rel=0.15)

    def test_estimator_deterministic(self):
        g = erdos_renyi(25, 3.0, seed=9)
        t = get_template("u3")
        e = build_engine(g, t, "pgbsc")
        a = e.estimate(n_iters=5, seed=1)
        b = e.estimate(n_iters=5, seed=1)
        assert a["count"] == b["count"]

    def test_work_estimates_ordering(self):
        g = erdos_renyi(50, 4.0, seed=1)
        t = get_template("u7")
        f = build_engine(g, t, "fascia")
        p = build_engine(g, t, "pfascia")
        # pruning strictly reduces traversal flops (paper Table 2)
        assert p.work.spmm_flops < f.work.spmm_flops
