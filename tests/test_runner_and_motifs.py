"""Fault-tolerant runner (single-device path) + motif features + data."""

import os

import jax
import numpy as np
import pytest

from repro.core import build_engine, count_subgraphs_exact, get_template
from repro.core.motif_features import motif_features
from repro.core.runner import EstimatorRunner, engine_counter
from repro.graph import erdos_renyi, star


class TestRunner:
    def _mk(self, tmp, n_iters=10, sub="a"):
        g = erdos_renyi(30, 4.0, seed=0)
        t = get_template("u3")
        eng = build_engine(g, t, "pgbsc")
        return EstimatorRunner(
            engine_counter(eng, seed=9), k=t.k,
            automorphisms=t.automorphisms, n_iterations=n_iters,
            ledger_dir=os.path.join(tmp, sub), checkpoint_every=3, seed=9)

    def test_resume_equals_straight(self, tmp_path):
        r1 = self._mk(str(tmp_path), sub="x")
        partial = r1.run(max_iterations_this_call=4)
        assert len(partial.completed) == 4
        resumed = self._mk(str(tmp_path), sub="x").run()
        straight = self._mk(str(tmp_path), sub="y").run()
        assert resumed.count == straight.count
        assert len(resumed.completed) == 10
        assert resumed.restarts >= 1

    def test_ledger_mismatch_restarts_clean(self, tmp_path):
        r1 = self._mk(str(tmp_path), n_iters=5, sub="z")
        r1.run()
        # different iteration budget -> fresh ledger
        r2 = self._mk(str(tmp_path), n_iters=8, sub="z")
        res = r2.run()
        assert len(res.completed) == 8

    def test_estimate_near_exact(self, tmp_path):
        g = erdos_renyi(30, 4.0, seed=0)
        t = get_template("u3")
        eng = build_engine(g, t, "pgbsc")
        r = EstimatorRunner(engine_counter(eng, seed=1), k=t.k,
                            automorphisms=t.automorphisms, n_iterations=150,
                            ledger_dir=str(tmp_path / "e"),
                            checkpoint_every=50, seed=1)
        res = r.run()
        exact = count_subgraphs_exact(g, t)
        assert res.count == pytest.approx(exact, rel=0.25)


class TestMotifFeatures:
    def test_star_center_has_more_stars(self):
        g = star(12)
        f = motif_features(g, ["star4"], n_iters=12, seed=0, log1p=False)
        assert f.shape == (12, 1)
        # the hub roots far more star4 copies than any leaf
        assert f[0, 0] > 5 * f[1:, 0].max()

    def test_deterministic(self):
        g = erdos_renyi(25, 3.0, seed=2)
        a = motif_features(g, ["u3"], n_iters=4, seed=5)
        b = motif_features(g, ["u3"], n_iters=4, seed=5)
        np.testing.assert_array_equal(a, b)


class TestSyntheticData:
    def test_lm_batches_deterministic_and_bounded(self):
        from repro.configs import reduced_config
        from repro.data.synthetic import make_batch
        arch = reduced_config("smollm-360m")
        b1 = make_batch(arch, "smoke_train", jax.random.PRNGKey(3))
        b2 = make_batch(arch, "smoke_train", jax.random.PRNGKey(3))
        np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                      np.asarray(b2["tokens"]))
        assert int(b1["tokens"].max()) < arch.model.vocab_size
        # autoregressive consistency: targets are tokens shifted by one
        np.testing.assert_array_equal(np.asarray(b1["tokens"][:, 1:]),
                                      np.asarray(b1["targets"][:, :-1]))

    def test_specs_match_batches_for_all_archs(self):
        from repro.configs import ARCH_IDS, input_specs, reduced_config
        from repro.data.synthetic import make_batch
        for arch_id in ARCH_IDS:
            arch = reduced_config(arch_id)
            for cell in arch.cells:
                specs, _, _ = input_specs(arch, cell.name)
                batch = make_batch(arch, cell.name, jax.random.PRNGKey(0))
                # same tree structure and identical shapes/dtypes
                bs = jax.tree_util.tree_map(
                    lambda x: (tuple(x.shape), str(x.dtype)), batch)
                ss = jax.tree_util.tree_map(
                    lambda x: (tuple(x.shape), str(x.dtype)), specs)
                assert bs == ss, (arch_id, cell.name, bs, ss)
