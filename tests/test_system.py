"""End-to-end system behaviour: the full pipeline from graph to estimate,
with fault-tolerant resume, plus one training round-trip per family."""

import jax
import numpy as np
import pytest

from repro.core import (build_engine, count_subgraphs_exact, get_template)
from repro.core.runner import EstimatorRunner, engine_counter
from repro.graph import erdos_renyi
from repro.optim.optimizer import AdamWConfig
from repro.train.checkpoint import restore_checkpoint, save_checkpoint
from repro.train.step import build_train_step, concrete_train_state


def test_end_to_end_counting_pipeline(tmp_path):
    """graph -> engines agree -> estimator via fault-tolerant runner ->
    interrupt -> resume -> matches exact count within tolerance."""
    g = erdos_renyi(40, 4.0, seed=8)
    t = get_template("u5")
    exact = count_subgraphs_exact(g, t)

    # all three engines, same coloring, identical result
    from repro.graph.coloring import coloring_numpy
    colors = coloring_numpy(3, 0, g.n, t.k)
    vals = []
    for eng_name in ("fascia", "pfascia", "pgbsc"):
        eng = build_engine(g, t, eng_name)
        vals.append(float(eng.count_colorful(colors)[0]))
    assert vals[0] == vals[1] == vals[2]

    # runner with interruption
    eng = build_engine(g, t, "pgbsc", dedup=True)
    mk = lambda: EstimatorRunner(
        engine_counter(eng, seed=4), k=t.k, automorphisms=t.automorphisms,
        n_iterations=120, ledger_dir=str(tmp_path / "led"),
        checkpoint_every=20, seed=4)
    mk().run(max_iterations_this_call=50)      # simulated preemption
    res = mk().run()                           # resume
    assert len(res.completed) == 120
    assert res.count == pytest.approx(exact, rel=0.3)


def test_end_to_end_training_with_checkpoint(tmp_path):
    """LM reduced config: train, checkpoint, restore, continue — loss drops
    and the restored state continues bit-identically."""
    from repro.configs import reduced_config
    from repro.data.synthetic import make_batch
    arch = reduced_config("smollm-360m")
    state = concrete_train_state(arch, jax.random.PRNGKey(0))
    step = jax.jit(build_train_step(
        arch, AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)))

    losses = []
    for it in range(8):
        batch = make_batch(arch, "smoke_train",
                           jax.random.fold_in(jax.random.PRNGKey(1), it))
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
        if it == 3:
            save_checkpoint(str(tmp_path / "ck"), it, state,
                            extras={"step": it})
    assert losses[-1] < losses[0]

    restored, extras = restore_checkpoint(str(tmp_path / "ck"), state)
    assert extras["step"] == 3
    # continue from the checkpoint: identical to the original step-4 result
    batch4 = make_batch(arch, "smoke_train",
                        jax.random.fold_in(jax.random.PRNGKey(1), 4))
    _, m_replay = step(restored, batch4)
    assert float(m_replay["loss"]) == losses[4]


def test_motif_features_feed_models():
    """The paper's engine output plugs into the GNN substrate (GSN-style)."""
    from repro.core.motif_features import motif_features
    from repro.configs import reduced_config
    from repro.models.gnn import gnn_forward, init_gnn
    g = erdos_renyi(30, 4.0, seed=5)
    feats = motif_features(g, ["u3", "star4"], n_iters=4, seed=0)
    assert feats.shape == (30, 2)
    assert np.isfinite(feats).all()
    arch = reduced_config("pna")
    params = init_gnn(jax.random.PRNGKey(0), arch.model, d_in=2)
    src, dst = g.edges_by_dst
    import jax.numpy as jnp
    out = gnn_forward(params, arch.model, {
        "x": jnp.asarray(feats), "edge_index": jnp.asarray(np.stack([src, dst])),
        "node_graph": jnp.zeros((30,), jnp.int32), "pool": False,
        "n_graphs": 1})
    assert out.shape == (30, arch.model.n_classes)
    assert np.isfinite(np.asarray(out)).all()
