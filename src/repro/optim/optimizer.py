"""AdamW + schedules + clipping + optional int8 error-feedback gradient
compression (distributed-optimization trick for bandwidth-bound all-reduce).

No external deps: optimizer state is a pytree mirroring the params.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "init_adamw", "adamw_update", "clip_by_global_norm",
           "cosine_schedule", "compress_int8", "decompress_int8",
           "compressed_psum"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def cosine_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0., 1.)
    return cfg.lr * warm * 0.5 * (1 + jnp.cos(jnp.pi * t))


def init_adamw(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads, max_norm: float):
    sq = jax.tree_util.tree_reduce(
        lambda a, g: a + jnp.sum(g.astype(jnp.float32) ** 2), grads, 0.0)
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(cfg: AdamWConfig, params, grads, state):
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step.astype(jnp.float32))
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu_n = cfg.b1 * mu + (1 - cfg.b1) * g32
        nu_n = cfg.b2 * nu + (1 - cfg.b2) * g32 * g32
        mu_hat = mu_n / (1 - cfg.b1 ** step.astype(jnp.float32))
        nu_hat = nu_n / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu_n, nu_n

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_mu = jax.tree_util.tree_leaves(state["mu"])
    flat_nu = jax.tree_util.tree_leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}


# --------------------------------------------------- gradient compression
def compress_int8(g: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor symmetric int8 quantization -> (q, scale)."""
    amax = jnp.max(jnp.abs(g.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, axis: str, residuals):
    """Error-feedback int8-compressed gradient all-reduce (shard_map scope).

    Each device quantizes (grad + residual), psums the int8 payload in int32,
    dequantizes with a psum-maxed scale, and keeps the quantization error as
    next step's residual. Cuts all-reduce bytes 4x vs f32 at <1e-2 relative
    error with error feedback (tested in tests/test_optim.py).
    """
    def one(g, r):
        x = g.astype(jnp.float32) + r
        amax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        new_r = x - q.astype(jnp.float32) * scale
        summed = jax.lax.psum(q.astype(jnp.int32), axis)
        return summed.astype(jnp.float32) * scale, new_r

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(residuals)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    summed = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
    new_res = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
    return summed, new_res
