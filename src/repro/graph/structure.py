"""Graph structures for PGBSC.

The host-side canonical representation is CSR (numpy). Device-side formats are
derived on demand:

* ``edges``        — (src, dst) int32 arrays sorted by dst (segment-sum SpMM).
* ``ell``          — padded neighbor lists (n, max_deg) for vertex-centric
                     (FASCIA-style) engines.
* ``edge_chunks``  — destination-tile-sorted fixed-size edge chunks for the
                     Pallas gather SpMM kernel.
* ``bsr``          — 128x128 dense-ified adjacency tiles (block-sparse rows)
                     for the Pallas MXU SpMM kernel.

All formats represent the *reverse* traversal used by the DP: for an undirected
graph, A is symmetric and Y[:, i] = sum_{j in N(i)} M[:, j].
"""

from __future__ import annotations

import dataclasses
import hashlib
from functools import cached_property

import numpy as np

__all__ = ["Graph", "EdgeChunks", "BsrMatrix"]


@dataclasses.dataclass(frozen=True)
class EdgeChunks:
    """Fixed-size edge chunks grouped by (dst_tile, src_tile) pairs.

    Every chunk touches exactly one (source tile, destination tile) pair of
    ``tile`` vertices each; chunks are sorted by destination tile so an
    accumulator output block stays resident across consecutive grid steps,
    and the source tile id drives the BlockSpec window of the count matrix.
    """

    src: np.ndarray        # (n_chunks, chunk_size) int32, global src vertex id
    dst_local: np.ndarray  # (n_chunks, chunk_size) int32, dst offset inside tile
    mask: np.ndarray       # (n_chunks, chunk_size) float32 {0, 1}
    src_tile: np.ndarray   # (n_chunks,) int32, source tile index
    dst_tile: np.ndarray   # (n_chunks,) int32, destination tile index
    tile: int
    n_tiles: int

    @property
    def n_chunks(self) -> int:
        return int(self.src.shape[0])

    @property
    def chunk_size(self) -> int:
        return int(self.src.shape[1])


@dataclasses.dataclass(frozen=True)
class BsrMatrix:
    """Block-sparse adjacency: dense ``tile x tile`` blocks for nonempty tiles.

    ``blocks[b]`` is the dense sub-matrix A[src_tile*t:(src_tile+1)*t,
    dst_tile*t:(dst_tile+1)*t]; the SpMM computes
    ``Y[:, dst_block] += M[:, src_block] @ blocks[b]``. Blocks are sorted by
    ``dst_tile`` so output blocks are revisited consecutively.
    """

    blocks: np.ndarray    # (n_blocks, tile, tile) float32
    src_tile: np.ndarray  # (n_blocks,) int32
    dst_tile: np.ndarray  # (n_blocks,) int32
    tile: int
    n_tiles: int

    @property
    def n_blocks(self) -> int:
        return int(self.blocks.shape[0])

    @property
    def density(self) -> float:
        nnz = float(np.count_nonzero(self.blocks))
        return nnz / max(1.0, self.blocks.size)


@dataclasses.dataclass(frozen=True)
class Graph:
    """Simple undirected graph in CSR form (host-side numpy).

    ``indptr``/``indices`` follow scipy conventions. The graph is stored
    symmetrized and deduplicated; self-loops are removed.
    """

    n: int
    indptr: np.ndarray   # (n + 1,) int64
    indices: np.ndarray  # (m,) int32  — column ids, sorted per row

    # ------------------------------------------------------------- builders
    @staticmethod
    def from_edges(n: int, edges: np.ndarray) -> "Graph":
        """Build from an (m, 2) array of (possibly directed/duplicated) edges."""
        edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        if edges.size:
            if edges.min() < 0 or edges.max() >= n:
                raise ValueError("edge endpoint out of range")
        # symmetrize, drop self loops, dedup
        und = np.concatenate([edges, edges[:, ::-1]], axis=0)
        und = und[und[:, 0] != und[:, 1]]
        if und.size:
            key = und[:, 0] * n + und[:, 1]
            key = np.unique(key)
            src = (key // n).astype(np.int64)
            dst = (key % n).astype(np.int32)
        else:
            src = np.zeros((0,), np.int64)
            dst = np.zeros((0,), np.int32)
        indptr = np.zeros(n + 1, dtype=np.int64)
        np.add.at(indptr, src + 1, 1)
        np.cumsum(indptr, out=indptr)
        return Graph(n=n, indptr=indptr, indices=dst)

    @staticmethod
    def from_adjacency(a: np.ndarray) -> "Graph":
        a = np.asarray(a)
        src, dst = np.nonzero(a)
        return Graph.from_edges(a.shape[0], np.stack([src, dst], axis=1))

    # ------------------------------------------------------------ properties
    @property
    def m(self) -> int:
        """Number of directed edge slots (2x undirected edge count)."""
        return int(self.indices.shape[0])

    @cached_property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int64)

    @property
    def max_degree(self) -> int:
        return int(self.degrees.max()) if self.n else 0

    @property
    def avg_degree(self) -> float:
        return float(self.m) / max(1, self.n)

    @cached_property
    def fingerprint(self) -> str:
        """Stable content hash of the CSR structure (32 hex chars).

        Identical across processes and machines for identical graphs, so it
        can key persistent caches (compiled engines, estimate ledgers, .npz
        dataset caches) without trusting file paths or object identity.
        """
        h = hashlib.blake2b(digest_size=16)
        h.update(np.int64(self.n).tobytes())
        h.update(np.ascontiguousarray(self.indptr, np.int64).tobytes())
        h.update(np.ascontiguousarray(self.indices, np.int32).tobytes())
        return h.hexdigest()

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]

    def to_dense(self) -> np.ndarray:
        a = np.zeros((self.n, self.n), dtype=np.float32)
        src = np.repeat(np.arange(self.n), self.degrees)
        a[src, self.indices] = 1.0
        return a

    # ------------------------------------------------------- device formats
    @cached_property
    def edges_by_dst(self) -> tuple[np.ndarray, np.ndarray]:
        """(src, dst) int32 arrays; CSR is per-dst sorted already (symmetric).

        Because the CSR rows are destination rows for the reverse traversal
        (A symmetric), row i's entries are the sources contributing to dst i.
        """
        dst = np.repeat(np.arange(self.n, dtype=np.int32), self.degrees)
        src = self.indices.astype(np.int32)
        return src, dst

    def ell(self, pad_value: int | None = None) -> tuple[np.ndarray, np.ndarray]:
        """Padded neighbor table (n, max_deg) + float mask. pad_value defaults n-1."""
        d = self.max_degree
        pad = (self.n - 1) if pad_value is None else pad_value
        nbr = np.full((self.n, max(d, 1)), pad, dtype=np.int32)
        msk = np.zeros((self.n, max(d, 1)), dtype=np.float32)
        for v in range(self.n):
            lo, hi = self.indptr[v], self.indptr[v + 1]
            nbr[v, : hi - lo] = self.indices[lo:hi]
            msk[v, : hi - lo] = 1.0
        return nbr, msk

    def edge_chunks(self, tile: int = 128, chunk_size: int = 512) -> EdgeChunks:
        """(dst_tile, src_tile)-grouped fixed-size edge chunks (gather SpMM)."""
        src, dst = self.edges_by_dst
        n_tiles = -(-self.n // tile)
        stile = src // tile
        dtile = dst // tile
        key = dtile.astype(np.int64) * n_tiles + stile
        order = np.argsort(key, kind="stable")
        src, dst, key = src[order], dst[order], key[order]
        uniq, starts = np.unique(key, return_index=True)
        bounds = list(starts) + [len(key)]

        chunk_src, chunk_dl, chunk_mask, chunk_st, chunk_dt = [], [], [], [], []
        for i, kk in enumerate(uniq):
            st = int(kk % n_tiles)
            dt = int(kk // n_tiles)
            s = src[bounds[i]: bounds[i + 1]]
            d = dst[bounds[i]: bounds[i + 1]] - dt * tile
            for off in range(0, len(s), chunk_size):
                ss = s[off: off + chunk_size]
                dd = d[off: off + chunk_size]
                pad = chunk_size - len(ss)
                # padding edges point at the chunk's own src tile, masked out
                chunk_src.append(np.pad(ss, (0, pad), constant_values=st * tile))
                chunk_dl.append(np.pad(dd, (0, pad)))
                msk = np.zeros(chunk_size, np.float32)
                msk[: len(ss)] = 1.0
                chunk_mask.append(msk)
                chunk_st.append(st)
                chunk_dt.append(dt)
        # Every dst tile needs >= 1 chunk so its output block is initialized.
        present = set(chunk_dt)
        for t in range(n_tiles):
            if t not in present:
                chunk_src.append(np.full(chunk_size, t * tile, np.int64))
                chunk_dl.append(np.zeros(chunk_size, np.int64))
                chunk_mask.append(np.zeros(chunk_size, np.float32))
                chunk_st.append(t)
                chunk_dt.append(t)
        order2 = np.argsort(np.asarray(chunk_dt), kind="stable")
        return EdgeChunks(
            src=np.stack(chunk_src).astype(np.int32)[order2],
            dst_local=np.stack(chunk_dl).astype(np.int32)[order2],
            mask=np.stack(chunk_mask)[order2],
            src_tile=np.asarray(chunk_st, dtype=np.int32)[order2],
            dst_tile=np.asarray(chunk_dt, dtype=np.int32)[order2],
            tile=tile,
            n_tiles=n_tiles,
        )

    def bsr(self, tile: int = 128) -> BsrMatrix:
        """Dense-ified tile blocks, sorted by destination tile.

        Block b holds A[src_tile, dst_tile] densified;
        Y[:, dst] += M[:, src] @ block. Efficient after RCM reordering
        concentrates nonzeros near the diagonal.
        """
        src, dst = self.edges_by_dst
        n_tiles = -(-self.n // tile)
        stile = src // tile
        dtile = dst // tile
        key = dtile.astype(np.int64) * n_tiles + stile
        order = np.argsort(key, kind="stable")
        src, dst, key = src[order], dst[order], key[order]
        uniq, starts = np.unique(key, return_index=True)
        starts = list(starts) + [len(key)]
        blocks, s_tiles, d_tiles = [], [], []
        for i, k in enumerate(uniq):
            st = int(k % n_tiles)
            dt = int(k // n_tiles)
            blk = np.zeros((tile, tile), dtype=np.float32)
            sl = slice(starts[i], starts[i + 1])
            blk[src[sl] - st * tile, dst[sl] - dt * tile] = 1.0
            blocks.append(blk)
            s_tiles.append(st)
            d_tiles.append(dt)
        # Every dst tile needs >= 1 block so its output block is initialized.
        present = set(d_tiles)
        for t in range(n_tiles):
            if t not in present:
                blocks.append(np.zeros((tile, tile), np.float32))
                s_tiles.append(t)
                d_tiles.append(t)
        order = np.argsort(np.asarray(d_tiles), kind="stable")
        return BsrMatrix(
            blocks=np.stack(blocks)[order],
            src_tile=np.asarray(s_tiles, np.int32)[order],
            dst_tile=np.asarray(d_tiles, np.int32)[order],
            tile=tile,
            n_tiles=n_tiles,
        )

    def bsr_block_stats(self, tile: int = 128) -> dict:
        """Occupied-block count and density of the ``tile`` BSR layout
        WITHOUT materializing any blocks (one unique pass over edge tile
        keys) — cheap enough to publish as gauges on every engine build.
        Zero filler blocks for empty destination tiles (see :meth:`bsr`)
        are excluded: this counts blocks that carry actual nonzeros, the
        quantity vertex reordering is trying to shrink.
        """
        n_tiles = -(-self.n // tile)
        if self.m == 0:
            occupied = 0
        else:
            src, dst = self.edges_by_dst
            key = (dst // tile).astype(np.int64) * n_tiles + src // tile
            occupied = int(np.unique(key).size)
        total = n_tiles * n_tiles
        return {
            "tile": tile,
            "n_tiles": n_tiles,
            "occupied_blocks": occupied,
            "total_blocks": total,
            # fraction of the tile grid that is occupied (reordering
            # shrinks it) and nonzeros per occupied block (reordering
            # grows it — the MXU utilization proxy)
            "block_density": occupied / total if total else 0.0,
            "nnz_per_block": self.m / occupied if occupied else 0.0,
        }

    def padded(self, multiple: int) -> "Graph":
        """Pad vertex count up to a multiple (isolated padding vertices)."""
        n_pad = -(-self.n // multiple) * multiple
        if n_pad == self.n:
            return self
        indptr = np.concatenate(
            [self.indptr, np.full(n_pad - self.n, self.indptr[-1], np.int64)]
        )
        return Graph(n=n_pad, indptr=indptr, indices=self.indices)
