"""Deterministic synthetic graph generators (host-side numpy).

RMAT matches the paper's synthetic datasets; Erdos-Renyi / Barabasi-Albert /
grids / stars cover tests and benchmarks. All generators take an integer seed
and are reproducible.
"""

from __future__ import annotations

import numpy as np

from repro.graph.structure import Graph

__all__ = [
    "rmat",
    "erdos_renyi",
    "barabasi_albert",
    "grid_2d",
    "star",
    "path_graph",
    "complete_graph",
    "random_regular",
]


def rmat(
    scale: int,
    edge_factor: int = 16,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
) -> Graph:
    """R-MAT generator (Chakrabarti et al. 2004); skew grows with a/(b=c=d)."""
    n = 1 << scale
    m = n * edge_factor
    rng = np.random.default_rng(seed)
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for level in range(scale):
        r = rng.random(m)
        # quadrant probabilities a, b, c, d
        go_right = (r >= a) & (r < a + b) | (r >= a + b + c)
        go_down = r >= a + b
        src |= go_down.astype(np.int64) << level
        dst |= go_right.astype(np.int64) << level
    return Graph.from_edges(n, np.stack([src, dst], axis=1))


def erdos_renyi(n: int, avg_degree: float, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    m = int(n * avg_degree / 2)
    edges = rng.integers(0, n, size=(m, 2))
    return Graph.from_edges(n, edges)


def barabasi_albert(n: int, m_attach: int = 4, seed: int = 0) -> Graph:
    """Preferential attachment (vectorized approximation via repeated targets)."""
    rng = np.random.default_rng(seed)
    repeated: list[int] = list(range(m_attach))
    edges = []
    for v in range(m_attach, n):
        # sample m_attach targets proportional to degree (with replacement ok)
        idx = rng.integers(0, len(repeated), size=m_attach)
        chosen = [repeated[i] for i in idx]
        for u in chosen:
            edges.append((v, u))
        repeated.extend(chosen)
        repeated.extend([v] * m_attach)
    return Graph.from_edges(n, np.asarray(edges, dtype=np.int64))


def grid_2d(rows: int, cols: int) -> Graph:
    idx = np.arange(rows * cols).reshape(rows, cols)
    e = []
    e.append(np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], axis=1))
    e.append(np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], axis=1))
    return Graph.from_edges(rows * cols, np.concatenate(e, axis=0))


def star(n: int) -> Graph:
    edges = np.stack([np.zeros(n - 1, np.int64), np.arange(1, n)], axis=1)
    return Graph.from_edges(n, edges)


def path_graph(n: int) -> Graph:
    edges = np.stack([np.arange(n - 1), np.arange(1, n)], axis=1)
    return Graph.from_edges(n, edges)


def complete_graph(n: int) -> Graph:
    src, dst = np.meshgrid(np.arange(n), np.arange(n))
    return Graph.from_edges(n, np.stack([src.ravel(), dst.ravel()], axis=1))


def random_regular(n: int, d: int, seed: int = 0) -> Graph:
    """Approximate d-regular graph via random perfect matchings."""
    rng = np.random.default_rng(seed)
    edges = []
    for _ in range(d):
        perm = rng.permutation(n)
        edges.append(np.stack([perm[: n // 2], perm[n // 2: 2 * (n // 2)]], axis=1))
    return Graph.from_edges(n, np.concatenate(edges, axis=0))
