"""Graph IO: edge-list text/binary formats + deterministic dataset cache.

Production ingestion path for real datasets (SNAP/Graph500 edge lists): a
text/tsv reader, a compact .npz binary cache (10-50x faster to reload), and
a helper that round-trips through the cache automatically.
"""

from __future__ import annotations

import os

import numpy as np

from repro.graph.structure import Graph

__all__ = ["load_edge_list", "save_edge_list", "save_graph_npz",
           "load_graph_npz", "load_cached"]


def load_edge_list(path: str, *, comment: str = "#",
                   n: int | None = None) -> Graph:
    """Whitespace-separated 'src dst' lines; vertex count inferred if n=None."""
    edges = []
    with open(path) as f:
        for line in f:
            s = line.strip()
            if not s or s.startswith(comment):
                continue
            parts = s.split()
            edges.append((int(parts[0]), int(parts[1])))
    arr = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    n = n if n is not None else (int(arr.max()) + 1 if arr.size else 0)
    return Graph.from_edges(n, arr)


def save_edge_list(g: Graph, path: str) -> None:
    src, dst = g.edges_by_dst
    keep = src < dst          # write each undirected edge once
    with open(path, "w") as f:
        f.write(f"# n={g.n} m={int(keep.sum())}\n")
        for s, d in zip(src[keep], dst[keep]):
            f.write(f"{s} {d}\n")


def save_graph_npz(g: Graph, path: str) -> None:
    np.savez_compressed(path, n=np.int64(g.n), indptr=g.indptr,
                        indices=g.indices)


def load_graph_npz(path: str) -> Graph:
    z = np.load(path)
    return Graph(n=int(z["n"]), indptr=z["indptr"], indices=z["indices"])


def load_cached(path: str, cache_dir: str | None = None) -> Graph:
    """Load an edge list with a transparent .npz binary cache."""
    cache_dir = cache_dir or os.path.dirname(path)
    cache = os.path.join(cache_dir,
                         os.path.basename(path) + ".cache.npz")
    if os.path.isfile(cache) and \
            os.path.getmtime(cache) >= os.path.getmtime(path):
        return load_graph_npz(cache)
    g = load_edge_list(path)
    tmp = cache[:-len(".npz")] + ".tmp.npz"
    save_graph_npz(g, tmp)
    os.replace(tmp, cache)
    return g
