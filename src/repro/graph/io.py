"""Graph IO: edge-list text/binary formats + deterministic dataset cache.

Production ingestion path for real datasets (SNAP/Graph500 edge lists): a
text/tsv reader, a compact .npz binary cache (10-50x faster to reload), and
a helper that round-trips through the cache automatically.
"""

from __future__ import annotations

import os
import zipfile

import numpy as np

from repro.graph.structure import Graph

__all__ = ["load_edge_list", "save_edge_list", "save_graph_npz",
           "load_graph_npz", "load_cached"]


def load_edge_list(path: str, *, comment: str = "#",
                   n: int | None = None) -> Graph:
    """Whitespace-separated 'src dst' lines; vertex count inferred if n=None."""
    edges = []
    with open(path) as f:
        for line in f:
            s = line.strip()
            if not s or s.startswith(comment):
                continue
            parts = s.split()
            edges.append((int(parts[0]), int(parts[1])))
    arr = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    n = n if n is not None else (int(arr.max()) + 1 if arr.size else 0)
    return Graph.from_edges(n, arr)


def save_edge_list(g: Graph, path: str) -> None:
    src, dst = g.edges_by_dst
    keep = src < dst          # write each undirected edge once
    with open(path, "w") as f:
        f.write(f"# n={g.n} m={int(keep.sum())}\n")
        for s, d in zip(src[keep], dst[keep]):
            f.write(f"{s} {d}\n")


def save_graph_npz(g: Graph, path: str, *, source: str | None = None,
                   source_stat: os.stat_result | None = None) -> None:
    """Save a graph; ``source`` records the originating edge-list file's
    stat so a cache can detect staleness even when mtimes lie (copied
    caches, rewrites that preserve timestamps, coarse filesystem clocks).
    Pass ``source_stat`` captured *before* reading the source to avoid
    stamping a concurrently-rewritten file's stat onto stale content."""
    extra = {"fingerprint": np.array(g.fingerprint)}
    if source is not None:
        st = source_stat if source_stat is not None else os.stat(source)
        extra["src_mtime_ns"] = np.int64(st.st_mtime_ns)
        extra["src_size"] = np.int64(st.st_size)
    np.savez_compressed(path, n=np.int64(g.n), indptr=g.indptr,
                        indices=g.indices, **extra)


def load_graph_npz(path: str) -> Graph:
    z = np.load(path)
    return Graph(n=int(z["n"]), indptr=z["indptr"], indices=z["indices"])


def _cache_is_fresh(cache: str, path: str) -> bool:
    """A cache is fresh only if its recorded source stat matches the source
    file exactly; legacy caches without the stat fall back to mtime order."""
    if not os.path.isfile(cache):
        return False
    try:
        z = np.load(cache)
    except (OSError, ValueError, zipfile.BadZipFile):
        # unreadable/truncated/corrupt cache -> treat as stale, rebuild
        return False
    st = os.stat(path)
    if "src_mtime_ns" in z.files and "src_size" in z.files:
        return (int(z["src_mtime_ns"]) == st.st_mtime_ns
                and int(z["src_size"]) == st.st_size)
    return os.path.getmtime(cache) >= os.path.getmtime(path)


def load_cached(path: str, cache_dir: str | None = None) -> Graph:
    """Load an edge list with a transparent .npz binary cache.

    The cache records the source file's (mtime_ns, size); a rewritten or
    newer edge list invalidates it and the graph is re-parsed and re-cached.
    """
    cache_dir = cache_dir or os.path.dirname(path)
    cache = os.path.join(cache_dir,
                         os.path.basename(path) + ".cache.npz")
    if _cache_is_fresh(cache, path):
        return load_graph_npz(cache)
    # stat BEFORE parsing: if the source is rewritten mid-parse, the stamped
    # stat stays older than the file's and the cache reads as stale next time
    st = os.stat(path)
    g = load_edge_list(path)
    tmp = cache[:-len(".npz")] + ".tmp.npz"
    save_graph_npz(g, tmp, source=path, source_stat=st)
    os.replace(tmp, cache)
    return g
