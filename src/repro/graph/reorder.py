"""Vertex reordering for locality (paper §4.3 pre-processing).

Reverse Cuthill-McKee concentrates nonzeros near the diagonal, which on TPU
translates directly into fewer nonempty 128x128 BSR tiles for the MXU SpMM
path. Degree sorting helps the gather path's destination-tile balance.

Engines opt in with ``build_engine(..., reorder="rcm")``: the graph is
permuted ONCE at engine construction, the whole plan walk runs in the
permuted vertex space, and only the coloring input / root-table output are
permuted at the engine boundary (see ``core/engines.py``). Orderings are
registered in :data:`ORDERINGS` by the name the engine/API/service accept.

Conventions: an ordering is ``order[new_id] = old_id``; its inverse is
``inv[old_id] = new_id`` (``inverse_order``). A coloring permutes as
``colors[..., order]`` and a per-vertex table inverse-permutes back as
``table[..., inv]``.
"""

from __future__ import annotations

import numpy as np

from repro.graph.structure import Graph

__all__ = ["rcm_order", "degree_order", "apply_order", "inverse_order",
           "ORDERINGS"]


def rcm_order(g: Graph) -> np.ndarray:
    """Reverse Cuthill-McKee permutation: order[new_id] = old_id."""
    n = g.n
    visited = np.zeros(n, dtype=bool)
    order: list[int] = []
    degrees = g.degrees
    # iterate components, starting from minimum-degree unvisited vertex
    remaining = np.argsort(degrees, kind="stable")
    ptr = 0
    while len(order) < n:
        while ptr < n and visited[remaining[ptr]]:
            ptr += 1
        if ptr >= n:
            break
        root = int(remaining[ptr])
        visited[root] = True
        order.append(root)
        head = len(order) - 1
        while head < len(order):
            v = order[head]
            head += 1
            nbrs = g.neighbors(v)
            nbrs = nbrs[~visited[nbrs]]
            if len(nbrs):
                nbrs = nbrs[np.argsort(degrees[nbrs], kind="stable")]
                visited[nbrs] = True
                order.extend(int(u) for u in nbrs)
    return np.asarray(order[::-1], dtype=np.int64)


def degree_order(g: Graph, descending: bool = True) -> np.ndarray:
    d = g.degrees
    o = np.argsort(d, kind="stable")
    return o[::-1].copy() if descending else o


# name -> ordering function; the vocabulary `reorder=` accepts everywhere
# (engine constructor, repro.api, the service CLI)
ORDERINGS = {"rcm": rcm_order, "degree": degree_order}


def inverse_order(order: np.ndarray) -> np.ndarray:
    """inv[old_id] = new_id for an ``order[new_id] = old_id`` permutation."""
    inv = np.empty_like(order)
    inv[order] = np.arange(len(order))
    return inv


def apply_order(g: Graph, order: np.ndarray) -> Graph:
    """Relabel graph so new vertex i is old vertex order[i].

    Returns a FRESH :class:`Graph` built from the relabeled edge list — no
    cached derived state (BSR blocks, fingerprint, degree arrays, ELL pads)
    leaks across from ``g``; everything is recomputed lazily for the new
    labeling. ``order`` must be a permutation of ``range(g.n)``.
    """
    order = np.asarray(order)
    if order.shape != (g.n,) or not np.array_equal(
            np.sort(order), np.arange(g.n)):
        raise ValueError(
            f"order must be a permutation of range({g.n}), got shape "
            f"{order.shape}")
    inv = inverse_order(order)
    src, dst = g.edges_by_dst
    new_edges = np.stack([inv[src], inv[dst]], axis=1)
    return Graph.from_edges(g.n, new_edges)
