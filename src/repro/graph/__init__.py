"""Graph substrate: structures, generators, coloring, reordering."""

from repro.graph.coloring import iteration_key, random_coloring
from repro.graph.generators import (barabasi_albert, complete_graph,
                                    erdos_renyi, grid_2d, path_graph,
                                    random_regular, rmat, star)
from repro.graph.reorder import (ORDERINGS, apply_order, degree_order,
                                 inverse_order, rcm_order)
from repro.graph.structure import BsrMatrix, EdgeChunks, Graph

__all__ = [
    "iteration_key", "random_coloring",
    "barabasi_albert", "complete_graph", "erdos_renyi", "grid_2d",
    "path_graph", "random_regular", "rmat", "star",
    "ORDERINGS", "apply_order", "degree_order", "inverse_order", "rcm_order",
    "BsrMatrix", "EdgeChunks", "Graph",
]
