"""Random vertex coloring (color-coding phase 1)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["random_coloring", "iteration_key", "batch_colorings"]


def random_coloring(key: jax.Array, n: int, k: int) -> jax.Array:
    """Uniform color in [0, k) per vertex, int32 (n,)."""
    return jax.random.randint(key, (n,), 0, k, dtype=jnp.int32)


def iteration_key(seed: int, iteration: int) -> jax.Array:
    """Deterministic per-iteration key: iterations are idempotent units of
    work that any worker (pod) can execute — the basis of the fault-tolerance
    story (see core/runner.py)."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), iteration)


def batch_colorings(seed, iterations: jax.Array, n: int, k: int) -> jax.Array:
    """(B, n) int32 colorings for a batch of iteration ids — jit-traceable.

    Row b equals ``random_coloring(iteration_key(seed, iterations[b]), n, k)``
    bit-for-bit, so batched estimators reproduce the sequential ones exactly.
    Both ``seed`` and ``iterations`` may be traced values, which lets the
    whole generation run device-side inside the caller's jit.
    """
    base = jax.random.PRNGKey(seed)
    its = jnp.asarray(iterations, jnp.int32)
    keys = jax.vmap(lambda it: jax.random.fold_in(base, it))(its)
    return jax.vmap(lambda kk: random_coloring(kk, n, k))(keys)


def coloring_numpy(seed: int, iteration: int, n: int, k: int) -> np.ndarray:
    """Host-side mirror of random_coloring for oracle tests."""
    return np.asarray(random_coloring(iteration_key(seed, iteration), n, k))
