"""Random vertex coloring (color-coding phase 1)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["random_coloring", "iteration_key"]


def random_coloring(key: jax.Array, n: int, k: int) -> jax.Array:
    """Uniform color in [0, k) per vertex, int32 (n,)."""
    return jax.random.randint(key, (n,), 0, k, dtype=jnp.int32)


def iteration_key(seed: int, iteration: int) -> jax.Array:
    """Deterministic per-iteration key: iterations are idempotent units of
    work that any worker (pod) can execute — the basis of the fault-tolerance
    story (see core/runner.py)."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), iteration)


def coloring_numpy(seed: int, iteration: int, n: int, k: int) -> np.ndarray:
    """Host-side mirror of random_coloring for oracle tests."""
    return np.asarray(random_coloring(iteration_key(seed, iteration), n, k))
