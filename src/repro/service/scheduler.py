"""Round-based adaptive-precision scheduler for the counting service.

The scheduler turns a set of live :class:`CountRequest`\\ s into the minimum
number of device dispatches:

* requests sharing a ``(graph fingerprint, template canonical hash,
  engine, plan, seed)`` key are attached to one **dispatch group** with a
  single deterministic sample stream (iteration ids 0, 1, 2, ... colored by
  ``fold_in(seed, id)``), so N concurrent tenants asking the same question
  cost the same device work as one — template identity is the *canonical
  hash*, so a registry name and a relabeled edge list of the same tree are
  the same question;
* each scheduling round extends every active group by up to ``round_size``
  iterations through ONE ``count_iterations_batch`` dispatch (via the
  fault-tolerant :class:`EstimatorRunner` ledger, so a killed service
  resumes where it stopped);
* every member request folds the new samples into a Welford running
  mean/stderr and **retires the moment its relative standard error hits its
  target**, instead of burning a fixed iteration budget.

Because samples are deterministic functions of (seed, iteration id), a
request that joins a group late — or a service that restarts on an existing
ledger — consumes the exact samples a solo run would have produced:
cross-request batching and resume are estimate-invariant.
"""

from __future__ import annotations

import os
import random
import tempfile
import time
import dataclasses

from repro.core.colorsets import colorful_probability
from repro.core.runner import EstimatorRunner, engine_counter
from repro.graph.structure import Graph
from repro.obs import metrics as _metrics
from repro.obs import tracing as _tracing
from repro.resilience import faults as _faults
from repro.resilience.degradation import (BreakerBoard, CircuitOpen,
                                          DegradationState)
from repro.resilience.retry import (DispatchTimeout, RetryPolicy,
                                    run_with_timeout)
from repro.service.cache import EngineCache, EstimateCache
from repro.service.requests import (CountRequest, RequestResult,
                                    RequestStatus, RunningStat)

__all__ = ["CountingService"]


@dataclasses.dataclass
class _Group:
    """One dispatch group: a shared deterministic sample stream."""

    key: tuple
    graph_name: str
    runner: EstimatorRunner
    engine: object
    scale: float                 # 1 / (automorphisms * colorful_probability)
    history: list[float]         # history[i] = scaled sample of iteration i
    cursor: int                  # next fresh iteration id (== len(history))
    members: list[str]
    # rebuild identity (degradation-ladder step-down/re-promotion swaps the
    # engine underneath the runner without losing the sample stream)
    spec: object = None
    engine_name: str = "pgbsc"
    plan_name: str = "optimized"
    seed: int = 0
    label: str = ""              # fault-point context / breaker label


@dataclasses.dataclass
class _ReqState:
    request: CountRequest
    status: RequestStatus
    stat: RunningStat
    consumed: int = 0
    group_key: tuple | None = None
    shared_group: bool = False
    from_cache: bool = False
    result: RequestResult | None = None
    error: str | None = None
    error_class: str | None = None   # structured error (exception class)
    t_submit: float = 0.0
    # latency attribution (perf_counter clock): submit -> attach start is
    # queue time, engine build inside attach is compile time, attach end ->
    # retire is execute time
    t_submit_pc: float = 0.0
    t_attach_pc: float = 0.0
    queue_s: float = 0.0
    build_s: float = 0.0

    @property
    def cap(self) -> int:
        return self.request.max_iters if self.request.max_iters is not None \
            else self._default_cap

    _default_cap: int = 0


class CountingService:
    """Multi-tenant subgraph-counting service (see module docstring).

    Parameters
    ----------
    ledger_root:
        Directory for per-group iteration ledgers (fault tolerance /
        resume). Defaults to a fresh temporary directory.
    engine_cache / estimate_cache:
        Shared caches; pass explicitly to share engines across services or
        persist estimates across processes (``estimate_cache`` may be a
        path string, an :class:`EstimateCache`, or None for in-memory).
    round_size:
        Fresh iterations dispatched per group per scheduling round; also
        the adaptive-stopping granularity.
    default_max_iters:
        Iteration cap for requests that specify only ``rel_stderr`` — the
        hard bound that keeps zero-count or high-variance queries finite.
    batch_size:
        Engine chunking knob forwarded to ``engine_counter`` (None = the
        engine's budget-derived default).
    memory_budget_bytes:
        Per-engine device-memory budget forwarded to every engine build
        (part of the engine-cache key): the executor's memory model turns
        it into the dispatch batch size — and into colorset-chunked
        execution for templates whose single-coloring footprint already
        exceeds it. None = the executor default budget.
    engine_kw:
        Extra build options forwarded to every engine construction (e.g.
        ``spmm_method``); part of the engine-cache key.
    retry_policy:
        Dispatch-path containment (:class:`~repro.resilience.retry.
        RetryPolicy`): per-dispatch retry budget, jittered exponential
        backoff, and (when ``timeout_s`` is set) a wall-clock watchdog
        that abandons hung dispatches. None = the default policy (4
        attempts, no watchdog).
    degrade_after / degrade_cooldown_s:
        Degradation-ladder shape: consecutive failures per step-down, and
        the failure-free interval before a one-rung re-promotion.
    breaker_threshold / breaker_cooldown_s:
        Circuit breaker per dispatch group: consecutive *exhausted*
        dispatches before the group's circuit opens (poison quarantine —
        requests fail fast instead of retrying forever), and the cool-down
        before a half-open trial dispatch.
    """

    def __init__(self, *, ledger_root: str | None = None,
                 engine_cache: EngineCache | None = None,
                 estimate_cache: EstimateCache | str | None = None,
                 round_size: int = 8, default_max_iters: int = 256,
                 checkpoint_every: int | None = None,
                 batch_size: int | None = None,
                 memory_budget_bytes: int | None = None,
                 engine_kw: dict | None = None,
                 retry_policy: RetryPolicy | None = None,
                 degrade_after: int = 2, degrade_cooldown_s: float = 60.0,
                 breaker_threshold: int = 3,
                 breaker_cooldown_s: float = 30.0):
        self.ledger_root = ledger_root or tempfile.mkdtemp(
            prefix="pgbsc_service_")
        # explicit None checks: both caches define __len__, so a fresh
        # (empty) shared cache passed by the caller is falsy
        self.engine_cache = EngineCache() if engine_cache is None \
            else engine_cache
        if isinstance(estimate_cache, str):
            estimate_cache = EstimateCache(estimate_cache)
        self.estimate_cache = EstimateCache() if estimate_cache is None \
            else estimate_cache
        self.round_size = int(round_size)
        self.default_max_iters = int(default_max_iters)
        self.checkpoint_every = checkpoint_every or self.round_size
        self.batch_size = batch_size
        self.engine_kw = dict(engine_kw or {})
        if memory_budget_bytes is not None:
            self.engine_kw["memory_budget_bytes"] = int(memory_budget_bytes)
        self.memory_budget_bytes = memory_budget_bytes
        self.retry_policy = retry_policy or RetryPolicy()
        self.degrade_after = int(degrade_after)
        self.degrade_cooldown_s = float(degrade_cooldown_s)
        self._breakers = BreakerBoard(threshold=breaker_threshold,
                                      cooldown_s=breaker_cooldown_s)
        self._ladders: dict[tuple, DegradationState] = {}
        # jittered-backoff stream (seeded: chaos runs are reproducible)
        self._retry_rng = random.Random(0xC0FFEE)
        self.graphs: dict[str, Graph] = {}
        self._requests: dict[str, _ReqState] = {}
        self._groups: dict[tuple, _Group] = {}
        self._seq = 0

    # ------------------------------------------------------------- tenants
    def add_graph(self, name: str, g: Graph) -> str:
        """Register a graph under ``name``; returns its content fingerprint."""
        self.graphs[name] = g
        return g.fingerprint

    def submit(self, request: CountRequest) -> str:
        """Queue a request; returns its id. Served instantly (status DONE,
        ``from_cache``) when the persistent estimate cache already holds an
        answer meeting the request's precision contract."""
        request.validate()               # fails fast on unknown/invalid
        #  templates too (names are sugar; arbitrary edge lists first-class)
        if request.graph not in self.graphs:
            raise KeyError(f"unknown graph {request.graph!r}; "
                           f"registered: {sorted(self.graphs)}")
        self._seq += 1
        rid = f"r{self._seq:04d}"
        st = _ReqState(request=request, status=RequestStatus.PENDING,
                       stat=RunningStat(), t_submit=time.time(),
                       t_submit_pc=time.perf_counter())
        st._default_cap = self.default_max_iters
        fp = self.graphs[request.graph].fingerprint
        ck = EstimateCache.key(fp, request.spec, request.engine,
                               request.plan, request.seed)
        ent = self.estimate_cache.satisfies(ck, request.rel_stderr,
                                            request.max_iters,
                                            request.min_iters)
        if ent is not None:
            se = float(ent["stderr"])
            st.status = RequestStatus.DONE
            st.from_cache = True
            st.result = RequestResult(
                estimate=float(ent["estimate"]), stderr=se,
                rel_stderr=float(ent["rel_stderr"]),
                ci95=(float(ent["estimate"]) - 1.96 * se,
                      float(ent["estimate"]) + 1.96 * se),
                iterations=int(ent["iterations"]), target_met=True,
                from_cache=True, seconds=0.0)
            _metrics.counter("service_requests_total",
                             status="cached").inc()
        self._requests[rid] = st
        return rid

    def status(self, rid: str) -> RequestStatus:
        return self._requests[rid].status

    def result(self, rid: str) -> RequestResult:
        st = self._requests[rid]
        if st.result is None:
            raise RuntimeError(f"request {rid} is {st.status.value}"
                               + (f": {st.error}" if st.error else ""))
        return st.result

    def cancel(self, rid: str) -> None:
        """Withdraw a request. Cancelling the last live member of a group
        drains the group *before* the next round, not after: every round
        re-checks liveness immediately before dispatching
        (:meth:`_plan_dispatch`), so a drained group never costs another
        device dispatch. A dispatch already in flight when the cancel
        lands still completes and flushes its runner-ledger checkpoint —
        those samples are real work and serve any future joiner."""
        st = self._requests[rid]
        if st.status in (RequestStatus.PENDING, RequestStatus.RUNNING):
            st.status = RequestStatus.CANCELLED
            _metrics.counter("service_requests_total",
                             status="cancelled").inc()

    # ----------------------------------------------------------- resilience
    def _ladder_for(self, key: tuple) -> DegradationState:
        """The degradation ladder for one engine-build identity (the group
        key minus the seed: graph, template, engine, plan)."""
        lk = key[:4]
        lad = self._ladders.get(lk)
        if lad is None:
            lad = DegradationState(engine=str(key[2]),
                                   template=str(key[1])[:8],
                                   step_after=self.degrade_after,
                                   cooldown_s=self.degrade_cooldown_s)
            self._ladders[lk] = lad
        return lad

    @staticmethod
    def _group_label(request: CountRequest, fingerprint: str) -> str:
        return (f"{fingerprint[:8]}:{request.spec.canonical_hash[:8]}:"
                f"{request.engine}:{request.plan}:s{request.seed}")

    def _fail_member(self, st: _ReqState, exc: BaseException) -> None:
        st.status = RequestStatus.FAILED
        st.error = f"{type(exc).__name__}: {exc}"
        st.error_class = type(exc).__name__
        _metrics.counter("service_requests_total", status="failed").inc()

    def _rebuild_group_engine(self, grp: _Group,
                              ladder: DegradationState) -> None:
        """Swap the group's engine for one built at the ladder's current
        level. The runner (and its ledger) survive — the sample stream is
        a pure function of ``(seed, iteration id)``, so an engine swap is
        estimate-invariant."""
        g = self.graphs[grp.graph_name]
        eng = self.engine_cache.get(g, grp.spec, grp.engine_name,
                                    grp.plan_name,
                                    **ladder.apply(self.engine_kw))
        grp.engine = eng
        grp.runner.counter = engine_counter(
            eng, seed=grp.seed, batch_size=self.batch_size, label=grp.label)
        _metrics.counter("engine_rebuilds_total",
                         level=ladder.level_name).inc()

    def resilience_state(self) -> dict:
        """Degradation-ladder and circuit-breaker state (``/healthz``)."""
        ladders = {}
        for (fp, th, eng, plan), lad in self._ladders.items():
            if lad.level > 0:
                ladders[f"{str(th)[:8]}:{eng}:{plan}"] = lad.snapshot()
        return {"degraded_ladders": ladders,
                "ladder_total": len(self._ladders),
                "breakers": self._breakers.snapshot()}

    # ----------------------------------------------------------- scheduling
    def _build_group(self, st: _ReqState) -> tuple[_Group, float]:
        """Construct the dispatch group for ``st``'s request: engine build
        (or cache hit) plus ledger resume. This is the slow half of attach
        — the async front end runs it outside its admission lock so a cold
        compile never blocks new submissions. Returns ``(group,
        build_seconds)``; the caller registers the group.

        Builds run at the group's degradation-ladder level; a failed build
        that steps the ladder down (e.g. an OOM at the fused/bf16 level)
        retries at the degraded level before giving up."""
        g = self.graphs[st.request.graph]
        spec = st.request.spec
        t = spec.tree
        key = st.request.group_key(g.fingerprint)
        label = self._group_label(st.request, g.fingerprint)
        ladder = self._ladder_for(key)
        t_build = time.perf_counter()
        while True:
            try:
                eng = self.engine_cache.get(
                    g, spec, st.request.engine,
                    st.request.plan, **ladder.apply(self.engine_kw))
                break
            except Exception:
                if not ladder.on_failure(reason="build_error"):
                    raise
                # stepped down: retry the build with the degraded options
        build_s = time.perf_counter() - t_build
        scale = 1.0 / (t.automorphisms * colorful_probability(t.k))
        # canonical hash, not name: two spellings of one tree resume
        # the same ledger
        ledger_dir = os.path.join(
            self.ledger_root,
            f"{g.fingerprint[:12]}_{spec.canonical_hash}_"
            f"{st.request.engine}_{st.request.plan}_s{st.request.seed}")
        runner = EstimatorRunner(
            engine_counter(eng, seed=st.request.seed,
                           batch_size=self.batch_size, label=label),
            k=t.k, automorphisms=t.automorphisms, n_iterations=None,
            ledger_dir=ledger_dir,
            checkpoint_every=self.checkpoint_every,
            seed=st.request.seed)
        # resume: ledgered contiguous prefix becomes instant history
        led = runner.completed_iterations()
        history: list[float] = []
        while len(history) in led:
            history.append(led[len(history)] * scale)
        return _Group(key=key, graph_name=st.request.graph, runner=runner,
                      engine=eng, scale=scale, history=history,
                      cursor=len(history), members=[], spec=spec,
                      engine_name=st.request.engine,
                      plan_name=st.request.plan, seed=st.request.seed,
                      label=label), build_s

    def _attach(self, rid: str, st: _ReqState) -> None:
        t_start = time.perf_counter()
        st.queue_s = max(0.0, t_start - st.t_submit_pc)
        _metrics.histogram("service_request_queue_seconds").observe(
            st.queue_s)
        g = self.graphs[st.request.graph]
        key = st.request.group_key(g.fingerprint)
        grp = self._groups.get(key)
        if grp is None:
            # compile time is attributed to the group creator; joiners
            # inherit a warm engine and report build_s = 0
            grp, st.build_s = self._build_group(st)
            self._groups[key] = grp
        else:
            st.shared_group = True
        grp.members.append(rid)
        st.group_key = key
        st.status = RequestStatus.RUNNING
        st.t_attach_pc = time.perf_counter()

    def _satisfied(self, st: _ReqState) -> bool:
        n = st.stat.n
        if n >= st.cap:
            return True
        tgt = st.request.rel_stderr
        return (tgt is not None and n >= min(st.request.min_iters, st.cap)
                and st.stat.rel_stderr <= tgt)

    def _retire(self, rid: str, st: _ReqState) -> None:
        stat = st.stat
        tgt = st.request.rel_stderr
        st.status = RequestStatus.DONE
        now = time.perf_counter()
        total_s = max(0.0, now - st.t_submit_pc)
        execute_s = max(0.0, now - st.t_attach_pc)
        breakdown = {"queue_s": st.queue_s, "compile_s": st.build_s,
                     "execute_s": execute_s, "total_s": total_s}
        _metrics.histogram("service_request_compile_seconds").observe(
            st.build_s)
        _metrics.histogram("service_request_execute_seconds").observe(
            execute_s)
        _metrics.histogram("service_request_total_seconds").observe(total_s)
        _metrics.counter("service_requests_total", status="done").inc()
        st.result = RequestResult(
            estimate=stat.mean, stderr=stat.stderr,
            rel_stderr=stat.rel_stderr, ci95=stat.ci95, iterations=stat.n,
            target_met=(tgt is None or stat.rel_stderr <= tgt),
            from_cache=False, shared_group=st.shared_group,
            seconds=time.time() - st.t_submit, breakdown=breakdown)
        g = self.graphs[st.request.graph]
        ck = EstimateCache.key(g.fingerprint, st.request.spec,
                               st.request.engine, st.request.plan,
                               st.request.seed)
        prev = self.estimate_cache.get(ck)
        if prev is None or prev["iterations"] < stat.n:
            self.estimate_cache.put(ck, {
                "estimate": stat.mean, "stderr": stat.stderr,
                "rel_stderr": stat.rel_stderr, "iterations": stat.n})

    def _consume_and_retire(self) -> None:
        for rid, st in self._requests.items():
            if st.status is not RequestStatus.RUNNING:
                continue
            grp = self._groups[st.group_key]
            hi = min(len(grp.history), st.cap)
            while st.consumed < hi:
                st.stat.update(grp.history[st.consumed])
                st.consumed += 1
                if self._satisfied(st):
                    break
            if self._satisfied(st):
                self._retire(rid, st)

    def _live_members(self, grp: _Group) -> list[_ReqState]:
        return [self._requests[rid] for rid in grp.members
                if self._requests[rid].status is RequestStatus.RUNNING]

    def _plan_dispatch(self, grp: _Group) -> list[int] | None:
        """Fresh iteration ids for one round of ``grp``, or None when the
        group is drained (every member retired, failed, or cancelled).
        Liveness is evaluated here, immediately before the dispatch it
        plans — so cancelling a group's last live member drains it before
        the next round, never one round late."""
        live = self._live_members(grp)
        if not live:
            return None
        # never dispatch past the last live member's remaining budget
        # (every request has a cap — adaptive ones the service default)
        need = max(m.cap - m.stat.n for m in live)
        n_new = min(self.round_size, max(need, 1))
        return list(range(grp.cursor, grp.cursor + n_new))

    def _dispatch_ids(self, grp: _Group, ids: list[int]) -> bool:
        """Run one planned round and append its scaled samples to the group
        history; returns False when containment gave up (live members are
        marked FAILED with a structured error). The runner checkpoints the
        ledger per batch, so samples computed for a request cancelled
        mid-dispatch are still flushed and serve future joiners.

        Containment order per round:

        1. **circuit breaker** — an open breaker fails the round fast
           (:class:`CircuitOpen`), no device work, no retries;
        2. **re-promotion** — a degraded ladder past its cooldown steps up
           one rung and the engine is rebuilt at the better level;
        3. **watchdog + retry** — each attempt runs under the policy's
           wall-clock timeout (hung dispatches are abandoned, not joined
           forever); failures step the ladder (rebuilding the engine at
           the degraded level) and back off with seeded jitter until the
           attempt budget is exhausted.

        Because samples are pure functions of ``(seed, iteration id)``, a
        retried or degraded dispatch reproduces bitwise-identical
        estimates — containment never perturbs answers.
        """
        ladder = self._ladder_for(grp.key)
        breaker = self._breakers.get(grp.key, label=grp.label)
        if not breaker.allow():
            exc = CircuitOpen(grp.label, breaker.failures)
            for m in self._live_members(grp):
                self._fail_member(m, exc)
            return False
        if ladder.maybe_promote():
            try:
                self._rebuild_group_engine(grp, ladder)
            except Exception:
                ladder.on_failure(reason="rebuild_error")

        policy = self.retry_policy

        def attempt_fn(cancelled):
            _faults.inject("dispatch.hang", context=grp.label)
            if cancelled.is_set():      # watchdog already gave up on us
                return None
            return grp.runner.run_iterations(ids)

        per = None
        last_exc: BaseException | None = None
        for attempt in range(1, policy.max_attempts + 1):
            t_disp = time.perf_counter()
            try:
                with _tracing.span("service.dispatch",
                                   group=grp.graph_name,
                                   engine=grp.key[2], n=len(ids),
                                   tenants=len(self._live_members(grp)),
                                   attempt=attempt):
                    with _tracing.profiled_dispatch():
                        per = run_with_timeout(attempt_fn, policy.timeout_s,
                                               name=grp.label)
                break
            except Exception as exc:
                last_exc = exc
                reason = "timeout" if isinstance(exc, DispatchTimeout) \
                    else "error"
                if ladder.on_failure(reason=f"dispatch_{reason}"):
                    try:
                        self._rebuild_group_engine(grp, ladder)
                    except Exception:
                        pass        # keep the old engine; retry may still work
                if attempt >= policy.max_attempts:
                    break
                _metrics.counter("dispatch_retries_total",
                                 reason=reason).inc()
                time.sleep(policy.delay(attempt, self._retry_rng))
        if per is None:
            breaker.on_failure()
            for m in self._live_members(grp):
                self._fail_member(m, last_exc)
            return False
        breaker.on_success()
        ladder.on_success()
        _metrics.counter("service_dispatches_total").inc()
        _metrics.histogram("service_dispatch_seconds").observe(
            time.perf_counter() - t_disp)
        for i in ids:
            grp.history.append(per[i] * grp.scale)
        grp.cursor += len(ids)
        return True

    def step(self) -> int:
        """One scheduling round; returns the number of live requests left.

        Round shape: attach new requests to groups, let everyone consume
        already-available samples (joins and ledger resumes often finish
        right here, with zero device work), then extend each still-needed
        group by one ``round_size`` batch — a single device dispatch per
        group regardless of how many tenants share it — and consume again.
        """
        _metrics.counter("service_rounds_total").inc()
        with _tracing.span("service.round"):
            for rid, st in list(self._requests.items()):
                if st.status is RequestStatus.PENDING:
                    try:
                        self._attach(rid, st)
                    except Exception as exc:  # unknown engine/plan, build
                        self._fail_member(st, exc)
            self._consume_and_retire()
            for grp in self._groups.values():
                ids = self._plan_dispatch(grp)
                if ids is None:
                    continue
                self._dispatch_ids(grp, ids)
            self._consume_and_retire()
            self._release_idle_engines()
        return sum(st.status in (RequestStatus.PENDING, RequestStatus.RUNNING)
                   for st in self._requests.values())

    def _release_idle_engines(self) -> None:
        """Release device arrays of engines that only idle groups pin.

        Groups are kept forever (their sample history serves late joiners
        instantly), but a retired group must not keep an engine's device
        arrays and compiled executables resident after the bounded
        :class:`EngineCache` evicted it — otherwise device memory grows
        with every distinct group ever seen regardless of the cache bound.
        Engines still cache-resident stay warm (repeated requests keep the
        no-rebuild/no-recompile guarantee); engines used by any live group
        are left alone; a late joiner to an idle group re-materializes its
        engine lazily.
        """
        keep = self.engine_cache.resident_ids() \
            if hasattr(self.engine_cache, "resident_ids") else set()
        keep |= {id(grp.engine) for grp in self._groups.values()
                 if self._live_members(grp)}
        for grp in self._groups.values():
            eng = grp.engine
            if id(eng) in keep or not hasattr(eng, "release"):
                continue
            if not getattr(eng, "_released", True):
                eng.release()

    def run(self, max_rounds: int = 100_000) -> dict[str, RequestResult]:
        """Drive rounds until every request reaches a terminal status;
        returns results for all DONE requests (keyed by request id)."""
        for _ in range(max_rounds):
            if self.step() == 0:
                break
        return {rid: st.result for rid, st in self._requests.items()
                if st.result is not None}

    # ------------------------------------------------------------- insight
    def stats(self) -> dict:
        """Service-level accounting: engine- and estimate-cache behavior,
        group count, unique device iterations vs. per-request iterations
        consumed."""
        consumed = sum(st.result.iterations for st in self._requests.values()
                       if st.result is not None and not st.from_cache)
        return {
            "requests": len(self._requests),
            "groups": len(self._groups),
            "engine_cache": self.engine_cache.stats(),
            "estimate_cache": self.estimate_cache.stats(),
            "unique_iterations": sum(g.cursor for g in self._groups.values()),
            "consumed_iterations": consumed,
        }
