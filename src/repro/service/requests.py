"""Request API for the multi-tenant counting service.

A :class:`CountRequest` names a registered graph, a template — a registry
name (sugar), a :class:`~repro.core.templates.TemplateSpec`, a
TreeTemplate, or a raw edge list; arbitrary user trees are first-class —
an engine/plan choice, and a *precision contract*: either a
relative-standard-error target (``rel_stderr``, adaptive stopping) or a
fixed iteration cap (``max_iters``), or both (stop at whichever comes
first). The service answers with a :class:`RequestResult` carrying the
estimate, its standard error, and a 95% confidence interval computed from
the per-iteration color-coding samples. Request identity — for dispatch
groups and every cache — is the template's *canonical hash*, never its
name: two spellings of the same rooted tree share one sample stream.

Status lifecycle (see ``repro.service`` package docstring for the full
narrative)::

    PENDING --> RUNNING --> DONE
        \\          \\-----> FAILED
         \\---------------> DONE       (served from the estimate cache)
          \\--------------> CANCELLED  (cancel() before completion)
           \\-------------> SHED       (admission control rejected it)

``SHED`` is terminal at submission time: the async front end's admission
control refused the request (bounded queue full, modeled memory over
budget) instead of letting it degrade everyone else's tail latency. The
shed reason travels in the request's ``error`` field and in the
``service_shed_total{reason}`` counter.
"""

from __future__ import annotations

import dataclasses
import enum
import math

from repro.core.templates import TemplateSpec

__all__ = ["RequestStatus", "CountRequest", "RequestResult", "RunningStat"]


class RequestStatus(str, enum.Enum):
    PENDING = "pending"       # submitted, not yet scheduled into a round
    RUNNING = "running"       # attached to a dispatch group, consuming samples
    DONE = "done"             # precision target met, cap reached, or cached
    FAILED = "failed"         # engine build / dispatch raised
    CANCELLED = "cancelled"   # withdrawn by the client
    SHED = "shed"             # rejected by admission control (backpressure)


@dataclasses.dataclass
class CountRequest:
    """One tenant's counting query.

    ``graph`` names a graph registered with the service (the service keys
    caches by the graph's content fingerprint, so two names for the same
    graph share everything). Precision: ``rel_stderr`` is the adaptive
    target stderr/|mean|; ``max_iters`` caps iterations (service default
    applies when None). ``min_iters`` guards against spuriously-early
    stopping on the first few lucky samples.
    """

    graph: str
    template: object          # str name | TemplateSpec | TreeTemplate | edges
    engine: str = "pgbsc"
    plan: str = "optimized"
    rel_stderr: float | None = None
    max_iters: int | None = None
    min_iters: int = 4
    seed: int = 0

    @property
    def spec(self) -> TemplateSpec:
        """The request's template as a :class:`TemplateSpec` (coerced once;
        registry names are sugar resolved here)."""
        sp = self.__dict__.get("_spec")
        if sp is None or self.__dict__.get("_spec_src") is not self.template:
            sp = TemplateSpec.of(self.template)
            self.__dict__["_spec"] = sp
            self.__dict__["_spec_src"] = self.template
        return sp

    @property
    def template_name(self) -> str:
        """Human-readable label (names when given, hash prefix otherwise)."""
        if isinstance(self.template, str):
            return self.template
        return self.spec.display_name

    def validate(self) -> None:
        self.spec.tree       # coerce + validate: unknown names raise
        #  KeyError, malformed edge lists a descriptive ValueError
        if self.rel_stderr is None and self.max_iters is None:
            raise ValueError("request needs a precision target: "
                             "rel_stderr and/or max_iters")
        if self.rel_stderr is not None and self.rel_stderr <= 0:
            raise ValueError(f"rel_stderr must be > 0, got {self.rel_stderr}")
        if self.max_iters is not None and self.max_iters < 1:
            raise ValueError(f"max_iters must be >= 1, got {self.max_iters}")

    def group_key(self, graph_fingerprint: str) -> tuple:
        """Requests sharing this key can consume one sample stream: same
        graph content, same *canonical* template (names never enter — two
        spellings of one tree share a group), engine, plan, and seed."""
        return (graph_fingerprint, self.spec.canonical_hash, self.engine,
                self.plan, self.seed)


@dataclasses.dataclass
class RequestResult:
    """Final answer for one request."""

    estimate: float
    stderr: float
    rel_stderr: float
    ci95: tuple[float, float]
    iterations: int
    target_met: bool
    from_cache: bool = False      # served by the persistent estimate cache
    shared_group: bool = False    # joined an existing dispatch group
    seconds: float = 0.0
    # per-request latency attribution (queue_s / compile_s / execute_s /
    # total_s), filled by the scheduler at retirement; None for cache hits
    breakdown: dict | None = None

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["ci95"] = list(self.ci95)
        return d


class RunningStat:
    """Welford running mean/variance over per-iteration estimator samples.

    Numerically stable single-pass accumulation; ``stderr`` is the standard
    error of the mean, ``rel_stderr`` the stopping statistic (inf until two
    samples exist or while the mean is zero, so zero-count templates run to
    their iteration cap instead of retiring on a degenerate target).
    """

    __slots__ = ("n", "mean", "_m2")

    def __init__(self):
        self.n = 0
        self.mean = 0.0
        self._m2 = 0.0

    def update(self, x: float) -> None:
        self.n += 1
        d = x - self.mean
        self.mean += d / self.n
        self._m2 += d * (x - self.mean)

    @property
    def variance(self) -> float:
        """Unbiased sample variance (ddof=1)."""
        return self._m2 / (self.n - 1) if self.n > 1 else 0.0

    @property
    def stderr(self) -> float:
        return math.sqrt(self.variance / self.n) if self.n > 1 else float("inf")

    @property
    def rel_stderr(self) -> float:
        if self.n < 2 or self.mean == 0.0:
            return float("inf")
        return self.stderr / abs(self.mean)

    @property
    def ci95(self) -> tuple[float, float]:
        se = self.stderr if self.n > 1 else 0.0
        return (self.mean - 1.96 * se, self.mean + 1.96 * se)
