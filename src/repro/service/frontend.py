"""Stdlib HTTP/JSON front end over the async counting service.

Zero-dependency (``http.server``) so the serving story ships with the
repo, not with a framework. Each request thread talks to the
:class:`~repro.service.async_loop.AsyncCountingService` through its
thread-safe ``submit``/``wait``/``result`` API; the dispatcher thread
owns all device work.

Endpoints
---------
``POST /count``
    Body is a JSON :class:`~repro.api.CountQuery` plus QoS/transport
    fields::

        {"graph": "g",
         "templates": ["u5", [[0,1],[1,2],[1,3]],
                       {"edges": [[0,1],[1,2]], "root": 0}],
         "rel_stderr": 0.1, "max_iters": 64, "seed": 0,
         "engine": "pgbsc", "plan": "optimized",
         "qos": {"class": "interactive", "tenant": "alice",
                 "weight": 2.0, "deadline_s": 5.0},
         "wait": true, "timeout_s": 60}

    Template entries may be registry names, raw edge lists, or
    ``{edges, root, name}`` dicts (everything ``TemplateSpec.of``
    accepts). One service request is submitted per template; they share
    dispatch groups/caches exactly like native requests. With
    ``wait=true`` (default) the response carries each template's result;
    with ``wait=false`` it returns request ids for later polling.
    Status 200 = all done, 202 = accepted (not waited / not finished),
    429 = every template was shed (``Retry-After`` hints backoff),
    207-style mixed outcomes report per-request status in the body.

``GET /result/<rid>``
    Status + result (or error / shed reason) for one request id.

``GET /metrics`` / ``GET /metrics.json`` / ``GET /healthz``
    Prometheus text exposition, the schema-v1 JSON metrics snapshot, and
    a liveness probe carrying queue depth, in-flight count, and the
    failure-containment state (degradation ladders, circuit breakers,
    dispatcher supervision) — a load balancer can see a degraded-but-
    alive process and route around a dead dispatcher.

Hardening
---------
Every handler error — including injected ``http.handler`` faults — is
contained to a structured 500 body ``{"error", "error_class",
"request_id"}``; the server thread pool survives. ``POST /count`` wait
times are clamped to the server's ``max_wait_s`` so no handler thread
can be parked forever by a client-supplied timeout.
"""

from __future__ import annotations

import itertools
import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from repro.api import CountQuery
from repro.obs import metrics as _metrics
from repro.resilience import faults as _faults
from repro.service.async_loop import AsyncCountingService
from repro.service.qos import QoS
from repro.service.requests import CountRequest, RequestStatus

__all__ = ["make_server", "serve_forever"]

_MAX_BODY = 4 << 20          # 4 MiB request-body cap (edge-list templates)
_DEFAULT_TIMEOUT_S = 120.0
_MAX_WAIT_S = 300.0          # hard clamp on client-requested handler waits

_REQ_IDS = itertools.count(1)


def _parse_template(obj):
    """JSON template entry -> something ``TemplateSpec.of`` accepts."""
    if isinstance(obj, dict):
        from repro.core.templates import TemplateSpec
        return TemplateSpec(edges=tuple(tuple(e) for e in obj["edges"]),
                            root=int(obj.get("root", 0)),
                            name=obj.get("name"))
    if isinstance(obj, (list, tuple)):
        return [tuple(e) for e in obj]
    return obj                       # registry name string


def _parse_qos(obj) -> QoS:
    if not obj:
        return QoS()
    return QoS(klass=obj.get("class", obj.get("klass", "interactive")),
               tenant=str(obj.get("tenant", "default")),
               weight=float(obj.get("weight", 1.0)),
               deadline_s=(None if obj.get("deadline_s") is None
                           else float(obj["deadline_s"])))


class _Handler(BaseHTTPRequestHandler):
    # the server instance carries .svc (set by make_server)
    protocol_version = "HTTP/1.1"

    # ------------------------------------------------------------- plumbing
    def log_message(self, fmt, *args):     # route through metrics, not stderr
        _metrics.counter("http_requests_total",
                         method=self.command or "?").inc()

    def _send_json(self, code: int, payload: dict,
                   extra_headers: dict | None = None) -> None:
        body = json.dumps(payload).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        for k, v in (extra_headers or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(body)

    def _send_text(self, code: int, text: str,
                   ctype: str = "text/plain; charset=utf-8") -> None:
        body = text.encode()
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    @property
    def svc(self) -> AsyncCountingService:
        return self.server.svc

    def _send_error_500(self, exc: BaseException, req_id: str) -> None:
        """Structured 500: error class + per-request id, so a client (or
        the chaos driver) can attribute failures without scraping logs."""
        _metrics.counter("http_errors_total",
                         error_class=type(exc).__name__).inc()
        try:
            self._send_json(500, {
                "error": f"{type(exc).__name__}: {exc}",
                "error_class": type(exc).__name__,
                "request_id": req_id})
        except Exception:
            pass           # client hung up mid-error; nothing left to save

    # ------------------------------------------------------------ endpoints
    def do_GET(self):
        req_id = f"h{next(_REQ_IDS):06d}"
        try:
            _faults.inject("http.handler", context=f"GET {self.path}")
            if self.path == "/healthz":
                self._get_healthz()
            elif self.path == "/metrics":
                self._send_text(200, _metrics.to_prometheus(),
                                "text/plain; version=0.0.4; charset=utf-8")
            elif self.path == "/metrics.json":
                self._send_json(200, _metrics.snapshot())
            elif self.path.startswith("/result/"):
                self._get_result(self.path[len("/result/"):])
            else:
                self._send_json(404, {"error": f"no route {self.path!r}"})
        except Exception as exc:
            self._send_error_500(exc, req_id)

    def _get_healthz(self) -> None:
        st = self.svc.stats()
        res = self.svc.resilience_state()
        dispatcher = res.get("dispatcher", {})
        # alive=False once the supervisor gave up: flip ok so a load
        # balancer stops routing here, but keep serving results/metrics
        ok = dispatcher.get("alive", True)
        self._send_json(200 if ok else 503, {
            "ok": bool(ok), "queue_depth": st["queue_depth"],
            "requests": st["requests"], "groups": st["groups"],
            "resilience": res})

    def _get_result(self, rid: str) -> None:
        try:
            status = self.svc.status(rid)
        except KeyError:
            self._send_json(404, {"error": f"unknown request {rid!r}"})
            return
        out = {"id": rid, "status": status.value}
        if status is RequestStatus.DONE:
            out["result"] = self.svc.result(rid).to_dict()
            self._send_json(200, out)
        elif status is RequestStatus.SHED:
            out["reason"] = self.svc.shed_reason(rid)
            self._send_json(429, out, {"Retry-After": "1"})
        elif status is RequestStatus.FAILED:
            st = self.svc._requests[rid]
            out["error"] = st.error
            out["error_class"] = st.error_class
            self._send_json(500, out)
        else:
            self._send_json(202, out)

    def do_POST(self):
        req_id = f"h{next(_REQ_IDS):06d}"
        if self.path != "/count":
            self._send_json(404, {"error": f"no route {self.path!r}"})
            return
        try:
            _faults.inject("http.handler", context=f"POST {self.path}")
            n = int(self.headers.get("Content-Length", 0))
            if n > _MAX_BODY:
                self._send_json(413, {"error": "body too large"})
                return
            body = json.loads(self.rfile.read(n) or b"{}")
            self._post_count(body)
        except (ValueError, KeyError, TypeError) as exc:
            self._send_json(400, {"error": f"{type(exc).__name__}: {exc}",
                                  "error_class": type(exc).__name__,
                                  "request_id": req_id})
        except Exception as exc:
            self._send_error_500(exc, req_id)

    def _post_count(self, body: dict) -> None:
        graph = body.get("graph", "g")
        tpls = body.get("templates", body.get("template"))
        if tpls is None:
            raise ValueError("body needs 'templates' (or 'template')")
        if isinstance(tpls, str) or not isinstance(tpls, list) \
                or (tpls and isinstance(tpls[0], (int, float))):
            tpls = [tpls]
        # validate + coerce through the first-class query API: bad
        # templates/contracts fail here with a 400, before any submit
        query = CountQuery(
            templates=tuple(_parse_template(t) for t in tpls),
            rel_stderr=body.get("rel_stderr"),
            max_iters=body.get("max_iters"),
            min_iters=int(body.get("min_iters", 4)),
            seed=int(body.get("seed", 0)),
            engine=body.get("engine", "pgbsc"),
            plan=body.get("plan", "optimized"))
        query.validate()
        qos = _parse_qos(body.get("qos"))
        rids = [self.svc.submit(CountRequest(
            graph=graph, template=spec, engine=query.engine,
            plan=query.plan, rel_stderr=query.rel_stderr,
            max_iters=query.max_iters, min_iters=query.min_iters,
            seed=query.seed), qos=qos) for spec in query.templates]
        if body.get("wait", True):
            # clamp: a client cannot park a handler thread past the
            # server's budget — unfinished work polls via /result/<rid>
            wait_s = min(float(body.get("timeout_s", _DEFAULT_TIMEOUT_S)),
                         getattr(self.server, "max_wait_s", _MAX_WAIT_S))
            self.svc.wait(rids, wait_s)
        out, n_done, n_shed = [], 0, 0
        for rid in rids:
            status = self.svc.status(rid)
            ent = {"id": rid, "status": status.value}
            if status is RequestStatus.DONE:
                ent["result"] = self.svc.result(rid).to_dict()
                n_done += 1
            elif status is RequestStatus.SHED:
                ent["reason"] = self.svc.shed_reason(rid)
                n_shed += 1
            elif status is RequestStatus.FAILED:
                ent["error"] = self.svc._requests[rid].error
                ent["error_class"] = self.svc._requests[rid].error_class
            out.append(ent)
        if n_shed == len(rids):
            self._send_json(429, {"requests": out}, {"Retry-After": "1"})
        elif n_done == len(rids):
            self._send_json(200, {"requests": out})
        else:
            self._send_json(202, {"requests": out})


def make_server(svc: AsyncCountingService, host: str = "127.0.0.1",
                port: int = 8080,
                max_wait_s: float = _MAX_WAIT_S) -> ThreadingHTTPServer:
    """A ready-to-run threaded HTTP server bound to (host, port); the
    caller owns ``serve_forever``/``shutdown`` (and the service's
    ``start``/``close``). ``max_wait_s`` clamps client-requested
    ``POST /count`` waits (handler-thread containment)."""
    httpd = ThreadingHTTPServer((host, port), _Handler)
    httpd.daemon_threads = True
    httpd.svc = svc
    httpd.max_wait_s = float(max_wait_s)
    return httpd


def serve_forever(svc: AsyncCountingService, host: str = "127.0.0.1",
                  port: int = 8080,
                  max_wait_s: float = _MAX_WAIT_S) -> ThreadingHTTPServer:
    """Start the dispatcher + HTTP server on a daemon thread; returns the
    server (``.shutdown()`` to stop)."""
    svc.start()
    httpd = make_server(svc, host, port, max_wait_s=max_wait_s)
    t = threading.Thread(target=httpd.serve_forever,
                         name="pgbsc-http", daemon=True)
    t.start()
    return httpd
