"""QoS primitives for the async serving front end.

Three request classes, in strict priority order at dispatch boundaries:

* ``deadline`` — carries an absolute deadline; scheduled earliest-
  deadline-first *ahead of everything else*. Preemption is at dispatch
  boundaries: a running batch group is never killed mid-dispatch, but the
  next round always goes to the most urgent deadline group first.
* ``interactive`` — latency-sensitive best effort; always dispatched
  before batch work.
* ``batch`` — throughput traffic; absorbs whatever device time the two
  classes above leave.

Within ``interactive`` and ``batch``, tenants share the device by
**weighted fair queuing**: each tenant accrues virtual time
``work / weight`` per dispatch, and the group whose tenants have the
least virtual time goes next — a tenant with weight 2 gets twice the
dispatch share of a weight-1 tenant under contention, and an idle
tenant's unused share is redistributed instead of banked (newcomers
start at the current virtual-time floor, so nobody replays history).

Admission control is a bounded FIFO (:class:`AdmissionQueue`): when the
queue is full the request is **shed** — rejected immediately with a
reason (``queue_full``) instead of silently growing an unbounded backlog
whose tail latency is everyone's problem. The async loop sheds for
modeled-memory overruns the same way (``memory_budget``); shed reasons
are the labels on the ``service_shed_total`` counter.
"""

from __future__ import annotations

import dataclasses
import enum
import threading
from typing import Iterable

from repro.obs import metrics as _metrics

__all__ = [
    "QoSClass", "QoS", "GroupView", "FairScheduler", "AdmissionQueue",
    "SHED_QUEUE_FULL", "SHED_MEMORY", "SHED_CLOSED",
    "DEFAULT_DEADLINE_S",
]

# shed reasons (the ``reason`` label of ``service_shed_total``)
SHED_QUEUE_FULL = "queue_full"
SHED_MEMORY = "memory_budget"
SHED_CLOSED = "closed"

# a ``deadline`` request that names no deadline gets this budget
DEFAULT_DEADLINE_S = 30.0


class QoSClass(str, enum.Enum):
    DEADLINE = "deadline"
    INTERACTIVE = "interactive"
    BATCH = "batch"

    @property
    def rank(self) -> int:
        """Strict dispatch priority; lower dispatches first."""
        return _RANK[self]


_RANK = {QoSClass.DEADLINE: 0, QoSClass.INTERACTIVE: 1, QoSClass.BATCH: 2}


@dataclasses.dataclass(frozen=True)
class QoS:
    """One request's service contract: class, tenant identity for fair
    sharing, tenant weight, and (deadline class) a relative deadline in
    seconds from submission."""

    klass: QoSClass = QoSClass.INTERACTIVE
    tenant: str = "default"
    weight: float = 1.0
    deadline_s: float | None = None

    def __post_init__(self):
        object.__setattr__(self, "klass", QoSClass(self.klass))
        if self.weight <= 0:
            raise ValueError(f"weight must be > 0, got {self.weight}")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError(
                f"deadline_s must be > 0, got {self.deadline_s}")
        if self.klass is QoSClass.DEADLINE and self.deadline_s is None:
            object.__setattr__(self, "deadline_s", DEFAULT_DEADLINE_S)


@dataclasses.dataclass
class GroupView:
    """What the dispatcher tells the policy about one dispatchable group:
    the best (lowest-rank) class among its live members, the earliest
    absolute deadline any member carries (inf when none), and the
    ``(tenant, weight)`` pairs of its live members."""

    key: object
    rank: int
    deadline: float
    tenants: tuple[tuple[str, float], ...]


class FairScheduler:
    """Pick the next group to dispatch: strict class priority, EDF inside
    the deadline class, weighted fair queuing across tenants inside the
    other classes. Stateful only in per-tenant virtual time."""

    def __init__(self):
        self._vt: dict[str, float] = {}

    def _floor(self) -> float:
        return min(self._vt.values(), default=0.0)

    def pick(self, groups: list[GroupView]) -> GroupView:
        """The next group to dispatch (``groups`` must be non-empty). Ties
        resolve to the earliest-listed group, so callers listing groups in
        creation order get FIFO among equals."""
        # SFQ activity accounting: only tenants with backlogged work keep
        # virtual-time standing. A tenant absent from every dispatchable
        # group is idle — it drops out and rejoins at the then-current
        # floor, so idle time is redistributed, never banked. Present
        # tenants keep their vt (a starved tenant's low vt is exactly its
        # claim to the next dispatch).
        present = {t for gv in groups for t, _ in gv.tenants}
        self._vt = {t: v for t, v in self._vt.items() if t in present}
        floor = self._floor()
        for t in present:
            self._vt.setdefault(t, floor)

        def urgency(gv: GroupView):
            vt = min((self._vt[t] for t, _ in gv.tenants), default=floor)
            if gv.rank == QoSClass.DEADLINE.rank:
                return (gv.rank, gv.deadline, vt)
            return (gv.rank, vt, gv.deadline)

        return min(groups, key=urgency)

    def charge(self, tenants: Iterable[tuple[str, float]],
               cost: float) -> None:
        """Account one dispatch of ``cost`` work units (iterations) to the
        group's live tenants: the cost splits evenly across members and
        each tenant's virtual time advances by its share over its weight.
        Newly-seen tenants start at the current floor — idle time earns no
        banked credit."""
        ts = list(tenants)
        if not ts:
            return
        floor = self._floor()
        share = cost / len(ts)
        for tenant, weight in ts:
            base = max(self._vt.get(tenant, floor), floor)
            self._vt[tenant] = base + share / max(weight, 1e-9)

    def virtual_times(self) -> dict[str, float]:
        """Per-tenant virtual time (introspection / tests)."""
        return dict(self._vt)


class AdmissionQueue:
    """Bounded FIFO with reject-on-full backpressure.

    :meth:`offer` never blocks: it either enqueues and returns None, or
    returns a shed reason (``queue_full``). The dispatcher drains with
    :meth:`drain`. Depth is published as the ``service_queue_depth``
    gauge; admissions count into ``service_queue_admitted_total``.
    """

    def __init__(self, maxsize: int = 1024):
        if maxsize < 1:
            raise ValueError(f"maxsize must be >= 1, got {maxsize}")
        self.maxsize = int(maxsize)
        self._items: list = []
        self._lock = threading.Lock()

    def offer(self, item) -> str | None:
        with self._lock:
            if len(self._items) >= self.maxsize:
                return SHED_QUEUE_FULL
            self._items.append(item)
            depth = len(self._items)
        _metrics.counter("service_queue_admitted_total").inc()
        _metrics.gauge("service_queue_depth").set(depth)
        return None

    def drain(self) -> list:
        with self._lock:
            items, self._items = self._items, []
        if items:
            _metrics.gauge("service_queue_depth").set(0)
        return items

    def contents(self) -> list:
        """Queued items, oldest first, without draining (supervision uses
        this to tell drained-but-unattached requests from queued ones)."""
        with self._lock:
            return list(self._items)

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)
