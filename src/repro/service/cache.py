"""Engine/plan and estimate caches for the counting service.

Engine builds are the expensive fixed cost of a request: SpMM preparation
walks the whole edge set and the first dispatch pays jit compilation. The
:class:`EngineCache` keys built engines by
``(graph fingerprint, template, engine, plan, build options)`` so repeated
and concurrent requests never rebuild or recompile — the graph's *content*
hash (``Graph.fingerprint``) is the key component, so two differently-named
registrations of the same graph still share one engine.

The :class:`EstimateCache` persists *answers* (estimate, stderr, iteration
count) keyed by the same identity plus the coloring seed, as a JSON file
that is atomically replaced on update. A new service process can serve a
repeat query straight from it — without even building an engine — whenever
the cached precision already meets the request's target.
"""

from __future__ import annotations

import json
import os
from collections import OrderedDict

from repro.core import build_engine, get_template
from repro.core.engines import CountingEngine
from repro.graph.structure import Graph

__all__ = ["EngineCache", "EstimateCache"]


DEFAULT_MAX_ENTRIES = 8


class EngineCache:
    """LRU cache of built :class:`CountingEngine` instances.

    ``max_entries`` bounds resident engines — each holds device-side graph
    formats and compiled executables, so an unbounded cache is an unbounded
    device-memory leak under multi-tenant traffic. The default keeps 8;
    pass ``None`` explicitly for the old unbounded behavior. Eviction calls
    the engine's :meth:`~repro.core.engines.CountingEngine.release`, which
    actually drops its device arrays and clears its jitted executables (an
    evicted engine that a caller still holds rebuilds lazily on next use).
    ``hits`` / ``misses`` count lookups, ``builds`` counts constructions,
    ``evictions`` counts released engines — the service surfaces these so
    "no second engine build" and "bounded residency" are both observable.
    """

    def __init__(self, max_entries: int | None = DEFAULT_MAX_ENTRIES):
        self.max_entries = max_entries
        self._engines: OrderedDict[tuple, CountingEngine] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.builds = 0
        self.evictions = 0

    @staticmethod
    def key(g: Graph, template: str, engine: str, plan: str,
            **build_kw) -> tuple:
        return (g.fingerprint, template, engine, plan,
                tuple(sorted(build_kw.items())))

    def get(self, g: Graph, template: str, engine: str = "pgbsc",
            plan: str = "optimized", **build_kw) -> CountingEngine:
        k = self.key(g, template, engine, plan, **build_kw)
        if k in self._engines:
            self.hits += 1
            self._engines.move_to_end(k)
            return self._engines[k]
        self.misses += 1
        eng = build_engine(g, get_template(template), engine, plan=plan,
                           **build_kw)
        self.builds += 1
        self._engines[k] = eng
        if self.max_entries is not None:
            while len(self._engines) > self.max_entries:
                _, old = self._engines.popitem(last=False)
                if hasattr(old, "release"):
                    old.release()
                self.evictions += 1
        return eng

    def resident_ids(self) -> set[int]:
        """``id()`` of cache-managed engine objects — the set whose device
        residency ``max_entries`` bounds (used by the service to avoid
        releasing engines that are still cache-warm)."""
        return {id(e) for e in self._engines.values()}

    def __len__(self) -> int:
        return len(self._engines)

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "builds": self.builds, "evictions": self.evictions,
                "resident": len(self._engines)}


class EstimateCache:
    """Persistent map from request identity to a finished estimate.

    Entries: ``{estimate, stderr, rel_stderr, iterations}``. ``path=None``
    keeps the cache in-memory (tests / ephemeral services). Writes replace
    the JSON file atomically, matching the runner-ledger durability story.
    """

    def __init__(self, path: str | None = None):
        self.path = path
        self._mem: dict[str, dict] = {}
        if path and os.path.isfile(path):
            try:
                with open(path) as f:
                    self._mem = json.load(f)
            except (OSError, json.JSONDecodeError):
                self._mem = {}

    @staticmethod
    def key(graph_fingerprint: str, template: str, engine: str, plan: str,
            seed: int) -> str:
        return f"{graph_fingerprint}:{template}:{engine}:{plan}:s{seed}"

    def get(self, key: str) -> dict | None:
        return self._mem.get(key)

    def satisfies(self, key: str, rel_stderr: float | None,
                  max_iters: int | None, min_iters: int = 0) -> dict | None:
        """The cached entry, if it already meets the request's precision
        contract (at least as tight a rel stderr AND at least ``min_iters``
        samples — the same early-stop guard the scheduler enforces; at
        least as many iterations as a pure iteration-cap request would
        run)."""
        ent = self._mem.get(key)
        if ent is None:
            return None
        if rel_stderr is not None:
            ok = (ent["rel_stderr"] <= rel_stderr
                  and ent["iterations"] >= min_iters)
            return ent if ok else None
        return ent if ent["iterations"] >= (max_iters or 0) else None

    def put(self, key: str, entry: dict) -> None:
        self._mem[key] = entry
        if self.path:
            os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
            tmp = self.path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(self._mem, f)
            os.replace(tmp, self.path)

    def __len__(self) -> int:
        return len(self._mem)
