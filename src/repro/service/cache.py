"""Engine/plan and estimate caches for the counting service.

Engine builds are the expensive fixed cost of a request: SpMM preparation
walks the whole edge set and the first dispatch pays jit compilation. The
:class:`EngineCache` keys built engines by
``(graph fingerprint, template canonical hash, engine, plan, build
options)`` so repeated and concurrent requests never rebuild or recompile —
*content* hashes on both axes: the graph's ``Graph.fingerprint`` and the
template's ``canonical_hash``, so two differently-named registrations of
the same graph AND two spellings of the same tree (registry name vs. raw
edge list, relabeled vertices) still share one engine. A list of same-k
templates keys a fused multi-template engine the same way (joined hashes).

The :class:`EstimateCache` persists *answers* (estimate, stderr, iteration
count) keyed by the same identity plus the coloring seed, as a JSON file
that is atomically replaced on update. A new service process can serve a
repeat query straight from it — without even building an engine — whenever
the cached precision already meets the request's target. The file carries
a ``schema`` version: entries written before the canonical-hash keying
(version < 2 keyed by template *names*) are ignored on load — never
crashed on — so a stale name key can't alias a canonical-hash key.
"""

from __future__ import annotations

import contextlib
import json
import os
import tempfile
import threading
from collections import OrderedDict

try:                              # POSIX advisory file lock; absent on
    import fcntl                  # platforms where flock is unavailable
except ImportError:               # (the cache degrades to atomic-replace-
    fcntl = None                  # only, which is still torn-write-safe)

from repro.core import build_engine
from repro.core.engines import CountingEngine
from repro.core.templates import TemplateSpec, as_template
from repro.graph.structure import Graph
from repro.obs import metrics as _metrics
from repro.obs import tracing as _tracing
from repro.resilience import faults as _faults
from repro.resilience import recovery as _recovery

__all__ = ["EngineCache", "EstimateCache", "SCHEMA_VERSION"]


DEFAULT_MAX_ENTRIES = 8

# estimate-cache file schema; bumped when key semantics change (v2: keys
# carry template canonical hashes instead of registry names)
SCHEMA_VERSION = 2


def _template_key(template) -> str:
    """Canonical-hash key component for one template or a fused bundle."""
    if isinstance(template, (list, tuple)):
        return "+".join(TemplateSpec.of(t).canonical_hash for t in template)
    return TemplateSpec.of(template).canonical_hash


def _template_build_arg(template):
    """What build_engine receives: TreeTemplate(s), warm caches preserved."""
    if isinstance(template, (list, tuple)):
        return [as_template(t) for t in template]
    return as_template(template)


class EngineCache:
    """LRU cache of built :class:`CountingEngine` instances.

    ``max_entries`` bounds resident engines — each holds device-side graph
    formats and compiled executables, so an unbounded cache is an unbounded
    device-memory leak under multi-tenant traffic. The default keeps 8;
    pass ``None`` explicitly for the old unbounded behavior. Eviction calls
    the engine's :meth:`~repro.core.engines.CountingEngine.release`, which
    actually drops its device arrays and clears its jitted executables (an
    evicted engine that a caller still holds rebuilds lazily on next use).
    ``hits`` / ``misses`` count lookups, ``builds`` counts constructions,
    ``evictions`` counts released engines — the service surfaces these so
    "no second engine build" and "bounded residency" are both observable.
    """

    def __init__(self, max_entries: int | None = DEFAULT_MAX_ENTRIES):
        self.max_entries = max_entries
        self._engines: OrderedDict[tuple, CountingEngine] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.builds = 0
        self.evictions = 0

    @staticmethod
    def key(g: Graph, template, engine: str, plan: str,
            **build_kw) -> tuple:
        # None-valued options mean "engine default" and must alias the
        # absent spelling (reorder=None == no reorder kwarg); dtype-like
        # values key by name so np.float32/jnp.float32 spellings collide
        opts = tuple(sorted(
            (k, getattr(v, "__name__", None) or str(v))
            for k, v in build_kw.items() if v is not None))
        return (g.fingerprint, _template_key(template), engine, plan, opts)

    def get(self, g: Graph, template, engine: str = "pgbsc",
            plan: str = "optimized", **build_kw) -> CountingEngine:
        """``template``: name / TemplateSpec / TreeTemplate / edge list, or
        a list of them (equal k) for a fused multi-template engine."""
        k = self.key(g, template, engine, plan, **build_kw)
        if k in self._engines:
            self.hits += 1
            _metrics.counter("engine_cache_lookups_total",
                             result="hit").inc()
            self._engines.move_to_end(k)
            return self._engines[k]
        self.misses += 1
        _metrics.counter("engine_cache_lookups_total", result="miss").inc()
        _faults.inject("engine.build",
                       context=f"{g.fingerprint[:12]}:{engine}:{plan}")
        with _tracing.span("engine_cache.build", engine=engine, plan=plan):
            eng = build_engine(g, _template_build_arg(template), engine,
                               plan=plan, **build_kw)
        self.builds += 1
        _metrics.counter("engine_cache_builds_total").inc()
        self._engines[k] = eng
        if self.max_entries is not None:
            while len(self._engines) > self.max_entries:
                _, old = self._engines.popitem(last=False)
                if hasattr(old, "release"):
                    old.release()
                self.evictions += 1
                _metrics.counter("engine_cache_evictions_total").inc()
        return eng

    def has(self, g: Graph, template, engine: str = "pgbsc",
            plan: str = "optimized", **build_kw) -> bool:
        """Whether this exact engine is cache-resident — a pure probe: no
        build, no LRU refresh (the async warm pool uses it to decide what
        to pre-materialize without perturbing eviction order)."""
        return self.key(g, template, engine, plan, **build_kw) \
            in self._engines

    def resident_ids(self) -> set[int]:
        """``id()`` of cache-managed engine objects — the set whose device
        residency ``max_entries`` bounds (used by the service to avoid
        releasing engines that are still cache-warm)."""
        return {id(e) for e in self._engines.values()}

    def __len__(self) -> int:
        return len(self._engines)

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "builds": self.builds, "evictions": self.evictions,
                "resident": len(self._engines)}


class EstimateCache:
    """Persistent map from request identity to a finished estimate.

    Entries: ``{estimate, stderr, rel_stderr, iterations}``. ``path=None``
    keeps the cache in-memory (tests / ephemeral services). The on-disk
    form is ``{"schema": SCHEMA_VERSION, "crc": ..., "entries": {...}}``;
    files with a different (or missing — pre-versioning) schema are
    silently treated as empty, because their keys used template *names*
    and must not alias today's canonical-hash keys. Unparseable or
    CRC-failing files (torn writes, disk corruption) are quarantined to a
    ``.corrupt`` sidecar and the cache starts cold — see
    :mod:`repro.resilience.recovery`.

    **Concurrency.** The cache is safe for concurrent writers — both the
    async front end's threads inside one process and independent service
    processes sharing one file:

    * every write goes to a uniquely-named temp file in the target
      directory and lands via ``os.replace`` — a crashed or preempted
      writer can tear its temp file, never the cache;
    * the whole read-modify-write is serialized under an exclusive
      ``flock`` on a ``<path>.lock`` sidecar (plus an in-process mutex),
      and *merges* with the entries on disk before replacing — two
      processes writing disjoint keys both survive, and for a contended
      key the entry with more iterations wins (the same
      keep-the-tighter-answer policy the scheduler applies).
    """

    def __init__(self, path: str | None = None):
        self.path = path
        self._mem: dict[str, dict] = {}
        self._tlock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.writes = 0
        self.invalidations = 0
        if path:
            with self._file_lock():
                self._mem = self._read_disk()

    # ------------------------------------------------------- file locking
    @contextlib.contextmanager
    def _file_lock(self):
        """Exclusive advisory lock on ``<path>.lock`` (no-op when the cache
        is memory-only or flock is unavailable)."""
        if not self.path or fcntl is None:
            yield
            return
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        with open(self.path + ".lock", "a+") as lf:
            fcntl.flock(lf, fcntl.LOCK_EX)
            try:
                yield
            finally:
                fcntl.flock(lf, fcntl.LOCK_UN)

    def _read_disk(self) -> dict[str, dict]:
        """Entries currently on disk (empty on stale schema / unreadable /
        missing / torn file — discarded, never crashed on).

        A file that fails to parse or fails its CRC — a ``kill -9``
        mid-write predating the tmp+replace protocol, disk corruption, an
        injected ``cache.read`` fault — is quarantined to a ``.corrupt``
        sidecar and the cache continues cold: corruption must never raise
        into the admission path."""
        if not self.path or not os.path.isfile(self.path):
            return {}
        try:
            _faults.inject("cache.read", context=self.path)
            with open(self.path) as f:
                data = json.load(f)
        except Exception:
            _recovery.quarantine(self.path, kind="estimate_cache",
                                 reason="read")
            self.invalidations += 1
            _metrics.counter("estimate_cache_invalidations_total",
                             reason="corrupt").inc()
            return {}
        if (isinstance(data, dict)
                and data.get("schema") == SCHEMA_VERSION
                and isinstance(data.get("entries"), dict)):
            if "crc" in data and \
                    _recovery.payload_crc(data["entries"]) != data["crc"]:
                _recovery.quarantine(self.path, kind="estimate_cache",
                                     reason="crc")
                self.invalidations += 1
                _metrics.counter("estimate_cache_invalidations_total",
                                 reason="corrupt").inc()
                return {}
            return data["entries"]
        self.invalidations += 1
        _metrics.counter("estimate_cache_invalidations_total",
                         reason="schema").inc()
        return {}

    @staticmethod
    def _merge(into: dict[str, dict], new: dict[str, dict]) -> dict:
        """Overlay ``new`` on ``into``; on key conflict the entry with more
        iterations wins (ties keep ``new``)."""
        for k, ent in new.items():
            prev = into.get(k)
            if prev is None or prev.get("iterations", 0) <= \
                    ent.get("iterations", 0):
                into[k] = ent
        return into

    @staticmethod
    def key(graph_fingerprint: str, template, engine: str, plan: str,
            seed: int) -> str:
        """``template`` may be anything :meth:`TemplateSpec.of` accepts;
        the key always carries its canonical hash."""
        th = _template_key(template)
        return f"{graph_fingerprint}:{th}:{engine}:{plan}:s{seed}"

    def get(self, key: str) -> dict | None:
        return self._mem.get(key)

    def satisfies(self, key: str, rel_stderr: float | None,
                  max_iters: int | None, min_iters: int = 0) -> dict | None:
        """The cached entry, if it already meets the request's precision
        contract (at least as tight a rel stderr AND at least ``min_iters``
        samples — the same early-stop guard the scheduler enforces; at
        least as many iterations as a pure iteration-cap request would
        run)."""
        ent = self._satisfies(key, rel_stderr, max_iters, min_iters)
        if ent is None:
            self.misses += 1
            _metrics.counter("estimate_cache_lookups_total",
                             result="miss").inc()
        else:
            self.hits += 1
            _metrics.counter("estimate_cache_lookups_total",
                             result="hit").inc()
        return ent

    def _satisfies(self, key, rel_stderr, max_iters, min_iters):
        ent = self._mem.get(key)
        if ent is None:
            return None
        if rel_stderr is not None:
            ok = (ent["rel_stderr"] <= rel_stderr
                  and ent["iterations"] >= min_iters)
            return ent if ok else None
        return ent if ent["iterations"] >= (max_iters or 0) else None

    def put(self, key: str, entry: dict) -> None:
        with self._tlock:
            self._merge(self._mem, {key: entry})
            self.writes += 1
            _metrics.counter("estimate_cache_writes_total").inc()
            if not self.path:
                return
            with self._file_lock():
                # merge with what concurrent writers already landed, so
                # interleaved puts from other threads/processes are never
                # lost — then replace atomically via a unique temp file
                self._mem = self._merge(self._read_disk(), self._mem)
                d = os.path.dirname(self.path) or "."
                os.makedirs(d, exist_ok=True)
                fd, tmp = tempfile.mkstemp(
                    dir=d, prefix=os.path.basename(self.path) + ".")
                try:
                    with os.fdopen(fd, "w") as f:
                        json.dump({"schema": SCHEMA_VERSION,
                                   "crc": _recovery.payload_crc(self._mem),
                                   "entries": self._mem}, f)
                    os.replace(tmp, self.path)
                except BaseException:
                    with contextlib.suppress(OSError):
                        os.unlink(tmp)
                    raise

    def __len__(self) -> int:
        return len(self._mem)

    def stats(self) -> dict:
        """Same contract as :meth:`EngineCache.stats`: lookup hits/misses
        (``satisfies`` calls — the serve-from-cache decision point),
        writes, schema invalidations, and resident entry count."""
        return {"hits": self.hits, "misses": self.misses,
                "writes": self.writes,
                "invalidations": self.invalidations,
                "resident": len(self._mem)}
