"""Multi-tenant subgraph-counting service.

This package is the serving layer above the color-coding engines: many
tenants submit counting queries against registered graphs, and a
round-based scheduler answers all of them with the fewest possible device
dispatches. It exists because the estimator's unit of work — one coloring
iteration — is small, deterministic, and embarrassingly parallel, so the
interesting systems problem is *scheduling and reuse*, not the kernel.

Request lifecycle
-----------------
1. **Register** graphs: ``service.add_graph("web", g)``. Cache identity is
   the graph's content fingerprint, never its name.
2. **Submit** a :class:`~repro.service.requests.CountRequest` — template,
   engine/plan choice, and a precision contract (``rel_stderr`` target
   and/or ``max_iters`` cap). The request starts ``PENDING``; if the
   persistent estimate cache already holds an answer at least as precise
   as the contract, it completes ``DONE`` immediately with
   ``from_cache=True``.
3. **Schedule**: each :meth:`~repro.service.scheduler.CountingService.step`
   round attaches pending requests to dispatch groups keyed by
   ``(graph fingerprint, template, engine, plan, seed)`` (status
   ``RUNNING``). Engines come from the
   :class:`~repro.service.cache.EngineCache`, so concurrent and repeated
   requests never rebuild or recompile; group members share ONE sample
   stream, so N identical queries cost one query's device work.
4. **Adapt**: every round extends each needed group by ``round_size``
   iterations in a single batched device dispatch, journaled through the
   fault-tolerant runner ledger (kill the process, restart, and the group
   resumes with zero recomputation). Each request folds samples into a
   Welford running mean and retires ``DONE`` as soon as its relative
   standard error meets its target — tight targets run longer, loose ones
   stop early, and nobody burns a fixed iteration budget.
5. **Collect**: results carry the estimate, standard error, 95% confidence
   interval, iterations consumed, and cache/sharing provenance. Finished
   answers feed the estimate cache for future tenants. ``FAILED`` (bad
   engine / build error) and ``CANCELLED`` are the other terminal states.

Two front ends share this machinery:

* :class:`~repro.service.scheduler.CountingService` — the synchronous
  round scheduler (`run()`), right for offline batch jobs where all
  requests are known up front;
* :class:`~repro.service.async_loop.AsyncCountingService` — a
  continuously-admitting dispatcher thread with QoS classes
  (interactive / batch / deadline), per-tenant weighted fairness,
  bounded-queue backpressure with load shedding (``SHED``), and warm
  engine pools; `repro.service.frontend` puts an HTTP/JSON API on top.
  Estimates are bitwise-identical between the two (samples are
  deterministic functions of ``(seed, iteration id)``).

Typical use::

    from repro.service import CountingService, CountRequest

    svc = CountingService(round_size=16)
    svc.add_graph("g", g)
    ids = [svc.submit(CountRequest("g", t, rel_stderr=0.05))
           for t in ("u5", "u7", "u5")]
    for rid, res in svc.run().items():
        print(rid, res.estimate, "+-", res.stderr, res.ci95)
"""

from repro.service.async_loop import AsyncCountingService
from repro.service.cache import EngineCache, EstimateCache
from repro.service.qos import AdmissionQueue, FairScheduler, QoS, QoSClass
from repro.service.requests import (CountRequest, RequestResult,
                                    RequestStatus, RunningStat)
from repro.service.scheduler import CountingService

__all__ = [
    "CountingService", "AsyncCountingService",
    "CountRequest", "RequestResult", "RequestStatus", "RunningStat",
    "EngineCache", "EstimateCache",
    "QoS", "QoSClass", "FairScheduler", "AdmissionQueue",
]
