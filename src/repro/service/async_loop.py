"""Continuously-admitting async serving loop over the counting engines.

:class:`AsyncCountingService` replaces the round barrier of
:class:`~repro.service.scheduler.CountingService` with a dispatcher
thread that runs for the life of the service: requests are admitted at
any time from any thread, joined into in-flight dispatch groups between
iterations, and scheduled by QoS class. It *reuses* the round
scheduler's group machinery (``_Group`` sample streams, Welford
consumption, retire-at-target) — every sample is a deterministic
function of ``(seed, iteration id)``, so an async request's estimate is
bitwise-identical to what the synchronous round scheduler would have
produced for the same request.

What the async loop adds on top of the base scheduler:

* **Continuous admission** — :meth:`submit` is thread-safe and never
  blocks on device work; cold engine builds happen on the dispatcher
  thread *outside* the admission lock, so a compile never stalls intake.
* **QoS dispatch order** — at every dispatch boundary the policy
  (:class:`~repro.service.qos.FairScheduler`) picks ONE group:
  deadline-class work earliest-deadline-first ahead of everything,
  interactive before batch, weighted fair queuing across tenants within
  a class. Contrast the round barrier, which extends *all* groups every
  round and makes interactive tail latency a function of total load.
* **Backpressure** — a bounded admission queue; when it is full the
  request is rejected with status ``SHED`` (reason ``queue_full``)
  instead of joining an unbounded backlog. Requests whose modeled memory
  (the executor's :func:`~repro.core.executor.pick_execution`) cannot
  fit the service budget even with colorset chunking are shed at
  admission (``memory_budget``) — before any engine build is wasted.
* **Warm engine pools** — popular ``(graph, template)`` pairs are
  pre-materialized through the shared :class:`EngineCache` whenever the
  dispatcher is idle (plus an explicit :meth:`prewarm` API), so a cold
  build+compile lands on idle time, not on an interactive request.

Metrics: ``service_queue_depth`` / ``service_queue_admitted_total``,
``service_shed_total{reason}``, ``service_inflight_requests``, per-class
``service_request_total_seconds{qos}`` / ``service_request_queue_seconds
{qos}`` histograms, ``service_async_requests_total{status,qos}``,
``service_deadline_total{outcome}``, ``service_warm_builds_total``.

Typical use::

    svc = AsyncCountingService(max_queue_depth=512)
    svc.add_graph("g", g)
    with svc:                                   # starts the dispatcher
        rid = svc.submit(CountRequest("g", "u5", rel_stderr=0.05),
                         qos=QoS(klass="interactive", tenant="alice"))
        res = svc.result(rid, timeout=30.0)
"""

from __future__ import annotations

import threading
import time

from repro.core import executor as pexec
from repro.obs import metrics as _metrics
from repro.resilience import faults as _faults
from repro.resilience.retry import RetryPolicy
from repro.service.qos import (SHED_CLOSED, SHED_MEMORY, AdmissionQueue,
                               FairScheduler, GroupView, QoS, QoSClass)
from repro.service.requests import CountRequest, RequestResult, RequestStatus
from repro.service.scheduler import CountingService, _Group, _ReqState

__all__ = ["AsyncCountingService", "DispatcherDead", "TERMINAL_STATUSES"]

TERMINAL_STATUSES = frozenset((
    RequestStatus.DONE, RequestStatus.FAILED,
    RequestStatus.CANCELLED, RequestStatus.SHED))


class DispatcherDead(RuntimeError):
    """The dispatcher thread crashed past its restart budget; live
    requests are failed with this so nothing waits forever."""

    def __init__(self, crashes: int, cause: BaseException):
        self.crashes = crashes
        self.cause = cause
        super().__init__(
            f"dispatcher dead after {crashes} crashes "
            f"(last: {type(cause).__name__}: {cause})")


class AsyncCountingService(CountingService):
    """Continuously-admitting, QoS-aware counting service (module
    docstring has the full narrative).

    Parameters beyond :class:`CountingService`:

    max_queue_depth:
        Bound on requests admitted but not yet attached; a full queue
        sheds (status ``SHED``, reason ``queue_full``).
    shed_on_memory:
        Shed requests whose modeled peak memory cannot fit
        ``memory_budget_bytes`` even chunked (reason ``memory_budget``).
    warm_pool:
        Pre-materialize popular (graph, template) engines on idle
        dispatcher time (and honor :meth:`prewarm` hints).
    idle_wait_s:
        Dispatcher sleep granularity when there is nothing to do.
    max_dispatcher_restarts:
        Failure containment for the dispatcher thread itself: an
        unhandled exception escaping the loop restarts it (after
        re-queueing any drained-but-unattached requests) up to this many
        times; past the budget, every live request is failed with a
        structured ``DispatcherDead`` error and the service stops
        admitting — admitted requests always reach a terminal status,
        never orphaned limbo.
    """

    def __init__(self, *, max_queue_depth: int = 1024,
                 shed_on_memory: bool = True, warm_pool: bool = True,
                 idle_wait_s: float = 0.05,
                 max_dispatcher_restarts: int = 3, **kw):
        # async dispatches default to a wall-clock watchdog: a hung device
        # call must not freeze the only dispatcher thread forever
        if kw.get("retry_policy") is None:
            kw["retry_policy"] = RetryPolicy(timeout_s=120.0)
        super().__init__(**kw)
        self._lock = threading.RLock()
        self._cv = threading.Condition(self._lock)
        self._queue = AdmissionQueue(max_queue_depth)
        self._policy = FairScheduler()
        self._qos: dict[str, QoS] = {}
        self._deadline_abs: dict[str, float] = {}
        self._retire_order: list[str] = []
        self.shed_on_memory = shed_on_memory
        self.warm_pool = warm_pool
        self.idle_wait_s = float(idle_wait_s)
        self._fits_memo: dict[tuple, bool] = {}
        self._warm_hints: list[tuple] = []
        self._popularity: dict[tuple, tuple[int, tuple]] = {}
        self._thread: threading.Thread | None = None
        self._running = False
        self._closed = False
        self.max_dispatcher_restarts = int(max_dispatcher_restarts)
        self._dispatcher_crashes = 0

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "AsyncCountingService":
        """Start the supervised dispatcher thread (idempotent)."""
        with self._cv:
            if self._thread is not None and self._thread.is_alive():
                return self
            self._running = True
            self._closed = False
            self._dispatcher_crashes = 0
            self._thread = threading.Thread(
                target=self._supervise, name="pgbsc-async-dispatcher",
                daemon=True)
            self._thread.start()
        return self

    def close(self, timeout: float | None = 30.0) -> None:
        """Stop admitting, shed anything still queued (reason ``closed``),
        and join the dispatcher. In-flight device work completes and
        flushes its ledger checkpoint first."""
        with self._cv:
            self._closed = True
            self._running = False
            self._cv.notify_all()
            t = self._thread
        if t is not None:
            t.join(timeout)
        self._thread = None

    def __enter__(self) -> "AsyncCountingService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    def run(self, max_rounds: int = 100_000):
        """The synchronous round driver stays available for offline batch
        jobs — but not while the async dispatcher owns the groups."""
        if self._thread is not None and self._thread.is_alive():
            raise RuntimeError(
                "run() is the synchronous round driver; this service's "
                "async dispatcher is running — use wait()/result()")
        return super().run(max_rounds)

    # ------------------------------------------------------------ admission
    def submit(self, request: CountRequest, qos: QoS | None = None) -> str:
        """Admit a request from any thread; returns its id immediately.

        Outcomes: served from the estimate cache (``DONE``), queued for
        the dispatcher (``PENDING``), or rejected (``SHED`` with
        :meth:`shed_reason` — queue full, modeled memory over budget, or
        service closed). Never blocks on device work.
        """
        q = qos or QoS()
        with self._cv:
            rid = super().submit(request)     # validate + cache fast path
            st = self._requests[rid]
            self._qos[rid] = q
            key = (request.graph, request.spec.canonical_hash,
                   request.engine, request.plan)
            n_seen = self._popularity.get(key, (0, None))[0] + 1
            self._popularity[key] = (
                n_seen, (request.graph, request.spec, request.engine,
                         request.plan))
            if st.status is RequestStatus.DONE:      # estimate-cache hit
                _metrics.counter("service_async_requests_total",
                                 status="cached", qos=q.klass.value).inc()
                self._cv.notify_all()
                return rid
            if self._closed:
                self._shed(rid, st, SHED_CLOSED, q)
                return rid
            if self.shed_on_memory and not self._modeled_fits(request):
                self._shed(rid, st, SHED_MEMORY, q)
                return rid
            reason = self._queue.offer(rid)
            if reason is not None:
                self._shed(rid, st, reason, q)
                return rid
            if q.deadline_s is not None:
                self._deadline_abs[rid] = time.monotonic() + q.deadline_s
            self._cv.notify_all()
            return rid

    def _shed(self, rid: str, st: _ReqState, reason: str, q: QoS) -> None:
        st.status = RequestStatus.SHED
        st.error = reason
        _metrics.counter("service_shed_total", reason=reason).inc()
        _metrics.counter("service_async_requests_total",
                         status="shed", qos=q.klass.value).inc()
        self._cv.notify_all()

    def shed_reason(self, rid: str) -> str | None:
        st = self._requests[rid]
        return st.error if st.status is RequestStatus.SHED else None

    def qos_of(self, rid: str) -> QoS | None:
        return self._qos.get(rid)

    def _modeled_fits(self, request: CountRequest) -> bool:
        """Admission-time memory check: can this template's plan walk fit
        the service budget at all (batch 1, colorset chunking allowed)?
        Uses the executor's analytic model only — no engine build, no
        device work. Unknown plans pass (they fail at attach with a
        better error)."""
        if self.memory_budget_bytes is None:
            return True
        g = self.graphs[request.graph]
        spec = request.spec
        memo_key = (g.fingerprint, spec.canonical_hash, request.engine,
                    request.plan, self.memory_budget_bytes)
        hit = self._fits_memo.get(memo_key)
        if hit is not None:
            return hit
        t = spec.tree
        plan = {"plain": t.plan, "dedup": t.plan_dedup,
                "optimized": t.plan_optimized}.get(request.plan)
        if plan is None:
            return True
        choice = pexec.pick_execution(
            plan, t.k, g.n,
            memory_budget_bytes=self.memory_budget_bytes,
            passive_cache=(request.engine != "fascia"),
            allow_chunking=(request.engine == "pgbsc"))
        self._fits_memo[memo_key] = choice.fits
        return choice.fits

    # ------------------------------------------------------------- results
    def cancel(self, rid: str) -> None:
        with self._cv:
            super().cancel(rid)
            self._cv.notify_all()

    def wait(self, rids, timeout: float | None = None) -> bool:
        """Block until every listed request is terminal (DONE / FAILED /
        CANCELLED / SHED); returns False on timeout."""
        if isinstance(rids, str):
            rids = [rids]
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while True:
                if all(self._requests[r].status in TERMINAL_STATUSES
                       for r in rids):
                    return True
                remaining = self.idle_wait_s if deadline is None else \
                    deadline - time.monotonic()
                if remaining <= 0:
                    return False
                self._cv.wait(min(remaining, 1.0))

    def result(self, rid: str,
               timeout: float | None = None) -> RequestResult:
        """The request's result; with ``timeout`` set, blocks until the
        request is terminal (or the timeout lapses) first."""
        if timeout is not None:
            self.wait([rid], timeout)
        return super().result(rid)

    def drain(self, timeout: float | None = None) -> bool:
        """Block until no request is PENDING or RUNNING and the admission
        queue is empty; returns False on timeout."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while True:
                busy = len(self._queue) or any(
                    st.status in (RequestStatus.PENDING,
                                  RequestStatus.RUNNING)
                    for st in self._requests.values())
                if not busy:
                    return True
                remaining = self.idle_wait_s if deadline is None else \
                    deadline - time.monotonic()
                if remaining <= 0:
                    return False
                self._cv.wait(min(remaining, 1.0))

    def retired_order(self) -> list[str]:
        """Request ids in retirement order (QoS-invariant tests)."""
        with self._cv:
            return list(self._retire_order)

    def _retire(self, rid: str, st: _ReqState) -> None:
        super()._retire(rid, st)
        self._retire_order.append(rid)
        q = self._qos.get(rid)
        if q is None:
            return
        b = st.result.breakdown or {}
        _metrics.histogram("service_request_total_seconds",
                           qos=q.klass.value).observe(b.get("total_s", 0.0))
        _metrics.histogram("service_request_queue_seconds",
                           qos=q.klass.value).observe(b.get("queue_s", 0.0))
        _metrics.counter("service_async_requests_total",
                         status="done", qos=q.klass.value).inc()
        if q.klass is QoSClass.DEADLINE:
            met = time.monotonic() <= self._deadline_abs.get(
                rid, float("inf"))
            _metrics.counter("service_deadline_total",
                             outcome="met" if met else "missed").inc()

    # ------------------------------------------------------------ warm pool
    def prewarm(self, graph: str, template, engine: str = "pgbsc",
                plan: str = "optimized") -> None:
        """Hint the warm pool: materialize this (graph, template) engine on
        dispatcher idle time, ahead of any request needing it."""
        with self._cv:
            self._warm_hints.append((graph, template, engine, plan))
            self._cv.notify_all()

    def _next_warm_task(self) -> tuple | None:
        """Called under the lock: an explicit prewarm hint first, then the
        most popular pair whose engine is not cache-resident."""
        if not self.warm_pool:
            return None
        while self._warm_hints:
            task = self._warm_hints.pop(0)
            if not self._engine_resident(task):
                return task
        ranked = sorted(self._popularity.values(),
                        key=lambda cv: -cv[0])
        for _, task in ranked:
            if not self._engine_resident(task):
                return task
        return None

    def _engine_resident(self, task: tuple) -> bool:
        graph, template, engine, plan = task
        g = self.graphs.get(graph)
        if g is None:
            return True                       # unknown graph: nothing to do
        try:
            return self.engine_cache.has(g, template, engine, plan,
                                         **self.engine_kw)
        except Exception:
            return True                       # unbuildable key: skip warming
        # (a template that cannot even key will fail loudly at attach)

    def _do_warm(self, task: tuple) -> None:
        """Build one warm engine (dispatcher thread, outside the lock)."""
        graph, template, engine, plan = task
        g = self.graphs.get(graph)
        if g is None:
            return
        try:
            self.engine_cache.get(g, template, engine, plan,
                                  **self.engine_kw)
            _metrics.counter("service_warm_builds_total").inc()
        except Exception:
            _metrics.counter("service_warm_failures_total").inc()

    # ----------------------------------------------------------- dispatcher
    def _attach_async(self, rid: str) -> None:
        """Attach one admitted request: join an existing group under the
        lock, or build the group (engine + ledger resume) outside it."""
        with self._cv:
            st = self._requests[rid]
            if st.status is not RequestStatus.PENDING:
                return                        # cancelled while queued
            t_start = time.perf_counter()
            st.queue_s = max(0.0, t_start - st.t_submit_pc)
            _metrics.histogram("service_request_queue_seconds").observe(
                st.queue_s)
            g = self.graphs[st.request.graph]
            key = st.request.group_key(g.fingerprint)
            grp = self._groups.get(key)
            if grp is not None:
                st.shared_group = True
                self._join(rid, st, grp)
                return
        try:                                  # slow path: outside the lock
            built, build_s = self._build_group(st)
        except Exception as exc:
            with self._cv:
                st.status = RequestStatus.FAILED
                st.error = f"{type(exc).__name__}: {exc}"
                _metrics.counter("service_requests_total",
                                 status="failed").inc()
                self._cv.notify_all()
            return
        with self._cv:
            grp = self._groups.get(key)
            if grp is None:
                grp = built
                self._groups[key] = grp
                st.build_s = build_s
            else:
                st.shared_group = True        # lost a (theoretical) race
            if st.status is RequestStatus.PENDING:
                self._join(rid, st, grp)

    def _join(self, rid: str, st: _ReqState, grp: _Group) -> None:
        grp.members.append(rid)
        st.group_key = grp.key
        st.status = RequestStatus.RUNNING
        st.t_attach_pc = time.perf_counter()
        self._cv.notify_all()

    def _group_views(self) -> list[GroupView]:
        """Dispatchable groups as policy views (called under the lock);
        creation order is preserved so policy ties resolve FIFO."""
        views: list[GroupView] = []
        for key, grp in self._groups.items():
            live = [r for r in grp.members
                    if self._requests[r].status is RequestStatus.RUNNING]
            if not live:
                continue
            rank = min(self._qos.get(r, _DEFAULT_QOS).klass.rank
                       for r in live)
            deadline = min((self._deadline_abs[r] for r in live
                            if r in self._deadline_abs),
                           default=float("inf"))
            tenants: dict[str, float] = {}
            for r in live:
                q = self._qos.get(r, _DEFAULT_QOS)
                tenants[q.tenant] = max(tenants.get(q.tenant, 0.0),
                                        q.weight)
            views.append(GroupView(key=key, rank=rank, deadline=deadline,
                                   tenants=tuple(tenants.items())))
        return views

    def _supervise(self) -> None:
        """Dispatcher thread body: run :meth:`_loop`, and when an
        exception escapes it (a bug, a poisoned attach, an injected
        ``dispatch.loop`` fault), contain it — restart the loop with
        drained-but-unattached requests re-queued, up to
        ``max_dispatcher_restarts``; past the budget fail every live
        request with :class:`DispatcherDead` and stop admitting. Either
        way, every admitted request reaches a terminal status."""
        while True:
            try:
                self._loop()
                return                              # clean shutdown
            except BaseException as exc:
                _metrics.counter("dispatcher_crashes_total").inc()
                with self._cv:
                    self._dispatcher_crashes += 1
                    crashed_out = (self._dispatcher_crashes
                                   > self.max_dispatcher_restarts)
                    if crashed_out or not self._running:
                        self._running = False
                        self._closed = True        # future submits shed
                        self._fail_live(exc)
                        self._cv.notify_all()
                        return
                    self._requeue_unattached()
                _metrics.counter("dispatcher_restarts_total").inc()

    def _requeue_unattached(self) -> None:
        """Re-offer PENDING requests the crashed loop drained but never
        attached (called under the lock). A full queue sheds them —
        terminal either way, never silently dropped."""
        queued = set(self._queue.contents())
        for rid, st in self._requests.items():
            if st.status is RequestStatus.PENDING and \
                    st.group_key is None and rid not in queued:
                reason = self._queue.offer(rid)
                if reason is not None:
                    self._shed(rid, st, reason,
                               self._qos.get(rid, _DEFAULT_QOS))

    def _fail_live(self, cause: BaseException) -> None:
        """Fail every PENDING/RUNNING request with a structured
        DispatcherDead error (called under the lock)."""
        exc = DispatcherDead(self._dispatcher_crashes, cause)
        for st in self._requests.values():
            if st.status in (RequestStatus.PENDING, RequestStatus.RUNNING):
                self._fail_member(st, exc)

    def _loop(self) -> None:
        while True:
            _faults.inject("dispatch.loop", context="async")
            with self._cv:
                if not self._running:
                    for rid in self._queue.drain():
                        st = self._requests[rid]
                        if st.status is RequestStatus.PENDING:
                            self._shed(rid, st, SHED_CLOSED,
                                       self._qos.get(rid, _DEFAULT_QOS))
                    self._cv.notify_all()
                    return
                pending = self._queue.drain()
            for rid in pending:               # builds happen outside the
                self._attach_async(rid)       # lock; submit stays live
            picked = None
            with self._cv:
                self._consume_and_retire()
                self._publish_inflight()
                views = self._group_views()
                if views:
                    gv = self._policy.pick(views)
                    grp = self._groups[gv.key]
                    ids = self._plan_dispatch(grp)
                    if ids is not None:
                        picked = (gv, grp, ids)
            if picked is not None:
                gv, grp, ids = picked
                # device work runs without the lock: admission, cancel,
                # and waiters stay responsive during a dispatch
                self._dispatch_ids(grp, ids)
                with self._cv:
                    self._policy.charge(gv.tenants, len(ids))
                    self._consume_and_retire()
                    self._release_idle_engines()
                    self._publish_inflight()
                    self._cv.notify_all()
                continue
            warm = None
            with self._cv:
                if not len(self._queue):
                    warm = self._next_warm_task()
            if warm is not None:
                self._do_warm(warm)
                continue
            with self._cv:
                if self._running and not len(self._queue):
                    self._cv.wait(self.idle_wait_s)

    def _publish_inflight(self) -> None:
        n = sum(st.status in (RequestStatus.PENDING, RequestStatus.RUNNING)
                for st in self._requests.values())
        _metrics.gauge("service_inflight_requests").set(n)

    # ------------------------------------------------------------- insight
    def stats(self) -> dict:
        s = super().stats()
        s["queue_depth"] = len(self._queue)
        s["shed"] = sum(st.status is RequestStatus.SHED
                        for st in self._requests.values())
        s["tenant_virtual_time"] = self._policy.virtual_times()
        s["dispatcher_crashes"] = self._dispatcher_crashes
        return s

    def resilience_state(self) -> dict:
        s = super().resilience_state()
        t = self._thread
        s["dispatcher"] = {
            "alive": bool(t is not None and t.is_alive()),
            "crashes": self._dispatcher_crashes,
            "max_restarts": self.max_dispatcher_restarts,
        }
        return s


_DEFAULT_QOS = QoS()
