"""Block-size autotuning for the Pallas kernels: a small cached sweep.

Fused plans dispatch only a handful of distinct ``(B, C, N)`` table shapes
per engine, so exhaustive per-shape timing is cheap: each candidate block
configuration is compiled once and timed over a few repetitions, and the
winner is cached in-process keyed by (kernel kind, shape signature, dtype,
interpret flag, vertex-reorder choice). Subsequent dispatches with the same
signature pay a dict lookup; ``autotune_cache_{hits,misses}_total`` counters
in the obs registry make the reuse rate observable.

``measure=False`` (the default for :func:`ema_blocks` callers that pass
``autotune=False``) never runs the sweep — dispatch falls back to the static
heuristics — so tests and cold paths stay deterministic and compile-light.
"""

from __future__ import annotations

import time
from typing import Callable, Hashable, Sequence

import jax
import numpy as np

from repro.obs import metrics as _metrics
from repro.obs import tracing as _tracing

__all__ = ["autotune", "ema_blocks", "spmm_c_block", "cache_info",
           "clear_cache", "EMA_BLOCK_CANDIDATES", "SPMM_C_BLOCK_CANDIDATES"]

# (s_block, n_block) candidates for the eMA kernel sweep.
EMA_BLOCK_CANDIDATES: tuple[tuple[int, int], ...] = (
    (4, 256), (8, 256), (8, 512), (16, 512), (8, 1024),
)
# c_block candidates for the SpMM MXU kernels.
SPMM_C_BLOCK_CANDIDATES: tuple[int, ...] = (32, 64, 128, 256)

_CACHE: dict[Hashable, object] = {}


def clear_cache() -> None:
    _CACHE.clear()


def cache_info() -> dict:
    """Snapshot of tuned choices (for benchmarks / debugging)."""
    return dict(_CACHE)


def _time_once(fn: Callable[[], object], reps: int = 3) -> float:
    out = fn()                      # compile + warm
    jax.block_until_ready(out)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def autotune(key: Hashable, candidates: Sequence, make_fn: Callable,
             reps: int = 3):
    """Return the candidate minimizing median runtime of ``make_fn(cand)()``.

    ``make_fn(cand)`` must return a zero-arg callable running the kernel with
    that candidate; candidates that fail to trace/compile are skipped. The
    winner is cached under ``key``; on total failure the first candidate is
    cached so the sweep never repeats.
    """
    kind = str(key[0]) if isinstance(key, tuple) and key else "unknown"
    if key in _CACHE:
        _metrics.counter("autotune_cache_hits_total", kind=kind).inc()
        return _CACHE[key]
    _metrics.counter("autotune_cache_misses_total", kind=kind).inc()
    best, best_t = None, float("inf")
    with _tracing.span("autotune.sweep", kind=kind,
                       candidates=len(candidates)):
        for cand in candidates:
            try:
                t = _time_once(make_fn(cand), reps=reps)
            except Exception:
                continue
            if t < best_t:
                best, best_t = cand, t
    if best is None:
        best = candidates[0]
    _CACHE[key] = best
    return best


def ema_blocks(m_a, y_p, ia, ip, *, interpret: bool,
               candidates: Sequence[tuple[int, int]] = EMA_BLOCK_CANDIDATES
               ) -> tuple[int, int]:
    """Tuned (s_block, n_block) for :func:`..ema.pallas_ema.ema_pallas`.

    The key carries the backend kind, both table dtypes, and the interpret
    flag alongside the shapes — a bf16 sweep never reuses f32 timings. (The
    eMA kernel has no graph operand, so no reorder component here.)"""
    from repro.kernels.ema.pallas_ema import ema_pallas
    key = ("ema", m_a.shape, y_p.shape, ia.shape, str(m_a.dtype),
           str(y_p.dtype), interpret)

    def make(cand):
        sb, nb = cand
        return lambda: ema_pallas(m_a, y_p, ia, ip, s_block=sb, n_block=nb,
                                  interpret=interpret)

    return autotune(key, tuple(candidates), make)


def spmm_c_block(m, run_with_c_block: Callable[[int], object], *,
                 kind: str, interpret: bool, reorder: str = "",
                 candidates: Sequence[int] = SPMM_C_BLOCK_CANDIDATES) -> int:
    """Tuned c_block for the Pallas SpMM kernels (gather / bsr / fused).

    ``run_with_c_block(c)`` runs the kernel with that block size; candidates
    larger than the (padded) row count are skipped up front. The cache key
    is (backend kind, shape, dtype, interpret, reorder): a tuned block for
    the RCM-reordered BSR stream is a different entry than the identity
    order's — the block stream, and thus the winner, differs.
    """
    rows = m.shape[-2] if m.ndim >= 2 else 1
    cands = tuple(c for c in candidates if c <= max(rows, min(candidates)))
    if not cands:
        cands = (min(candidates),)
    key = (kind, m.shape, str(m.dtype), interpret, reorder)
    return autotune(key, cands, lambda c: (lambda: run_with_c_block(c)))
