"""Fused SpMM -> eMA Pallas kernel: one plan node, one kernel, no HBM
y-cache intermediate (paper §4.5's bandwidth argument taken to its limit).
The shared variant runs the SpMM leg once for a GROUP of consumers of the
same passive child, keeping the y tiles in VMEM scratch across them."""

from repro.kernels.fused.ops import (FusedPrep, fused_fits_vmem,
                                     fused_group_fits_vmem, fused_spmm_ema,
                                     fused_spmm_ema_shared, prepare_fused)

__all__ = ["FusedPrep", "fused_fits_vmem", "fused_group_fits_vmem",
           "fused_spmm_ema", "fused_spmm_ema_shared", "prepare_fused"]
