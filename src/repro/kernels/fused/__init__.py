"""Fused SpMM -> eMA Pallas kernel: one plan node, one kernel, no HBM
y-cache intermediate (paper §4.5's bandwidth argument taken to its limit)."""

from repro.kernels.fused.ops import (FusedPrep, fused_fits_vmem,
                                     fused_spmm_ema, prepare_fused)

__all__ = ["FusedPrep", "fused_fits_vmem", "fused_spmm_ema", "prepare_fused"]
