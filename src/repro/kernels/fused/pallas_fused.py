"""Fused SpMM -> eMA Pallas kernel (paper Algorithm 4 lines 3+7, one pass).

The unfused PGBSC walk materializes each plan node's passive neighbor-sum
table ``y_p = m_p @ A`` (shape ``(B, C(k,t_p), N)``) in HBM, then reads it
back for the eMA. This kernel keeps the whole exchange in VMEM: the grid
walks the destination-tile-sorted BSR block stream of the adjacency, and for
each destination vertex tile

    1. accumulates ``y[:, :, tile] += m_p[:, :, src_tile] @ block`` into a
       VMEM scratch accumulator (MXU matmuls over the tile's block run),
    2. on the tile's last block, applies the (IA, IP) split combination —
       expressed as one-hot selection matmuls per split, the MXU-friendly
       form of the row gathers — against the resident active table block and
       writes ONLY the ``(bb, C(k,t), tile)`` output block; y never exists
       outside VMEM.

Grid: (batch_blocks, n_blocks). The coloring batch is tiled into blocks of
``bb`` colorings that ride INSIDE the kernel block shapes (largest ``bb``
whose working set fits VMEM) rather than as bare grid steps — per-step
overhead is paid once per ``bb`` colorings, and the MXU matmuls see
``bb``-fold taller operands. The batch-block axis is parallel; the BSR block
axis is "arbitrary" (the scratch accumulator and output block carry state
across consecutive steps of one destination tile). ``Graph.bsr()``
guarantees every destination tile has at least one block (zero blocks are
inserted for empty tiles), so every output block is written. Padded output
rows (combination axis rounded up to the sublane multiple) select nothing
and come out exact zeros; padded batch rows see zero tables.

Correct under interpret mode on CPU; ``dimension_semantics`` set for the
compiled TPU path (the batched ``dot_general`` contractions need a Mosaic
with batched-dot support). Dtypes the dispatch layer admits (see
``ema.ops.pallas_dtype_pair``) split into a (storage, accumulator) pair:
tables and adjacency blocks stream in the storage dtype (bf16 halves their
HBM traffic), while the y scratch and the split-combination accumulator run
in the pair's accumulator dtype (f32 for bf16) and cast only at the output
store.

``fused_spmm_ema_shared_pallas`` generalizes the launch to a GROUP of
consumers sharing one passive child: the SpMM leg runs ONCE into the shared
y scratch, then each consumer's split combination reads it and writes its
own output table — the shared sub-templates a fused multi-template plan
creates cost one SpMM for the whole group instead of one per consumer.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["fused_spmm_ema_pallas", "fused_spmm_ema_shared_pallas",
           "pick_batch_block", "batch_block_fits",
           "group_batch_block_fits", "pick_group_batch_block"]

# conservative per-core VMEM working-set budget (matches ema.ops)
_VMEM_BUDGET = 12 * 1024 * 1024


def batch_block_fits(bb: int, c_a: int, c_p: int, s_pad: int, l: int,
                     tile: int, itemsize: int) -> bool:
    """Whether a ``bb``-coloring batch block's fused working set fits VMEM:
    ``bb`` copies of the active block, the passive block, the y scratch,
    and the output block, plus one adjacency tile and the (batch-free)
    selection matrices."""
    per_b = (c_a + 2 * c_p + s_pad) * tile
    fixed = tile * tile + l * s_pad * (c_a + c_p)
    return (bb * per_b + fixed) * itemsize < _VMEM_BUDGET


def pick_batch_block(b: int, c_a: int, c_p: int, s_pad: int, l: int,
                     tile: int, itemsize: int) -> int:
    """Largest batch block whose fused working set fits the VMEM budget
    (see :func:`batch_block_fits`); floors at 1."""
    bb = max(1, b)
    while bb > 1 and not batch_block_fits(bb, c_a, c_p, s_pad, l, tile,
                                          itemsize):
        bb = -(-bb // 2)
    return bb


def _kernel(dst_tile_ref, src_tile_ref,                   # scalar prefetch
            blocks_ref, ma_ref, mp_ref, sela_ref, selp_ref,  # inputs
            out_ref,                                      # output
            y_ref,                                        # VMEM scratch
            *, l: int):
    b = pl.program_id(1)
    nb = pl.num_programs(1)
    acc_dtype = y_ref.dtype      # accumulator pair member (f32 for bf16)

    # --- SpMM leg: accumulate this destination tile's neighbor sums in VMEM
    is_first = jnp.logical_or(
        b == 0, dst_tile_ref[b] != dst_tile_ref[jnp.maximum(b - 1, 0)]
    )

    @pl.when(is_first)
    def _zero():
        y_ref[...] = jnp.zeros_like(y_ref)

    # (bb, Cp, tile) @ (tile, tile): fold the batch block into matmul rows
    bb, c_p, tile = y_ref.shape
    mp_flat = mp_ref[...].reshape(bb * c_p, tile).astype(acc_dtype)
    y_ref[...] += jax.lax.dot(
        mp_flat, blocks_ref[0].astype(acc_dtype),
        preferred_element_type=acc_dtype,
    ).reshape(bb, c_p, tile)

    # --- eMA leg: on the tile's last block, combine and write the output.
    # The (IA, IP) row gathers are expressed as one-hot selection matmuls
    # (MXU-friendly; TPU Pallas has no dynamic sublane gather): per split i,
    #   out[b] += (sel_a[i] @ m_a[b]) * (sel_p[i] @ y[b]).
    # Padded output rows have all-zero selection rows, so they come out
    # exact zeros without a separate masking pass.
    is_last = jnp.logical_or(
        b == nb - 1, dst_tile_ref[b] != dst_tile_ref[jnp.minimum(b + 1, nb - 1)]
    )

    @pl.when(is_last)
    def _combine():
        s_pad = out_ref.shape[1]
        contract = (((1,), (1,)), ((), ()))   # sel (S,C) x table (bb,C,tile)
        ma = ma_ref[...].astype(acc_dtype)

        def body(i, acc):
            sel_a = sela_ref[pl.dslice(i, 1)][0].astype(acc_dtype)  # (S_pad, Ca)
            sel_p = selp_ref[pl.dslice(i, 1)][0].astype(acc_dtype)  # (S_pad, Cp)
            a_rows = jax.lax.dot_general(
                sel_a, ma, contract, preferred_element_type=acc_dtype)
            p_rows = jax.lax.dot_general(
                sel_p, y_ref[...], contract, preferred_element_type=acc_dtype)
            return acc + a_rows * p_rows                  # (S_pad, bb, tile)

        acc = jax.lax.fori_loop(
            0, l, body, jnp.zeros((s_pad, bb, tile), acc_dtype))
        out_ref[...] = acc.transpose(1, 0, 2).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("n_tiles", "tile", "interpret")
)
def fused_spmm_ema_pallas(
    m_a: jnp.ndarray,        # (B, Ca, N) float, N = n_tiles * tile
    m_p: jnp.ndarray,        # (B, Cp, N) float
    ia: jnp.ndarray,         # (S, L) int32
    ip: jnp.ndarray,         # (S, L) int32
    blocks: jnp.ndarray,     # (n_blocks, tile, tile) {0,1} adjacency tiles
    src_tile: jnp.ndarray,   # (n_blocks,) int32
    dst_tile: jnp.ndarray,   # (n_blocks,) int32, sorted ascending, all tiles
    *,
    n_tiles: int,
    tile: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """-> (B, S, N): ``ema(m_a, m_p @ A, ia, ip)`` without materializing
    the ``(B, Cp, N)`` neighbor-sum table. Inputs must be 3-D (batched);
    the ops-layer wrapper handles rank/padding/dtype dispatch."""
    s, l = ia.shape
    b, _, n = m_a.shape
    assert n == n_tiles * tile, (n, n_tiles, tile)
    assert m_p.shape[0] == b and m_p.shape[2] == n
    from repro.kernels.ema.ops import accum_dtype
    dtype = jnp.promote_types(m_a.dtype, m_p.dtype)
    acc_dt = jnp.dtype(accum_dtype(dtype))
    m_a = m_a.astype(dtype)
    m_p = m_p.astype(dtype)
    c_a, c_p = m_a.shape[1], m_p.shape[1]
    s_pad = -(-s // 8) * 8          # sublane multiple for the output block
    # fit check uses the accumulator itemsize: the y scratch and fori
    # accumulator dominate the working set and live in the wider dtype
    bb = pick_batch_block(b, c_a, c_p, s_pad, l, tile, acc_dt.itemsize)
    b_pad = -(-b // bb) * bb
    if b_pad != b:
        m_a = jnp.pad(m_a, ((0, b_pad - b), (0, 0), (0, 0)))
        m_p = jnp.pad(m_p, ((0, b_pad - b), (0, 0), (0, 0)))
    # one-hot selection matrices per split: sel[i, j, c] = 1 iff split i of
    # output row j reads table row c. Padded rows (>= s) select nothing.
    sel_a = (ia.T[:, :, None] == jnp.arange(c_a)).astype(dtype)  # (L, S, Ca)
    sel_p = (ip.T[:, :, None] == jnp.arange(c_p)).astype(dtype)  # (L, S, Cp)
    if s_pad != s:
        pad = ((0, 0), (0, s_pad - s), (0, 0))
        sel_a = jnp.pad(sel_a, pad)
        sel_p = jnp.pad(sel_p, pad)
    n_blocks = blocks.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b_pad // bb, n_blocks),
        in_specs=[
            pl.BlockSpec((1, tile, tile),
                         lambda g, blk, dt, st: (blk, 0, 0)),
            pl.BlockSpec((bb, c_a, tile),
                         lambda g, blk, dt, st: (g, 0, dt[blk])),
            pl.BlockSpec((bb, c_p, tile),
                         lambda g, blk, dt, st: (g, 0, st[blk])),
            pl.BlockSpec((l, s_pad, c_a),
                         lambda g, blk, dt, st: (0, 0, 0)),
            pl.BlockSpec((l, s_pad, c_p),
                         lambda g, blk, dt, st: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, s_pad, tile),
                               lambda g, blk, dt, st: (g, 0, dt[blk])),
        scratch_shapes=[pltpu.VMEM((bb, c_p, tile), acc_dt)],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, l=l),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b_pad, s_pad, n), dtype),
        interpret=interpret,
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
    )(dst_tile, src_tile, blocks, m_a, m_p, sel_a, sel_p)
    return out[:b, :s, :]


# ---------------------------------------------------------------------------
# Shared-passive group launch: one SpMM leg, many consumers
# ---------------------------------------------------------------------------

def group_batch_block_fits(bb: int, c_as: tuple[int, ...], c_p: int,
                           s_pads: tuple[int, ...], ls: tuple[int, ...],
                           tile: int, itemsize: int) -> bool:
    """VMEM fit for a shared-passive group step: every consumer's active and
    output blocks are resident simultaneously, but the passive block and the
    y scratch are paid ONCE for the whole group."""
    per_b = (sum(c_as) + sum(s_pads) + 2 * c_p) * tile
    fixed = tile * tile + sum(
        l * sp * (ca + c_p) for l, sp, ca in zip(ls, s_pads, c_as))
    return (bb * per_b + fixed) * itemsize < _VMEM_BUDGET


def pick_group_batch_block(b: int, c_as: tuple[int, ...], c_p: int,
                           s_pads: tuple[int, ...], ls: tuple[int, ...],
                           tile: int, itemsize: int) -> int:
    """Largest batch block whose group working set fits VMEM; floors at 1."""
    bb = max(1, b)
    while bb > 1 and not group_batch_block_fits(bb, c_as, c_p, s_pads, ls,
                                                tile, itemsize):
        bb = -(-bb // 2)
    return bb


def _shared_kernel(dst_tile_ref, src_tile_ref,            # scalar prefetch
                   *refs, n_cons: int, ls: tuple[int, ...]):
    # refs layout: blocks, mp, (ma_i, sela_i, selp_i) x n_cons,
    #              out_i x n_cons, y scratch
    blocks_ref, mp_ref = refs[0], refs[1]
    cons = [refs[2 + 3 * i: 5 + 3 * i] for i in range(n_cons)]
    outs = refs[2 + 3 * n_cons: 2 + 4 * n_cons]
    y_ref = refs[-1]
    b = pl.program_id(1)
    nb = pl.num_programs(1)
    acc_dtype = y_ref.dtype

    is_first = jnp.logical_or(
        b == 0, dst_tile_ref[b] != dst_tile_ref[jnp.maximum(b - 1, 0)]
    )

    @pl.when(is_first)
    def _zero():
        y_ref[...] = jnp.zeros_like(y_ref)

    bb, c_p, tile = y_ref.shape
    mp_flat = mp_ref[...].reshape(bb * c_p, tile).astype(acc_dtype)
    y_ref[...] += jax.lax.dot(
        mp_flat, blocks_ref[0].astype(acc_dtype),
        preferred_element_type=acc_dtype,
    ).reshape(bb, c_p, tile)

    is_last = jnp.logical_or(
        b == nb - 1, dst_tile_ref[b] != dst_tile_ref[jnp.minimum(b + 1, nb - 1)]
    )

    @pl.when(is_last)
    def _combine():
        contract = (((1,), (1,)), ((), ()))
        # the consumer loop unrolls at trace time; every consumer reads the
        # SAME resident y scratch — the SpMM leg was paid once above
        for ci in range(n_cons):
            ma_ref, sela_ref, selp_ref = cons[ci]
            out_ref = outs[ci]
            s_pad = out_ref.shape[1]
            ma = ma_ref[...].astype(acc_dtype)

            def body(i, acc, sela_ref=sela_ref, selp_ref=selp_ref, ma=ma):
                sel_a = sela_ref[pl.dslice(i, 1)][0].astype(acc_dtype)
                sel_p = selp_ref[pl.dslice(i, 1)][0].astype(acc_dtype)
                a_rows = jax.lax.dot_general(
                    sel_a, ma, contract, preferred_element_type=acc_dtype)
                p_rows = jax.lax.dot_general(
                    sel_p, y_ref[...], contract,
                    preferred_element_type=acc_dtype)
                return acc + a_rows * p_rows

            acc = jax.lax.fori_loop(
                0, ls[ci], body, jnp.zeros((s_pad, bb, tile), acc_dtype))
            out_ref[...] = acc.transpose(1, 0, 2).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("n_tiles", "tile", "interpret")
)
def fused_spmm_ema_shared_pallas(
    m_as: tuple,             # per-consumer (B, Ca_i, N) float
    m_p: jnp.ndarray,        # (B, Cp, N) float — the shared passive table
    ias: tuple,              # per-consumer (S_i, L_i) int32
    ips: tuple,              # per-consumer (S_i, L_i) int32
    blocks: jnp.ndarray,     # (n_blocks, tile, tile) adjacency tiles
    src_tile: jnp.ndarray,   # (n_blocks,) int32
    dst_tile: jnp.ndarray,   # (n_blocks,) int32, sorted ascending, all tiles
    *,
    n_tiles: int,
    tile: int = 128,
    interpret: bool = True,
) -> tuple:
    """-> per-consumer (B, S_i, N) tuple: every consumer's
    ``ema(m_a_i, m_p @ A, ia_i, ip_i)`` from ONE launch whose SpMM leg runs
    once into shared VMEM scratch. Inputs must be 3-D (batched)."""
    from repro.kernels.ema.ops import accum_dtype
    n_cons = len(m_as)
    assert n_cons == len(ias) == len(ips) and n_cons >= 1
    b, _, n = m_as[0].shape
    assert n == n_tiles * tile, (n, n_tiles, tile)
    assert m_p.shape[0] == b and m_p.shape[2] == n
    dtype = m_p.dtype
    for ma in m_as:
        dtype = jnp.promote_types(dtype, ma.dtype)
    acc_dt = jnp.dtype(accum_dtype(dtype))
    m_p = m_p.astype(dtype)
    m_as = tuple(ma.astype(dtype) for ma in m_as)
    c_p = m_p.shape[1]
    c_as = tuple(ma.shape[1] for ma in m_as)
    ss = tuple(ia.shape[0] for ia in ias)
    ls = tuple(ia.shape[1] for ia in ias)
    s_pads = tuple(-(-s // 8) * 8 for s in ss)
    bb = pick_group_batch_block(b, c_as, c_p, s_pads, ls, tile,
                                acc_dt.itemsize)
    b_pad = -(-b // bb) * bb
    if b_pad != b:
        pad = ((0, b_pad - b), (0, 0), (0, 0))
        m_p = jnp.pad(m_p, pad)
        m_as = tuple(jnp.pad(ma, pad) for ma in m_as)
    sel_as, sel_ps = [], []
    for ia, ip, c_a, s, s_pad in zip(ias, ips, c_as, ss, s_pads):
        sa = (ia.T[:, :, None] == jnp.arange(c_a)).astype(dtype)  # (L, S, Ca)
        sp = (ip.T[:, :, None] == jnp.arange(c_p)).astype(dtype)  # (L, S, Cp)
        if s_pad != s:
            pad = ((0, 0), (0, s_pad - s), (0, 0))
            sa, sp = jnp.pad(sa, pad), jnp.pad(sp, pad)
        sel_as.append(sa)
        sel_ps.append(sp)
    n_blocks = blocks.shape[0]

    in_specs = [
        pl.BlockSpec((1, tile, tile), lambda g, blk, dt, st: (blk, 0, 0)),
        pl.BlockSpec((bb, c_p, tile), lambda g, blk, dt, st: (g, 0, st[blk])),
    ]
    operands = [blocks, m_p]
    for ci in range(n_cons):
        in_specs.append(pl.BlockSpec(
            (bb, c_as[ci], tile), lambda g, blk, dt, st: (g, 0, dt[blk])))
        in_specs.append(pl.BlockSpec(
            (ls[ci], s_pads[ci], c_as[ci]), lambda g, blk, dt, st: (0, 0, 0)))
        in_specs.append(pl.BlockSpec(
            (ls[ci], s_pads[ci], c_p), lambda g, blk, dt, st: (0, 0, 0)))
        operands += [m_as[ci], sel_as[ci], sel_ps[ci]]
    out_specs = [
        pl.BlockSpec((bb, sp, tile), lambda g, blk, dt, st: (g, 0, dt[blk]))
        for sp in s_pads
    ]
    out_shape = [jax.ShapeDtypeStruct((b_pad, sp, n), dtype) for sp in s_pads]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b_pad // bb, n_blocks),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[pltpu.VMEM((bb, c_p, tile), acc_dt)],
    )
    outs = pl.pallas_call(
        functools.partial(_shared_kernel, n_cons=n_cons, ls=ls),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
    )(dst_tile, src_tile, *operands)
    return tuple(out[:b, :s, :] for out, s in zip(outs, ss))
