"""Dispatch for the fused SpMM -> eMA kernel.

``prepare_fused(graph)`` lifts the adjacency into the destination-sorted BSR
block stream the kernel walks (plus raw edge lists for the explicit XLA
fallback); ``fused_spmm_ema(m_a, m_p, ia, ip, prep)`` computes

    out = ema(m_a, m_p @ A, ia, ip)

without materializing the ``(B, C(k,t_p), N)`` neighbor-sum table in HBM —
the whole point of the fusion (see pallas_fused.py). Unsupported dtypes or
tables too large for VMEM run the unfused XLA pair (segment SpMM + scan eMA)
explicitly; the kernel path never downcasts. Sub-f32 storage dtypes (bf16)
stream half the table/adjacency bytes while the kernels accumulate in the
(storage, accum) pair's f32 member.

``fused_spmm_ema_shared`` is the group form: several consumers of ONE
passive child computed by a single launch whose SpMM leg runs once into
shared VMEM scratch (see ``fused_spmm_ema_shared_pallas``). Its fallback
preserves the sharing: one XLA segment SpMM, then one eMA per consumer.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.structure import Graph
from repro.kernels.ema.ops import (_PALLAS_VMEM_BYTES, accum_dtype, ema_xla,
                                   pallas_supports_dtype)
from repro.kernels.fused.pallas_fused import (batch_block_fits,
                                              fused_spmm_ema_pallas,
                                              fused_spmm_ema_shared_pallas,
                                              group_batch_block_fits)
from repro.obs import metrics as _metrics

__all__ = ["FusedPrep", "prepare_fused", "fused_spmm_ema",
           "fused_spmm_ema_shared", "fused_fits_vmem",
           "fused_group_fits_vmem"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FusedPrep:
    """Device-side adjacency operand for the fused kernel (a pytree)."""

    n: int
    arrays: dict[str, Any]
    static: dict[str, Any]

    def tree_flatten(self):
        keys = sorted(self.arrays)
        return [self.arrays[k] for k in keys], (
            self.n, keys, tuple(sorted(self.static.items())))

    @classmethod
    def tree_unflatten(cls, aux, children):
        n, keys, static = aux
        return cls(n, dict(zip(keys, children)), dict(static))

    @property
    def n_blocks(self) -> int:
        return int(self.arrays["blocks"].shape[0])


def prepare_fused(g: Graph, *, tile: int = 128, interpret: bool = True,
                  dtype=jnp.float32, reorder: str = "") -> FusedPrep:
    """BSR block stream (every dst tile populated, sorted by dst tile) plus
    the raw edge lists for the XLA fallback path. ``dtype`` is the storage
    dtype the adjacency blocks are held in (bf16 halves their HBM bytes);
    ``reorder`` tags the prep with the vertex-ordering choice for the
    autotune cache key, same as ``spmm.ops.prepare``."""
    gp = g.padded(tile)
    bs = gp.bsr(tile=tile)
    src, dst = g.edges_by_dst
    return FusedPrep(
        g.n,
        {"blocks": jnp.asarray(bs.blocks, jnp.dtype(dtype)),
         "src_tile": jnp.asarray(bs.src_tile),
         "dst_tile": jnp.asarray(bs.dst_tile),
         "fb_src": jnp.asarray(src), "fb_dst": jnp.asarray(dst)},
        {"tile": tile, "n_tiles": bs.n_tiles, "interpret": interpret,
         "reorder": reorder},
    )


def fused_fits_vmem(c_a: int, c_p: int, s: int, *, l: int = 0,
                    tile: int = 128, dtype=jnp.float32) -> bool:
    """VMEM residency of one fused grid step: active block + passive block +
    y scratch + adjacency block + the (padded) output block + the resident
    one-hot split-selection matrices (``l`` splits). Sized with the
    accumulator itemsize — the scratch buffers run in the wider pair member
    even when storage is bf16."""
    itemsize = np.dtype(accum_dtype(dtype)).itemsize
    s_pad = -(-s // 8) * 8
    rows = c_a + 2 * c_p + tile + s_pad
    sel = l * s_pad * (c_a + c_p)
    return (rows * tile + sel) * itemsize < _PALLAS_VMEM_BYTES


def fused_group_fits_vmem(c_as, c_p: int, ss, ls, *, tile: int = 128,
                          dtype=jnp.float32) -> bool:
    """VMEM residency of one shared-passive group step: every consumer's
    active/output blocks and selection matrices resident together, the
    passive block and y scratch paid once. Accumulator-itemsize sized,
    matching :func:`fused_fits_vmem`."""
    itemsize = np.dtype(accum_dtype(dtype)).itemsize
    s_pads = [-(-s // 8) * 8 for s in ss]
    rows = sum(c_as) + sum(s_pads) + 2 * c_p + tile
    sel = sum(l * sp * (ca + c_p) for l, sp, ca in zip(ls, s_pads, c_as))
    return (rows * tile + sel) * itemsize < _PALLAS_VMEM_BYTES


def _fallback(m_a, m_p, ia, ip, prep: FusedPrep) -> jnp.ndarray:
    """Unfused XLA pair — the explicit escape hatch for unsupported dtypes
    or VMEM-oversized tables (matches the kernel to float reassociation)."""
    from repro.kernels.spmm.ops import _spmm_segment
    _metrics.counter("kernel_launches_total", kernel="fused",
                     path="xla").inc()
    lead = m_p.shape[:-2]
    flat = m_p.reshape((-1, m_p.shape[-1]))
    y = _spmm_segment(flat, prep.arrays["fb_src"], prep.arrays["fb_dst"],
                      prep.n)
    y = y.reshape(lead + (m_p.shape[-2], m_p.shape[-1]))
    return ema_xla(m_a, y, ia, ip)


def fused_spmm_ema(m_a: jnp.ndarray, m_p: jnp.ndarray,
                   ia: jnp.ndarray, ip: jnp.ndarray,
                   prep: FusedPrep) -> jnp.ndarray:
    """``ema(m_a, m_p @ A, ia, ip)`` for tables of shape (..., C, N).

    Rank-polymorphic over one optional leading batch dimension (folded into
    the kernel grid — one launch for the whole coloring batch). The vertex
    axis is padded to the tile multiple on the way in (padding vertices are
    isolated, so their neighbor sums and output columns are exact zeros) and
    sliced on the way out.
    """
    st = prep.static
    dtype = jnp.promote_types(m_a.dtype, m_p.dtype)
    # every fallback decision is reason-counted (once per traced shape),
    # so "asked for the fused kernel, got the XLA pair" is never silent
    if not pallas_supports_dtype(dtype, st["interpret"]):
        _metrics.counter("kernel_fallbacks_total", kernel="fused",
                         reason="dtype_unsupported").inc()
        return _fallback(m_a, m_p, ia, ip, prep)
    if not fused_fits_vmem(m_a.shape[-2], m_p.shape[-2], ia.shape[0],
                           l=ia.shape[1], tile=st["tile"], dtype=dtype):
        _metrics.counter("kernel_fallbacks_total", kernel="fused",
                         reason="vmem_overflow").inc()
        return _fallback(m_a, m_p, ia, ip, prep)
    s_pad = -(-ia.shape[0] // 8) * 8
    if not batch_block_fits(1, m_a.shape[-2], m_p.shape[-2], s_pad,
                            ia.shape[1], st["tile"],
                            np.dtype(accum_dtype(dtype)).itemsize):
        # even a single-coloring batch block oversubscribes VMEM
        _metrics.counter("kernel_fallbacks_total", kernel="fused",
                         reason="batch_block").inc()
        return _fallback(m_a, m_p, ia, ip, prep)
    _metrics.counter("kernel_launches_total", kernel="fused",
                     path="pallas").inc()
    batched = m_a.ndim > 2
    lead = m_a.shape[:-2]
    n = m_a.shape[-1]
    m_a3 = m_a.reshape((-1,) + m_a.shape[-2:])
    m_p3 = m_p.reshape((-1,) + m_p.shape[-2:])
    n_pad = st["n_tiles"] * st["tile"]
    if n_pad != n:
        m_a3 = jnp.pad(m_a3, ((0, 0), (0, 0), (0, n_pad - n)))
        m_p3 = jnp.pad(m_p3, ((0, 0), (0, 0), (0, n_pad - n)))
    out = fused_spmm_ema_pallas(
        m_a3, m_p3, ia, ip, prep.arrays["blocks"], prep.arrays["src_tile"],
        prep.arrays["dst_tile"], n_tiles=st["n_tiles"], tile=st["tile"],
        interpret=st["interpret"])[:, :, :n]
    return out.reshape(lead + out.shape[-2:]) if batched else out[0]


def _fallback_shared(m_as, m_p, ias, ips, prep: FusedPrep) -> tuple:
    """Shared fallback: the SpMM still runs ONCE (the sharing survives the
    escape hatch), then one XLA eMA per consumer."""
    from repro.kernels.spmm.ops import _spmm_segment
    _metrics.counter("kernel_launches_total", kernel="fused_shared",
                     path="xla").inc()
    lead = m_p.shape[:-2]
    flat = m_p.reshape((-1, m_p.shape[-1]))
    y = _spmm_segment(flat, prep.arrays["fb_src"], prep.arrays["fb_dst"],
                      prep.n)
    y = y.reshape(lead + (m_p.shape[-2], m_p.shape[-1]))
    return tuple(ema_xla(m_a, y, ia, ip)
                 for m_a, ia, ip in zip(m_as, ias, ips))


def fused_spmm_ema_shared(m_as, m_p: jnp.ndarray, ias, ips,
                          prep: FusedPrep) -> tuple:
    """Per-consumer ``ema(m_a_i, m_p @ A, ia_i, ip_i)`` tuple for a group of
    consumers sharing one passive child. The Pallas path runs the SpMM leg
    once into shared VMEM scratch; tables have shape (..., C, N) with one
    optional shared leading batch dimension.
    """
    st = prep.static
    m_as, ias, ips = tuple(m_as), tuple(ias), tuple(ips)
    dtype = m_p.dtype
    for m_a in m_as:
        dtype = jnp.promote_types(dtype, m_a.dtype)
    c_as = tuple(m.shape[-2] for m in m_as)
    ss = tuple(ia.shape[0] for ia in ias)
    ls = tuple(ia.shape[1] for ia in ias)
    if not pallas_supports_dtype(dtype, st["interpret"]):
        _metrics.counter("kernel_fallbacks_total", kernel="fused_shared",
                         reason="dtype_unsupported").inc()
        return _fallback_shared(m_as, m_p, ias, ips, prep)
    s_pads = tuple(-(-s // 8) * 8 for s in ss)
    item = np.dtype(accum_dtype(dtype)).itemsize
    if not (fused_group_fits_vmem(c_as, m_p.shape[-2], ss, ls,
                                  tile=st["tile"], dtype=dtype)
            and group_batch_block_fits(1, c_as, m_p.shape[-2], s_pads, ls,
                                       st["tile"], item)):
        _metrics.counter("kernel_fallbacks_total", kernel="fused_shared",
                         reason="vmem_overflow").inc()
        return _fallback_shared(m_as, m_p, ias, ips, prep)
    _metrics.counter("kernel_launches_total", kernel="fused_shared",
                     path="pallas").inc()
    batched = m_p.ndim > 2
    lead = m_p.shape[:-2]
    n = m_p.shape[-1]
    m_p3 = m_p.reshape((-1,) + m_p.shape[-2:])
    m_as3 = tuple(m.reshape((-1,) + m.shape[-2:]) for m in m_as)
    n_pad = st["n_tiles"] * st["tile"]
    if n_pad != n:
        pad = ((0, 0), (0, 0), (0, n_pad - n))
        m_p3 = jnp.pad(m_p3, pad)
        m_as3 = tuple(jnp.pad(m, pad) for m in m_as3)
    outs = fused_spmm_ema_shared_pallas(
        m_as3, m_p3, ias, ips, prep.arrays["blocks"],
        prep.arrays["src_tile"], prep.arrays["dst_tile"],
        n_tiles=st["n_tiles"], tile=st["tile"], interpret=st["interpret"])
    outs = tuple(out[:, :, :n] for out in outs)
    if batched:
        return tuple(out.reshape(lead + out.shape[-2:]) for out in outs)
    return tuple(out[0] for out in outs)
