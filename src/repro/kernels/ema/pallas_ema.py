"""Pallas TPU eMA kernel (paper §4.5 Algorithm 4 line 7).

Layout (C, N): color combinations on sublanes, vertices on lanes. The static
split tables IA/IP select rows of the resident child tables; each step is a
vector FMA over a block of vertex lanes:

    out[j, v_blk] = sum_l m_a[IA[j, l], v_blk] * y_p[IP[j, l], v_blk]

Grid: (s_blocks, n_blocks). The child tables keep their full combination
dimension resident in VMEM and are blocked over vertices only — valid for
k <= ~13 (C(13,6) * 512 lanes * 4 B ≈ 3.5 MB per table); larger templates fall
back to the XLA path in ops.py. Row gathers are sublane-dynamic indexing,
which Mosaic lowers to vector loads with a dynamic base — cheap relative to
the lane-dynamic gathers the naive layout would need (that asymmetry is the
whole point of the paper's column-major layout, transposed to TPU lanes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ema_pallas"]


def _kernel(ia_ref, ip_ref, ma_ref, yp_ref, out_ref, *, s_block: int, l: int):
    sb = pl.program_id(0)
    n_blk = out_ref.shape[1]

    def s_body(s, _):
        def l_body(j, row):
            ia = ia_ref[sb * s_block + s, j]
            ip = ip_ref[sb * s_block + s, j]
            a_row = ma_ref[pl.dslice(ia, 1), :]   # (1, N_BLK)
            p_row = yp_ref[pl.dslice(ip, 1), :]   # (1, N_BLK)
            return row + a_row * p_row

        row = jax.lax.fori_loop(0, l, l_body, jnp.zeros((1, n_blk), jnp.float32))
        out_ref[pl.dslice(s, 1), :] = row
        return 0

    jax.lax.fori_loop(0, s_block, s_body, 0)


@functools.partial(
    jax.jit, static_argnames=("s_block", "n_block", "interpret")
)
def ema_pallas(
    m_a: jnp.ndarray,   # (Ca, N) f32
    y_p: jnp.ndarray,   # (Cp, N) f32
    ia: jnp.ndarray,    # (S, L) int32
    ip: jnp.ndarray,    # (S, L) int32
    *,
    s_block: int = 8,
    n_block: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    s, l = ia.shape
    n = m_a.shape[1]
    assert y_p.shape[1] == n
    s_pad = -(-s // s_block) * s_block
    n_pad = -(-n // n_block) * n_block
    if s_pad != s:
        # pad split tables with index 0 references; sliced away afterwards
        ia = jnp.pad(ia, ((0, s_pad - s), (0, 0)))
        ip = jnp.pad(ip, ((0, s_pad - s), (0, 0)))
    if n_pad != n:
        m_a = jnp.pad(m_a, ((0, 0), (0, n_pad - n)))
        y_p = jnp.pad(y_p, ((0, 0), (0, n_pad - n)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(s_pad // s_block, n_pad // n_block),
        in_specs=[
            pl.BlockSpec((m_a.shape[0], n_block), lambda sb, nb, IA, IP: (0, nb)),
            pl.BlockSpec((y_p.shape[0], n_block), lambda sb, nb, IA, IP: (0, nb)),
        ],
        out_specs=pl.BlockSpec((s_block, n_block), lambda sb, nb, IA, IP: (sb, nb)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, s_block=s_block, l=l),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s_pad, n_pad), jnp.float32),
        interpret=interpret,
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
    )(ia, ip, m_a, y_p)
    return out[:s, :n]
