"""Pallas eMA kernel (paper §4.5 Algorithm 4 line 7).

Layout (C, N): color combinations on sublanes, vertices on lanes. The static
split tables IA/IP select rows of the resident child tables; each step is a
vector FMA over a block of vertex lanes:

    out[j, v_blk] = sum_l m_a[IA[j, l], v_blk] * y_p[IP[j, l], v_blk]

Grid: (batch, s_blocks, n_blocks) — a batched (B, C, N) coloring table is one
kernel launch with the batch folded into the leading (parallel) grid axis.
The child tables keep their full combination dimension resident in VMEM and
are blocked over vertices only — valid for k <= ~13 (C(13,6) * 512 lanes *
4 B ≈ 3.5 MB per table); larger templates fall back to the XLA path in
ops.py. Row gathers are sublane-dynamic indexing, which Mosaic lowers to
vector loads with a dynamic base — cheap relative to the lane-dynamic gathers
the naive layout would need (that asymmetry is the whole point of the paper's
column-major layout, transposed to TPU lanes).

Tables of any admitted float dtype pass through with output dtype = promoted
input dtype; rows accumulate in the (storage, accum) pair's accumulator
dtype (f32 for bf16 tables — see ``ops.pallas_dtype_pair``) and cast only at
the final store; the padded tail of the split-table axis is masked, so
padded rows cost no FMAs and write exact zeros. Runs interpreted on CPU and
compiled (parallel dimension semantics) on TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ema_pallas"]


def _kernel(ia_ref, ip_ref, ma_ref, yp_ref, out_ref, *, s_block: int, l: int,
            s_total: int, acc_dtype):
    sb = pl.program_id(1)
    n_blk = out_ref.shape[-1]
    dtype = out_ref.dtype

    def s_body(s, _):
        s_global = sb * s_block + s

        def compute_row():
            # rows accumulate in acc_dtype (f32 for bf16 storage) and cast
            # at the store, so narrow tables never pay accumulation error
            def l_body(j, row):
                ia = ia_ref[s_global, j]
                ip = ip_ref[s_global, j]
                a_row = ma_ref[0, pl.dslice(ia, 1), :]   # (1, N_BLK)
                p_row = yp_ref[0, pl.dslice(ip, 1), :]   # (1, N_BLK)
                return row + a_row.astype(acc_dtype) * p_row.astype(acc_dtype)

            return jax.lax.fori_loop(0, l, l_body,
                                     jnp.zeros((1, n_blk), acc_dtype))

        # padded split rows (s_global >= s_total) skip the FMA loop entirely
        # and store zeros, so padding costs no work and no garbage values
        row = jax.lax.cond(s_global < s_total, compute_row,
                           lambda: jnp.zeros((1, n_blk), acc_dtype))
        out_ref[0, pl.dslice(s, 1), :] = row.astype(dtype)
        return 0

    jax.lax.fori_loop(0, s_block, s_body, 0)


@functools.partial(
    jax.jit, static_argnames=("s_block", "n_block", "interpret")
)
def ema_pallas(
    m_a: jnp.ndarray,   # (Ca, N) or (B, Ca, N)
    y_p: jnp.ndarray,   # (Cp, N) or (B, Cp, N)
    ia: jnp.ndarray,    # (S, L) int32
    ip: jnp.ndarray,    # (S, L) int32
    *,
    s_block: int = 8,
    n_block: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    s, l = ia.shape
    batched = m_a.ndim > 2
    if m_a.ndim != y_p.ndim:
        raise ValueError(f"rank mismatch: {m_a.shape} vs {y_p.shape}")
    if not batched:
        m_a = m_a[None]
        y_p = y_p[None]
    if m_a.ndim != 3:
        # collapse any extra leading dims into one batch axis
        lead = m_a.shape[:-2]
        out = ema_pallas(m_a.reshape((-1,) + m_a.shape[-2:]),
                         y_p.reshape((-1,) + y_p.shape[-2:]), ia, ip,
                         s_block=s_block, n_block=n_block,
                         interpret=interpret)
        return out.reshape(lead + out.shape[-2:])
    dtype = jnp.promote_types(m_a.dtype, y_p.dtype)
    m_a = m_a.astype(dtype)
    y_p = y_p.astype(dtype)
    b, _, n = m_a.shape
    assert y_p.shape[-1] == n
    s_pad = -(-s // s_block) * s_block
    n_pad = -(-n // n_block) * n_block
    if s_pad != s:
        # padded rows are masked inside the kernel (index 0 is a placeholder
        # that is never dereferenced); sliced away afterwards
        ia = jnp.pad(ia, ((0, s_pad - s), (0, 0)))
        ip = jnp.pad(ip, ((0, s_pad - s), (0, 0)))
    if n_pad != n:
        m_a = jnp.pad(m_a, ((0, 0), (0, 0), (0, n_pad - n)))
        y_p = jnp.pad(y_p, ((0, 0), (0, 0), (0, n_pad - n)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, s_pad // s_block, n_pad // n_block),
        in_specs=[
            pl.BlockSpec((1, m_a.shape[1], n_block),
                         lambda bb, sb, nb, IA, IP: (bb, 0, nb)),
            pl.BlockSpec((1, y_p.shape[1], n_block),
                         lambda bb, sb, nb, IA, IP: (bb, 0, nb)),
        ],
        out_specs=pl.BlockSpec((1, s_block, n_block),
                               lambda bb, sb, nb, IA, IP: (bb, sb, nb)),
    )
    from repro.kernels.ema.ops import accum_dtype
    out = pl.pallas_call(
        functools.partial(_kernel, s_block=s_block, l=l, s_total=s,
                          acc_dtype=accum_dtype(dtype)),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, s_pad, n_pad), dtype),
        interpret=interpret,
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel"),
        ),
    )(ia, ip, m_a, y_p)
    out = out[:, :s, :n]
    return out if batched else out[0]
