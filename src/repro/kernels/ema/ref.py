"""Pure-jnp oracle for the eMA (element-wise multiply-add) kernel.

Given child tables in (C, N) layout and static split tables IA/IP of shape
(S, L) (S output color sets, L splits each):

    out[j, v] = sum_l  m_a[IA[j, l], v] * y_p[IP[j, l], v]
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["ema_ref"]


def ema_ref(m_a: jnp.ndarray, y_p: jnp.ndarray,
            ia: jnp.ndarray, ip: jnp.ndarray) -> jnp.ndarray:
    # (S, L, N) gathers — memory-heavy but unambiguous; oracle only.
    ga = m_a[ia, :]          # (S, L, N)
    gp = y_p[ip, :]          # (S, L, N)
    return (ga * gp).sum(axis=1)
