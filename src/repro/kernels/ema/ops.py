"""Jitted eMA dispatch: XLA scan path + Pallas kernel path.

The XLA path scans over the L splits; each step is two row-gathers plus a
fused multiply-add over the full (S, N) tile — the direct JAX transcription of
paper Algorithm 4 line 7. The Pallas path keeps child tables resident in VMEM
(see pallas_ema.py) and is selected when (a) the caller asked for it, (b) the
table dtype is supported by the kernel in the current mode, and (c) the
resident tables fit the VMEM budget at the actual block sizes chosen. A dtype
the kernel does not support falls back to the XLA path *explicitly* — the
Pallas path never downcasts.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import autotune as _autotune
from repro.kernels.ema.pallas_ema import ema_pallas
from repro.obs import metrics as _metrics

__all__ = ["ema", "ema_xla", "ema_chunked", "pack_chunked_splits",
           "ChunkedSplits", "ema_flops", "pallas_supports_dtype",
           "pallas_dtype_pair", "accum_dtype"]

# VMEM budget for the Pallas path: both child tables + out block.
_PALLAS_VMEM_BYTES = 12 * 2 ** 20
_PALLAS_S_BLOCK = 8
_PALLAS_N_BLOCK = 512

# (storage dtype) -> (storage, accumulator) pairs the Pallas kernels run
# without *losing* precision relative to the storage contract. bf16 tables
# are admitted in BOTH modes because every kernel accumulates partial
# products in an f32 VMEM accumulator and casts only at the final store —
# halving HBM table traffic without bf16 accumulation error. f64 stays
# interpret-only (the TPU vector unit has no f64).
_INTERPRET_PAIRS = {
    np.dtype(jnp.float32): np.dtype(jnp.float32),
    np.dtype(jnp.float64): np.dtype(jnp.float64),
    np.dtype(jnp.bfloat16): np.dtype(jnp.float32),
}
_COMPILED_PAIRS = {
    np.dtype(jnp.float32): np.dtype(jnp.float32),
    np.dtype(jnp.bfloat16): np.dtype(jnp.float32),
}


def pallas_dtype_pair(dtype, interpret: bool
                      ) -> tuple[np.dtype, np.dtype] | None:
    """(storage, accumulator) dtype pair for the Pallas kernels, or None.

    None means the kernels cannot run this dtype in this mode without
    downcasting — the dispatch layers fall back to XLA explicitly.
    """
    dt = np.dtype(dtype)
    table = _INTERPRET_PAIRS if interpret else _COMPILED_PAIRS
    acc = table.get(dt)
    return None if acc is None else (dt, acc)


def pallas_supports_dtype(dtype, interpret: bool) -> bool:
    """Whether the Pallas kernels can run this dtype *without* downcasting."""
    return pallas_dtype_pair(dtype, interpret) is not None


def accum_dtype(dtype) -> np.dtype:
    """Accumulator dtype for a storage dtype: sub-f32 storage accumulates
    in f32 (kernel scratch AND the XLA fallback paths), wider passes
    through. This is the \"final reductions in f32\" half of the bf16
    contract — storage is narrow, arithmetic is not."""
    dt = np.dtype(dtype)
    return np.dtype(jnp.float32) if dt.itemsize < 4 else dt


def ema_xla(m_a: jnp.ndarray, y_p: jnp.ndarray,
            ia: jnp.ndarray, ip: jnp.ndarray) -> jnp.ndarray:
    """Child tables (..., C, N); gathers run on axis -2 so an optional
    leading batch dimension broadcasts through the scan untouched.
    Sub-f32 tables accumulate in f32 and cast back at the end, matching
    the kernel path's storage/accumulator contract."""
    store = m_a.dtype
    acc_dt = accum_dtype(store)

    def body(acc, idx):
        ia_l, ip_l = idx
        term = jnp.take(m_a, ia_l, axis=-2).astype(acc_dt) \
            * jnp.take(y_p, ip_l, axis=-2).astype(acc_dt)
        return acc + term, None

    acc0 = jnp.zeros(m_a.shape[:-2] + (ia.shape[0], m_a.shape[-1]), acc_dt)
    acc, _ = jax.lax.scan(body, acc0, (ia.T, ip.T))
    return acc.astype(store)


def ema(m_a: jnp.ndarray, y_p: jnp.ndarray, ia: jnp.ndarray, ip: jnp.ndarray,
        *, use_pallas: bool = False, interpret: bool = True,
        s_block: int | None = None, n_block: int | None = None,
        autotune: bool = False) -> jnp.ndarray:
    """eMA dispatch. ``use_pallas`` selects the kernel path when the dtype is
    supported and the tables fit VMEM at the chosen block sizes; a batched
    (B, C, N) input runs as ONE kernel launch (batch on the grid). Explicit
    ``s_block``/``n_block`` override the defaults; ``autotune=True`` sweeps
    :data:`repro.kernels.autotune.EMA_BLOCK_CANDIDATES` once per shape."""
    dtype = jnp.promote_types(m_a.dtype, y_p.dtype)
    if use_pallas:
        if not pallas_supports_dtype(dtype, interpret):
            _metrics.counter("kernel_fallbacks_total", kernel="ema",
                             reason="dtype_unsupported").inc()
        else:
            if autotune and (s_block is None or n_block is None):
                s_block, n_block = _autotune.ema_blocks(m_a, y_p, ia, ip,
                                                        interpret=interpret)
            sb = s_block or _PALLAS_S_BLOCK
            nb = n_block or _PALLAS_N_BLOCK
            if _fits_vmem(m_a, y_p, n_block=nb, s_block=sb):
                _metrics.counter("kernel_launches_total", kernel="ema",
                                 path="pallas").inc()
                return ema_pallas(m_a, y_p, ia, ip, s_block=sb, n_block=nb,
                                  interpret=interpret)
            _metrics.counter("kernel_fallbacks_total", kernel="ema",
                             reason="vmem_overflow").inc()
    _metrics.counter("kernel_launches_total", kernel="ema", path="xla").inc()
    return ema_xla(m_a, y_p, ia, ip)


def _fits_vmem(m_a, y_p, *, n_block: int = _PALLAS_N_BLOCK,
               s_block: int = _PALLAS_S_BLOCK) -> bool:
    """VMEM residency check at the *actual* block sizes and itemsize: both
    child tables (full combination axis, one n_block of lanes) plus the
    (s_block, n_block) output block."""
    itemsize = np.dtype(jnp.promote_types(m_a.dtype, y_p.dtype)).itemsize
    rows = m_a.shape[-2] + y_p.shape[-2] + s_block
    return rows * n_block * itemsize < _PALLAS_VMEM_BYTES


# ------------------------------------------------------------------ chunked
@dataclasses.dataclass(frozen=True)
class ChunkedSplits:
    """Static pair tables for the colorset-chunked eMA of one plan node.

    The (color set, split) pairs of the node's ``(IA, IP)`` tables are
    grouped by which passive-axis chunk their ``IP`` rank falls in, so each
    chunk's pairs can be applied the moment that slice of the SpMM output
    exists. All arrays are ``(n_chunks, pairs_pad)`` with ``pairs_pad`` a
    multiple of ``pair_block`` (padding pairs have mask 0).
    """

    out_idx: np.ndarray    # output color-set rank of each pair
    a_idx: np.ndarray      # active-child rank
    p_loc: np.ndarray      # passive rank, local to the chunk
    mask: np.ndarray       # 1.0 for real pairs
    n_chunks: int
    chunk_rows: int        # passive rows per chunk (last chunk padded)
    n_out_rows: int        # C(k, t)
    pair_block: int


def pack_chunked_splits(ia, ip, n_passive_rows: int, n_chunks: int,
                        pair_block: int = 128) -> ChunkedSplits:
    """Host-side regrouping of split tables for :func:`ema_chunked`."""
    ia = np.asarray(ia)
    ip = np.asarray(ip)
    s, l = ia.shape
    r = -(-n_passive_rows // n_chunks)
    jj = np.repeat(np.arange(s, dtype=np.int32), l)
    aa = ia.ravel().astype(np.int32)
    pp = ip.ravel().astype(np.int32)
    q_of = pp // r
    counts = np.bincount(q_of, minlength=n_chunks)
    p_max = int(counts.max()) if len(counts) else 1
    p_pad = max(pair_block, -(-p_max // pair_block) * pair_block)
    out_idx = np.zeros((n_chunks, p_pad), np.int32)
    a_idx = np.zeros((n_chunks, p_pad), np.int32)
    p_loc = np.zeros((n_chunks, p_pad), np.int32)
    mask = np.zeros((n_chunks, p_pad), np.float32)
    order = np.argsort(q_of, kind="stable")
    offs = np.concatenate([[0], np.cumsum(counts)])
    for q in range(n_chunks):
        sel = order[offs[q]: offs[q + 1]]
        m = len(sel)
        out_idx[q, :m] = jj[sel]
        a_idx[q, :m] = aa[sel]
        p_loc[q, :m] = pp[sel] - q * r
        mask[q, :m] = 1.0
    return ChunkedSplits(out_idx=out_idx, a_idx=a_idx, p_loc=p_loc,
                         mask=mask, n_chunks=n_chunks, chunk_rows=r,
                         n_out_rows=s, pair_block=pair_block)


def ema_chunked(m_a: jnp.ndarray, m_p: jnp.ndarray, pack: ChunkedSplits,
                spmm_fn) -> jnp.ndarray:
    """eMA that never materializes the full passive SpMM output.

    ``spmm_fn(chunk)`` maps a ``(..., chunk_rows, N)`` slice of the passive
    table to its neighbor sums; the scan walks the ``C(k, t_p)`` axis one
    chunk at a time, applying that chunk's (active, passive, out) pairs in
    ``pair_block``-sized scatter-adds. A leading (B,) batch dimension rides
    through every step natively (gathers on axis -2, scatter-adds under an
    ellipsis) — one scan for the whole coloring batch, no per-element
    serialization. Peak extra memory is one passive chunk + one pair block
    instead of the whole ``C(k, t_p) x N`` table. Matches the unchunked path
    to float reassociation (~1e-6 relative).
    """
    n = m_a.shape[-1]
    lead = m_a.shape[:-2]
    from repro.kernels.spmm.ops import spmm_row_chunks
    m_p_chunks = spmm_row_chunks(m_p, pack.n_chunks)    # (..., Q, R, N)
    # scan iterates the chunk axis, which must lead
    m_p_chunks = jnp.moveaxis(m_p_chunks, -3, 0)        # (Q, ..., R, N)
    pb = pack.pair_block
    n_blocks = pack.out_idx.shape[1] // pb
    oj = jnp.asarray(pack.out_idx)
    ai = jnp.asarray(pack.a_idx)
    pl = jnp.asarray(pack.p_loc)
    mk = jnp.asarray(pack.mask, m_a.dtype)

    def chunk_body(acc, xs):
        m_p_c, oj_c, ai_c, pl_c, mk_c = xs
        y = spmm_fn(m_p_c)                              # (..., R, N)

        def pair_body(acc2, ys):
            o, a, p, w = ys
            term = jnp.take(m_a, a, axis=-2) * jnp.take(y, p, axis=-2) \
                * w[:, None]
            return acc2.at[..., o, :].add(term), None

        acc, _ = jax.lax.scan(
            pair_body, acc,
            (oj_c.reshape(n_blocks, pb), ai_c.reshape(n_blocks, pb),
             pl_c.reshape(n_blocks, pb), mk_c.reshape(n_blocks, pb)))
        return acc, None

    acc0 = jnp.zeros(lead + (pack.n_out_rows, n), m_a.dtype)
    acc, _ = jax.lax.scan(chunk_body, acc0, (m_p_chunks, oj, ai, pl, mk))
    return acc


def ema_flops(n: int, s: int, l: int) -> int:
    """2 flops (mul + add) per (vertex, color set, split)."""
    return 2 * n * s * l
