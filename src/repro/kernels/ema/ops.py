"""Jitted eMA dispatch: XLA scan path + Pallas kernel path.

The XLA path scans over the L splits; each step is two row-gathers plus a
fused multiply-add over the full (S, N) tile — the direct JAX transcription of
paper Algorithm 4 line 7. The Pallas path keeps child tables resident in VMEM
(see pallas_ema.py) and is selected when they fit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ema.pallas_ema import ema_pallas

__all__ = ["ema", "ema_xla", "ema_flops"]

# VMEM budget for the Pallas path: both child tables + out block.
_PALLAS_VMEM_BYTES = 12 * 2 ** 20
_PALLAS_N_BLOCK = 512


def ema_xla(m_a: jnp.ndarray, y_p: jnp.ndarray,
            ia: jnp.ndarray, ip: jnp.ndarray) -> jnp.ndarray:
    """Child tables (..., C, N); gathers run on axis -2 so an optional
    leading batch dimension broadcasts through the scan untouched."""
    def body(acc, idx):
        ia_l, ip_l = idx
        term = jnp.take(m_a, ia_l, axis=-2) * jnp.take(y_p, ip_l, axis=-2)
        return acc + term, None

    acc0 = jnp.zeros(m_a.shape[:-2] + (ia.shape[0], m_a.shape[-1]), m_a.dtype)
    acc, _ = jax.lax.scan(body, acc0, (ia.T, ip.T))
    return acc


def ema(m_a: jnp.ndarray, y_p: jnp.ndarray, ia: jnp.ndarray, ip: jnp.ndarray,
        *, use_pallas: bool = False, interpret: bool = True) -> jnp.ndarray:
    if use_pallas and _fits_vmem(m_a, y_p):
        if m_a.ndim > 2:
            # batched colorings: one kernel launch per batch element inside a
            # single device call (lax.map keeps the grid spec 2-D)
            return jax.lax.map(
                lambda xy: ema_pallas(xy[0], xy[1], ia, ip,
                                      interpret=interpret),
                (m_a, y_p))
        return ema_pallas(m_a, y_p, ia, ip, interpret=interpret)
    return ema_xla(m_a, y_p, ia, ip)


def _fits_vmem(m_a, y_p) -> bool:
    resident = (m_a.shape[-2] + y_p.shape[-2]) * _PALLAS_N_BLOCK * 4
    return resident < _PALLAS_VMEM_BYTES


def ema_flops(n: int, s: int, l: int) -> int:
    """2 flops (mul + add) per (vertex, color set, split)."""
    return 2 * n * s * l
