"""Pallas TPU SpMM — pre-densified block-sparse (BSR) MXU path.

The adjacency is stored as dense 128x128 tiles for nonempty blocks only
(`Graph.bsr()`); after RCM reordering the nonzeros concentrate near the
diagonal so the number of stored blocks approaches E / (tile * avg_deg_local).
Each grid step is a single MXU matmul:

    out[:, dst_tile] += m[:, src_tile] @ block

Blocks are sorted by destination tile (consecutive output revisiting);
src/dst tile ids ride the scalar-prefetch channel into the BlockSpec index
maps. Compared to the gather path this trades HBM footprint
(tile^2 * 4B per nonempty block) for zero densification work per step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["spmm_bsr_pallas"]


def _kernel(src_tile_ref, dst_tile_ref, blocks_ref, m_ref, out_ref, acc_ref):
    b = pl.program_id(1)
    nb = pl.num_programs(1)
    acc_dtype = acc_ref.dtype
    is_first = jnp.logical_or(
        b == 0, dst_tile_ref[b] != dst_tile_ref[jnp.maximum(b - 1, 0)]
    )

    @pl.when(is_first)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # partial sums live in the accumulator scratch (f32 for bf16 storage);
    # the output block is written once, on the tile's last block
    acc_ref[...] += jax.lax.dot(
        m_ref[...].astype(acc_dtype), blocks_ref[0].astype(acc_dtype),
        preferred_element_type=acc_dtype,
    )

    is_last = jnp.logical_or(
        b == nb - 1, dst_tile_ref[b] != dst_tile_ref[jnp.minimum(b + 1, nb - 1)]
    )

    @pl.when(is_last)
    def _store():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("n_tiles", "tile", "c_block", "interpret")
)
def spmm_bsr_pallas(
    m: jnp.ndarray,          # (C, N) float, N = n_tiles * tile
    blocks: jnp.ndarray,     # (n_blocks, tile, tile) {0,1}, cast to m's dtype
    src_tile: jnp.ndarray,   # (n_blocks,) int32
    dst_tile: jnp.ndarray,   # (n_blocks,) int32, sorted ascending
    *,
    n_tiles: int,
    tile: int = 128,
    c_block: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    c, n = m.shape
    assert n == n_tiles * tile, (n, n_tiles, tile)
    dtype = m.dtype
    blocks = blocks.astype(dtype)
    c_pad = -(-c // c_block) * c_block
    if c_pad != c:
        m = jnp.pad(m, ((0, c_pad - c), (0, 0)))
    n_blocks = blocks.shape[0]

    from repro.kernels.ema.ops import accum_dtype
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(c_pad // c_block, n_blocks),
        in_specs=[
            pl.BlockSpec((1, tile, tile), lambda cb, b, st, dt: (b, 0, 0)),
            pl.BlockSpec((c_block, tile), lambda cb, b, st, dt: (cb, st[b])),
        ],
        out_specs=pl.BlockSpec((c_block, tile), lambda cb, b, st, dt: (cb, dt[b])),
        scratch_shapes=[pltpu.VMEM((c_block, tile), accum_dtype(dtype))],
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((c_pad, n), dtype),
        interpret=interpret,
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
    )(src_tile, dst_tile, blocks, m)
    return out[:c]
