"""Pallas TPU SpMM — edge-chunk gather path with on-the-fly densification.

TPU adaptation of the paper's CSC SpMM (§4.5): random column gathers do not
map onto the TPU memory hierarchy, so each destination-tile-sorted edge chunk
is *densified on the fly* inside VMEM via one-hot outer products and applied
as a 128x128 MXU matmul:

    P        = (onehot(src_local) * mask) @ onehot(dst_local)^T   # (T, T)
    out_tile += m_src_tile @ P                                    # MXU

The chunk stream is sorted by destination tile, so the output block stays
resident in VMEM across consecutive grid steps (revisiting pattern) and is
zero-initialized on first visit. ``src_tile``/``dst_tile`` ride the scalar
prefetch channel and drive the BlockSpec index maps (the TPU analogue of the
paper's propagation blocking).

Grid: (c_blocks, n_chunks). VMEM per step:
    m block   (C_BLK, T)     e.g. 512x128x4B = 256 KB
    out block (C_BLK, T)     256 KB
    one-hot scratch / P      (T, E_CHUNK) + (T, T) ≈ 320 KB
comfortably inside the ~16 MB VMEM budget; C_BLK and E_CHUNK are tunable.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["spmm_gather_pallas"]


def _kernel(src_tile_ref, dst_tile_ref,        # scalar prefetch (SMEM)
            src_ref, dstl_ref, mask_ref, m_ref,  # inputs
            out_ref,                            # output
            acc_ref):                           # VMEM accumulator scratch
    t = pl.program_id(1)
    nc = pl.num_programs(1)
    tile = out_ref.shape[1]
    acc_dtype = acc_ref.dtype

    # Zero the accumulator on the first chunk of each destination tile.
    is_first = jnp.logical_or(
        t == 0, dst_tile_ref[t] != dst_tile_ref[jnp.maximum(t - 1, 0)]
    )

    @pl.when(is_first)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    src = src_ref[0, :]            # (E,) global src ids of this chunk
    dstl = dstl_ref[0, :]          # (E,) local dst offsets
    mask = mask_ref[0, :].astype(acc_dtype)  # (E,) {0,1}

    src_local = src - src_tile_ref[t] * tile
    lane = jax.lax.broadcasted_iota(jnp.int32, (tile, src.shape[0]), 0)
    onehot_src = jnp.where(lane == src_local[None, :], mask[None, :],
                           jnp.zeros((), acc_dtype))
    onehot_dst = (lane == dstl[None, :]).astype(acc_dtype)
    p = jax.lax.dot_general(
        onehot_src, onehot_dst,
        (((1,), (1,)), ((), ())),
        preferred_element_type=acc_dtype,
    )                               # (T, T) densified adjacency block
    # partial sums in the accumulator dtype (f32 for bf16 storage); the
    # output block is written once, on the tile's last chunk
    acc_ref[...] += jax.lax.dot(
        m_ref[...].astype(acc_dtype), p, preferred_element_type=acc_dtype
    )

    is_last = jnp.logical_or(
        t == nc - 1, dst_tile_ref[t] != dst_tile_ref[jnp.minimum(t + 1, nc - 1)]
    )

    @pl.when(is_last)
    def _store():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("n_tiles", "tile", "c_block", "interpret"),
)
def spmm_gather_pallas(
    m: jnp.ndarray,            # (C, N) float, N = n_tiles * tile
    src: jnp.ndarray,          # (n_chunks, E) int32 global src ids
    dst_local: jnp.ndarray,    # (n_chunks, E) int32
    mask: jnp.ndarray,         # (n_chunks, E) {0,1}, cast to m's dtype
    src_tile: jnp.ndarray,     # (n_chunks,) int32
    dst_tile: jnp.ndarray,     # (n_chunks,) int32  (sorted ascending)
    *,
    n_tiles: int,
    tile: int = 128,
    c_block: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    from repro.kernels.ema.ops import accum_dtype
    c, n = m.shape
    assert n == n_tiles * tile, (n, n_tiles, tile)
    dtype = m.dtype
    mask = mask.astype(dtype)
    c_pad = -(-c // c_block) * c_block
    if c_pad != c:
        m = jnp.pad(m, ((0, c_pad - c), (0, 0)))
    n_chunks = src.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(c_pad // c_block, n_chunks),
        in_specs=[
            pl.BlockSpec((1, src.shape[1]), lambda cb, t, st, dt: (t, 0)),
            pl.BlockSpec((1, src.shape[1]), lambda cb, t, st, dt: (t, 0)),
            pl.BlockSpec((1, src.shape[1]), lambda cb, t, st, dt: (t, 0)),
            pl.BlockSpec((c_block, tile), lambda cb, t, st, dt: (cb, st[t])),
        ],
        out_specs=pl.BlockSpec((c_block, tile), lambda cb, t, st, dt: (cb, dt[t])),
        scratch_shapes=[pltpu.VMEM((c_block, tile), accum_dtype(dtype))],
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((c_pad, n), dtype),
        interpret=interpret,
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
    )(src_tile, dst_tile, src, dst_local, mask, m)
    return out[:c]
