"""Pure-jnp oracles for the PGBSC SpMM.

Count tables use the (C, N) "combination-major" layout (paper §4.3 column-major
adapted to TPU: vertices ride the 128-wide lane dimension).

SpMM semantics (undirected G, A symmetric):
    Y[r, i] = sum_{j in N(i)} M[r, j]        i.e.  Y = M @ A
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["spmm_dense", "spmm_segment_ref"]


def spmm_dense(m: jnp.ndarray, a_dense: jnp.ndarray) -> jnp.ndarray:
    """Oracle via dense matmul: (C, N) @ (N, N) -> (C, N)."""
    return m @ a_dense.astype(m.dtype)


def spmm_segment_ref(m: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray,
                     n: int) -> jnp.ndarray:
    """Oracle via one big segment-sum over edges (no chunking)."""
    import jax
    contrib = m[:, src]                       # (C, E)
    out = jax.ops.segment_sum(contrib.T, dst, num_segments=n)  # (N, C)
    return out.T
