"""Jitted SpMM dispatch over backends.

``prepare(graph, method)`` lifts a host Graph into the device arrays each
backend needs; ``spmm(m, prep)`` applies Y = M @ A. All backends agree with
``ref.spmm_dense`` / ``ref.spmm_segment_ref`` (tests sweep shapes and dtypes).

Backends:
  segment       chunked gather + segment_sum over edges (XLA; default on CPU)
  ell           padded neighbor-list gather (XLA; good for low max-degree)
  dense         dense matmul (tiny graphs / oracle)
  pallas_gather on-the-fly densified edge chunks on the MXU (TPU target)
  pallas_bsr    pre-densified 128x128 block-sparse MXU path (TPU target)
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.graph.structure import Graph
from repro.kernels import autotune as _autotune
from repro.kernels.ema import ops as ema_ops
from repro.kernels.spmm.pallas_bsr import spmm_bsr_pallas
from repro.kernels.spmm.pallas_gather import spmm_gather_pallas
from repro.obs import metrics as _metrics

__all__ = ["prepare", "spmm", "spmm_row_chunks", "SpmmPrep", "METHODS"]

METHODS = ("segment", "ell", "dense", "pallas_gather", "pallas_bsr")

# Target elements for the (rows x edges) gather intermediate of the segment
# backend; keeps peak memory bounded while amortizing scan overhead.
_SEGMENT_TARGET_ELEMS = 1 << 24


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SpmmPrep:
    """Device-side graph operand for a given backend (a pytree)."""

    method: str
    n: int
    arrays: dict[str, Any]
    static: dict[str, Any]

    def tree_flatten(self):
        keys = sorted(self.arrays)
        return [self.arrays[k] for k in keys], (self.method, self.n, keys,
                                                tuple(sorted(self.static.items())))

    @classmethod
    def tree_unflatten(cls, aux, children):
        method, n, keys, static = aux
        return cls(method, n, dict(zip(keys, children)), dict(static))


def prepare(g: Graph, method: str = "segment", *, tile: int = 128,
            chunk_size: int = 512, interpret: bool = True,
            dtype=jnp.float32, reorder: str = "") -> SpmmPrep:
    """``dtype`` is the table *storage* dtype: the Pallas backends store
    their adjacency operand (dense BSR blocks / gather masks) in it, so a
    bf16 engine streams half the adjacency bytes; kernels still accumulate
    in the (storage, accum) pair's accumulator. ``reorder`` tags the prep
    with the vertex-ordering choice the graph was built under — it rides
    ``static`` into the autotune cache key so timings never cross block
    streams with different locality."""
    if method not in METHODS:
        raise ValueError(f"unknown spmm method {method!r}")
    if method == "segment":
        src, dst = g.edges_by_dst
        return SpmmPrep(method, g.n,
                        {"src": jnp.asarray(src), "dst": jnp.asarray(dst)}, {})
    if method == "ell":
        nbr, mask = g.ell()
        return SpmmPrep(method, g.n,
                        {"nbr": jnp.asarray(nbr), "mask": jnp.asarray(mask)}, {})
    if method == "dense":
        return SpmmPrep(method, g.n, {"a": jnp.asarray(g.to_dense())}, {})
    # Pallas backends also carry the raw edge lists so a dtype the kernel
    # does not support can fall back to the XLA segment path explicitly
    # (never a silent downcast).
    fb_src, fb_dst = g.edges_by_dst
    fb = {"fb_src": jnp.asarray(fb_src), "fb_dst": jnp.asarray(fb_dst)}
    adj_dtype = jnp.dtype(dtype)
    if method == "pallas_gather":
        gp = g.padded(tile)
        ch = gp.edge_chunks(tile=tile, chunk_size=chunk_size)
        return SpmmPrep(
            method, g.n,
            {"src": jnp.asarray(ch.src), "dst_local": jnp.asarray(ch.dst_local),
             "mask": jnp.asarray(ch.mask, adj_dtype),
             "src_tile": jnp.asarray(ch.src_tile),
             "dst_tile": jnp.asarray(ch.dst_tile), **fb},
            {"tile": tile, "n_tiles": ch.n_tiles, "interpret": interpret,
             "reorder": reorder},
        )
    # pallas_bsr
    gp = g.padded(tile)
    bs = gp.bsr(tile=tile)
    return SpmmPrep(
        method, g.n,
        {"blocks": jnp.asarray(bs.blocks, adj_dtype),
         "src_tile": jnp.asarray(bs.src_tile),
         "dst_tile": jnp.asarray(bs.dst_tile), **fb},
        {"tile": tile, "n_tiles": bs.n_tiles, "interpret": interpret,
         "reorder": reorder},
    )


def _spmm_segment(m: jnp.ndarray, src, dst, n: int) -> jnp.ndarray:
    store = m.dtype
    acc_dt = ema_ops.accum_dtype(store)
    c = m.shape[0]
    e = max(int(src.shape[0]), 1)
    row_chunk = max(1, min(c, _SEGMENT_TARGET_ELEMS // e))
    n_chunks = -(-c // row_chunk)
    c_pad = n_chunks * row_chunk
    m_p = jnp.pad(m, ((0, c_pad - c), (0, 0))) if c_pad != c else m
    m_p = m_p.reshape(n_chunks, row_chunk, m.shape[1])

    def body(_, chunk):
        # sub-f32 storage accumulates its edge sums in f32 (same
        # storage/accum contract as the kernels) and casts back at the end
        contrib = chunk[:, src].astype(acc_dt)                    # (rc, E)
        out = jax.ops.segment_sum(contrib.T, dst, num_segments=n)  # (N, rc)
        return None, out.T.astype(store)

    _, out = jax.lax.scan(body, None, m_p)
    return out.reshape(c_pad, m.shape[1])[:c]


def _spmm_ell(m: jnp.ndarray, nbr, mask) -> jnp.ndarray:
    # Y[:, i] = sum_d m[:, nbr[i, d]] * mask[i, d]
    def body(acc, nd):
        col_ids, msk = nd
        return acc + m[:, col_ids] * msk[None, :], None

    acc0 = jnp.zeros_like(m)
    acc, _ = jax.lax.scan(body, acc0, (nbr.T, mask.T))
    return acc


def spmm(m: jnp.ndarray, prep: SpmmPrep, *, c_block: int | None = None,
         autotune: bool = False) -> jnp.ndarray:
    """Y = M @ A for count table m of shape (..., C, N).

    Leading (batch) dimensions are folded into the combination rows: every
    backend treats rows independently, so a (B, C, N) batched table is one
    (B*C, N) SpMM — a single kernel launch for the whole coloring batch.
    A dtype the Pallas kernels do not support in the current mode runs the
    XLA segment path on the prep's fallback edge lists instead (explicit
    fallback, never a downcast). ``c_block`` overrides the Pallas row-block
    heuristic; ``autotune=True`` sweeps candidates once per (shape, dtype).
    """
    if m.ndim > 2:
        lead = m.shape[:-1]
        out = spmm(m.reshape(-1, m.shape[-1]), prep, c_block=c_block,
                   autotune=autotune)
        return out.reshape(lead + (out.shape[-1],))
    a = prep.arrays
    if prep.method == "segment":
        return _spmm_segment(m, a["src"], a["dst"], prep.n)
    if prep.method == "ell":
        return _spmm_ell(m, a["nbr"], a["mask"])
    if prep.method == "dense":
        return m @ a["a"].astype(m.dtype)
    st = prep.static
    if not ema_ops.pallas_supports_dtype(m.dtype, st["interpret"]):
        # explicit XLA fallback — count it so "asked for Pallas, got XLA"
        # is observable (incremented once per traced shape under jit)
        _metrics.counter("kernel_fallbacks_total", kernel="spmm",
                         reason="dtype_unsupported").inc()
        _metrics.counter("kernel_launches_total", kernel="spmm",
                         path="xla").inc()
        return _spmm_segment(m, a["fb_src"], a["fb_dst"], prep.n)
    _metrics.counter("kernel_launches_total", kernel="spmm",
                     path=prep.method).inc()
    n_pad = st["n_tiles"] * st["tile"]
    m_pad = jnp.pad(m, ((0, 0), (0, n_pad - m.shape[1]))) if n_pad != m.shape[1] else m

    def run(cb: int) -> jnp.ndarray:
        if prep.method == "pallas_gather":
            return spmm_gather_pallas(
                m_pad, a["src"], a["dst_local"], a["mask"], a["src_tile"],
                a["dst_tile"], n_tiles=st["n_tiles"], tile=st["tile"],
                c_block=cb, interpret=st["interpret"],
            )
        return spmm_bsr_pallas(
            m_pad, a["blocks"], a["src_tile"], a["dst_tile"],
            n_tiles=st["n_tiles"], tile=st["tile"],
            c_block=cb, interpret=st["interpret"],
        )

    if c_block is None:
        if autotune:
            c_block = _autotune.spmm_c_block(
                m_pad, run, kind=prep.method, interpret=st["interpret"],
                reorder=st.get("reorder", ""))
        else:
            c_block = _pick_c_block(m.shape[0])
    return run(c_block)[:, : m.shape[1]]


def spmm_row_chunks(m: jnp.ndarray, n_chunks: int) -> jnp.ndarray:
    """Split the combination-row axis for the colorset-chunked executor path.

    Returns ``(n_chunks, rows_per_chunk, N)`` with zero-padded tail rows;
    each chunk is a self-contained SpMM operand (rows are independent), so
    the chunked eMA can scan ``spmm(chunk, prep)`` without ever holding the
    full ``C(k, t_p) x N`` neighbor-sum table.
    """
    c, n = m.shape[-2], m.shape[-1]
    r = -(-c // n_chunks)
    pad = n_chunks * r - c
    if pad:
        width = [(0, 0)] * (m.ndim - 2) + [(0, pad), (0, 0)]
        m = jnp.pad(m, width)
    return m.reshape(m.shape[:-2] + (n_chunks, r, n))


def _pick_c_block(c: int) -> int:
    for cand in (256, 128, 64, 32, 16, 8):
        if c >= cand:
            return cand
    return 8


def spmm_flops(g: Graph, rows: int) -> int:
    """Useful work: one add per (edge, row)."""
    return g.m * rows
