"""Training launcher: --arch <id> [--cell <cell>] on whatever devices exist.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --reduced --steps 20 --ckpt /tmp/ck

On real hardware the mesh is derived from jax.devices(); on this CPU
container use --reduced (tiny config) or the dry-run for the full sizes.
Checkpoints/resume via train.checkpoint; data from data.synthetic.
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config, reduced_config
from repro.data.synthetic import make_batch, statics_for
from repro.optim.optimizer import AdamWConfig
from repro.train.checkpoint import restore_checkpoint, save_checkpoint
from repro.train.step import build_train_step, concrete_train_state


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--cell", default=None)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    arch = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    cell_name = args.cell or next(
        c.name for c in arch.cells if c.kind == "train")
    cell = arch.cell(cell_name)
    d_in = cell.dims.get("d_feat")
    statics = statics_for(arch, cell_name)

    state = concrete_train_state(arch, jax.random.PRNGKey(args.seed),
                                 d_in=d_in)
    n_params = sum(x.size for x in
                   jax.tree_util.tree_leaves(state["params"]))
    print(f"arch={arch.arch_id} cell={cell_name} params={n_params / 1e6:.2f}M "
          f"devices={len(jax.devices())}")

    start = 0
    if args.ckpt:
        restored, extras = restore_checkpoint(args.ckpt, state)
        if restored is not None:
            state, start = restored, extras["step"]
            print(f"resumed from step {start}")

    step_fn = jax.jit(build_train_step(
        arch, AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps), statics=statics))

    t0 = time.time()
    for it in range(start, args.steps):
        batch = make_batch(arch, cell_name,
                           jax.random.fold_in(jax.random.PRNGKey(7), it))
        state, metrics = step_fn(state, batch)
        if it % max(args.steps // 10, 1) == 0 or it == args.steps - 1:
            print(f"step {it:5d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({time.time() - t0:.1f}s)", flush=True)
        if args.ckpt and (it + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt, it + 1, state,
                            extras={"step": it + 1})
    print("done")


if __name__ == "__main__":
    main()
