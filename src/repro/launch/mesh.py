"""Production mesh construction.

A function (not a module constant) so importing never touches jax device
state. Single pod: 16x16 = 256 chips (data, model). Multi-pod: 2x16x16 = 512
chips (pod, data, model) — the pod axis carries color-coding iterations /
data parallelism across pods.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh"]


def make_mesh(shape, axes):
    # jax.sharding.AxisType landed after 0.4.x; Auto is the implicit default
    # there, so omitting axis_types is semantics-preserving on old versions.
    kw = {}
    if hasattr(jax.sharding, "AxisType"):
        kw["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, **kw)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)
