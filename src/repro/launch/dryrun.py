import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, record memory/cost analysis + collective bytes.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
        --cell train_4k --mesh single --out results/dryrun

One JSON per (arch, cell, mesh) so independent processes can split the grid.
``--arch pgbsc`` runs the paper's own distributed counting step (RMAT-1M).
"""

import argparse
import json
import sys
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo import collective_summary, count_ops
from repro.analysis.roofline import model_flops, roofline_from_compiled
from repro.configs import get_config, input_specs, resolve_for_mesh, ARCH_IDS
from repro.launch.mesh import make_production_mesh
from repro.train import sharding as shd
from repro.train.step import (abstract_train_state, build_serve_step,
                              build_train_step, param_specs_for)

PGBSC_CELLS = {
    # paper workloads: (graph n, directed edge slots, template)
    "gs20_u5": {"n": 600_000, "e": 62_000_000, "template": "u5"},
    "rmat1m_u7": {"n": 1_000_000, "e": 400_000_000, "template": "u7"},
    "rmat1m_u10": {"n": 1_000_000, "e": 400_000_000, "template": "u10"},
    "rmat1m_u12": {"n": 1_000_000, "e": 400_000_000, "template": "u12"},
}


def _spec_shardings(mesh, tree_specs):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))


def run_cell(arch_id: str, cell_name: str, mesh_kind: str) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = len(mesh.devices.ravel())
    t0 = time.time()

    if arch_id == "pgbsc":
        rec = _run_pgbsc(cell_name, mesh, chips)
    elif arch_id == "pgbsc-opt":
        rec = _run_pgbsc(cell_name, mesh, chips, plan="optimized")
    else:
        rec = _run_arch(arch_id, cell_name, mesh, chips)

    rec.update(arch=arch_id, cell=cell_name, mesh=mesh_kind, chips=chips,
               wall_s=round(time.time() - t0, 1))
    return rec


def _microbatches_for(arch, cell) -> int:
    """Gradient-accumulation factor per train cell (activation memory).

    Extrapolation variants (scan_layers=False) skip microbatching: the total
    per-step work is identical and their job is exact cost counting, not
    memory footprint."""
    if arch.family == "lm" and cell.kind == "train" and \
            getattr(arch.model, "scan_layers", True):
        return 8
    return 1


def _lower_cell(arch, cell_name, mesh):
    """Lower one (arch-variant, cell) on the mesh; returns the Lowered."""
    cell = arch.cell(cell_name)
    batch, bspecs, statics = input_specs(arch, cell_name)
    bspecs = resolve_for_mesh(bspecs, mesh)
    d_in = cell.dims.get("d_feat")
    state = abstract_train_state(arch, d_in=d_in)
    if cell.kind == "train":
        pspecs = param_specs_for(arch, state["params"], mesh)
        state_specs = {"params": pspecs,
                       "opt": shd.opt_state_specs(pspecs, state["params"],
                                                  mesh)}
        mb = _microbatches_for(arch, cell)
        step = build_train_step(arch, statics=statics, microbatches=mb)
        in_sh = (_spec_shardings(mesh, state_specs),
                 _spec_shardings(mesh, bspecs))
        return jax.jit(step, in_shardings=in_sh,
                       donate_argnums=0).lower(state, batch)
    params = state["params"]
    pspecs = param_specs_for(arch, params, mesh)
    hints = None
    if arch.family == "lm" and cell.kind == "decode":
        from repro.configs.shapes import decode_hint_specs
        hspecs = resolve_for_mesh(decode_hint_specs(arch, cell), mesh)
        hints = {k: NamedSharding(mesh, v) for k, v in hspecs.items()}
    serve = build_serve_step(
        arch, cell.kind if cell.kind in ("prefill", "decode",
                                         "retrieval") else "serve",
        statics=statics, shard_hints=hints)
    in_sh = (_spec_shardings(mesh, pspecs), _spec_shardings(mesh, bspecs))
    donate = (1,) if cell.kind == "decode" else ()
    return jax.jit(serve, in_shardings=in_sh,
                   donate_argnums=donate).lower(params, batch)


def _lm_variant(arch, n_scan: int):
    """Arch with a reduced, *unrolled* layer count + HLO-visible attention
    chunks, for the layer-linear cost extrapolation (see _run_arch)."""
    import dataclasses
    m = arch.model
    front = m.first_dense_layers if m.moe else 0
    return dataclasses.replace(
        arch, model=dataclasses.replace(
            m, n_layers=front + n_scan, attn_unroll=True,
            scan_layers=False))


def _moe_grouped(arch, mesh):
    """Set MoE dispatch groups = data-shard count (GShard grouping)."""
    import dataclasses
    m = arch.model
    if getattr(m, "moe", None) is None:
        return arch
    g = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            g *= mesh.shape[ax]
    return dataclasses.replace(
        arch, model=dataclasses.replace(
            m, moe=dataclasses.replace(m.moe, groups=g)))


def _run_arch(arch_id, cell_name, mesh, chips):
    arch = _moe_grouped(get_config(arch_id), mesh)
    cell = arch.cell(cell_name)

    # full-size compile: memory analysis + collective structure
    lowered = _lower_cell(arch, cell_name, mesh)
    rec = _finalize(lowered, chips, mf=model_flops(arch, cell))

    if arch.family == "lm":
        # XLA HloCostAnalysis counts while (scan) bodies once; flops/bytes/
        # collective-bytes are exactly linear in the scanned layer count, so
        # two small compiles with unrolled attention chunks give the exact
        # totals: f(L) = f(2) + (L-2)/2 * (f(4) - f(2)).
        m = arch.model
        front = m.first_dense_layers if m.moe else 0
        l_full = m.n_layers - front
        roof2 = _roof_only(_lm_variant(arch, 2), cell_name, mesh, chips)
        roof4 = _roof_only(_lm_variant(arch, 4), cell_name, mesh, chips)

        def extrap(k):
            return roof2[k] + (l_full - 2) / 2.0 * (roof4[k] - roof2[k])

        from repro.analysis.roofline import RooflineTerms
        corrected = RooflineTerms(
            flops=extrap("flops"), bytes_accessed=extrap("bytes"),
            collective_bytes=extrap("collective_bytes"), chips=chips)
        rec["roofline_raw_scan_body"] = rec["roofline"]
        rec["roofline"] = corrected.as_dict()
        rec["useful_flops_ratio"] = (
            rec["model_flops_per_device"] / corrected.flops
            if corrected.flops else None)
        rec["extrapolation"] = {"l2": roof2, "l4": roof4,
                                "l_full_scanned": l_full}
    return rec


def _roof_only(arch_variant, cell_name, mesh, chips) -> dict:
    lowered = _lower_cell(arch_variant, cell_name, mesh)
    compiled = lowered.compile()
    text = compiled.as_text()
    roof = roofline_from_compiled(compiled, chips, hlo_text=text)
    return {"flops": roof.flops, "bytes": roof.bytes_accessed,
            "collective_bytes": roof.collective_bytes}


def _run_pgbsc(cell_name, mesh, chips, plan: str = "dedup"):
    from repro.core.distributed import DistributedPgbsc
    from repro.core.templates import get_template
    spec = PGBSC_CELLS[cell_name]
    dist = DistributedPgbsc(
        None, get_template(spec["template"]), mesh, plan=plan,
        abstract_dims={"n": spec["n"], "e": spec["e"]})
    step, args, shardings = dist.count_step_fn()
    jitted = jax.jit(step, in_shardings=shardings)
    lowered = jitted.lower(*args)
    mf = 2.0 * (dist.plan.n_nodes * spec["e"])  # order-of-magnitude useful work
    return _finalize(lowered, chips, mf=mf)


def _finalize(lowered, chips, mf: float) -> dict:
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    text = compiled.as_text()
    roof = roofline_from_compiled(compiled, chips, hlo_text=text)
    colls = collective_summary(text)
    per_dev_model_flops = mf / chips
    rec = {
        "ok": True,
        "compile_s": round(compile_s, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        },
        "roofline": roof.as_dict(),
        "collectives": colls,
        "hlo_ops": count_ops(text),
        "model_flops_global": mf,
        "model_flops_per_device": per_dev_model_flops,
        "useful_flops_ratio": (per_dev_model_flops / roof.flops
                               if roof.flops else None),
    }
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--cell", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)

    archs = list(ARCH_IDS) + ["pgbsc"] if args.arch == "all" \
        else args.arch.split(",")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    os.makedirs(args.out, exist_ok=True)

    n_fail = 0
    for arch_id in archs:
        if arch_id in ("pgbsc", "pgbsc-opt"):
            cells = list(PGBSC_CELLS)
        else:
            cells = [c.name for c in get_config(arch_id).cells]
        if args.cell != "all":
            cells = [c for c in cells if c in args.cell.split(",")]
        for cell in cells:
            for mesh_kind in meshes:
                tag = f"{arch_id}__{cell}__{mesh_kind}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip] {tag}")
                    continue
                print(f"[run ] {tag}", flush=True)
                try:
                    rec = run_cell(arch_id, cell, mesh_kind)
                except Exception as e:  # noqa: BLE001 — record the failure
                    rec = {"ok": False, "arch": arch_id, "cell": cell,
                           "mesh": mesh_kind, "error": repr(e),
                           "traceback": traceback.format_exc()[-4000:]}
                    n_fail += 1
                    print(f"[FAIL] {tag}: {e!r}", flush=True)
                with open(path + ".tmp", "w") as f:
                    json.dump(rec, f, indent=1)
                os.replace(path + ".tmp", path)
                if rec.get("ok"):
                    r = rec["roofline"]
                    print(f"[ ok ] {tag} compile={rec['compile_s']}s "
                          f"flops={r['flops']:.3e} bytes={r['bytes']:.3e} "
                          f"coll={r['collective_bytes']:.3e} "
                          f"dom={r['dominant']}", flush=True)
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
