"""Counting service launcher: a thin CLI over ``repro.service``.

    PYTHONPATH=src python -m repro.launch.serve \\
        --graph rmat:10 --templates u5,u7,path9 --rel-stderr 0.05 \\
        --template-edges "0-1,1-2,1-3@0"

Two modes share every engine/cache/obs flag:

* **batch** (default): each template becomes one request, the synchronous
  round scheduler drives them to completion, results print and the
  process exits.
* **serving** (``--http PORT``): starts the continuously-admitting
  :class:`~repro.service.async_loop.AsyncCountingService` plus the
  stdlib HTTP/JSON front end (``POST /count``, ``GET /result/<id>``,
  ``/metrics``, ``/metrics.json``, ``/healthz``) and runs until
  SIGINT/SIGTERM. ``--templates`` are pre-warmed into the engine pool so
  the first interactive request never pays a cold compile;
  ``--queue-depth`` bounds admission (overflow requests are shed with
  HTTP 429). ``--metrics-out`` writes the final snapshot on shutdown.

Failure containment knobs (both modes): ``--dispatch-timeout`` /
``--dispatch-retries`` shape the per-dispatch watchdog + retry budget;
``--inject`` arms the deterministic fault-injection harness (chaos
testing — e.g. ``--inject kernel.dispatch:raise:0.2``).

Each template in ``--templates`` becomes one service request (repeats are
real repeated requests — they exercise the engine cache and dispatch-group
sharing); names accept the registry plus dynamic ``path{k}`` / ``star{k}``
forms. ``--template-edges`` (repeatable) submits an *arbitrary* tree as
``"u-v,u-v,...[@root]"`` — the query API's TemplateSpec — and shares
caches/groups with any name spelling the same tree, because identity is
the canonical template hash. With ``--rel-stderr`` the scheduler stops
each request adaptively at the target precision, capped at ``--iters``;
without it every request runs exactly ``--iters`` iterations. Results
always report the estimate, its standard error, and the 95% confidence
interval from the per-iteration color-coding samples. Use ``--edge-list``
to serve a real graph; ``--results-cache`` persists answers across
invocations.
"""

from __future__ import annotations

import argparse
import json

from repro.core.templates import TemplateSpec
from repro.graph import erdos_renyi, rmat
from repro.obs import metrics as obs_metrics
from repro.obs import tracing as obs_tracing
from repro.obs.validate import validate_snapshot
from repro.service import CountingService, CountRequest
from repro.service.cache import DEFAULT_MAX_ENTRIES, EngineCache


def _load_graph(spec: str, edge_list: str | None):
    if edge_list:
        from repro.graph.io import load_cached
        return load_cached(edge_list)
    kind, _, arg = spec.partition(":")
    if kind == "rmat":
        return rmat(int(arg or 12), 16, seed=0)
    if kind == "er":
        n = int(arg or 1000)
        return erdos_renyi(n, 8.0, seed=0)
    raise ValueError(f"unknown graph spec {spec!r}")


def _retry_policy(args):
    from repro.resilience.retry import RetryPolicy
    return RetryPolicy(
        max_attempts=max(args.dispatch_retries, 1),
        timeout_s=args.dispatch_timeout if args.dispatch_timeout else None)


def _serve_http(args, g, budget, engine_kw) -> int:
    """Serving mode: async QoS service + HTTP front end until SIGINT."""
    import signal
    import threading

    from repro.service import AsyncCountingService
    from repro.service.frontend import serve_forever

    svc = AsyncCountingService(
        ledger_root=args.ledger, round_size=args.round_size,
        default_max_iters=args.iters, batch_size=args.batch_size,
        memory_budget_bytes=budget,
        engine_cache=EngineCache(max_entries=args.engine_cache_size),
        estimate_cache=args.results_cache,
        engine_kw=engine_kw or None,
        max_queue_depth=args.queue_depth,
        warm_pool=not args.no_warm_pool,
        retry_policy=_retry_policy(args))
    svc.add_graph("g", g)
    # pre-warm the advertised templates: cold build+compile lands here,
    # on startup/idle time, never on the first interactive request
    for tpl in [t for t in args.templates.split(",") if t]:
        svc.prewarm("g", tpl, args.engine, args.plan)
    for i, es in enumerate(args.template_edges):
        svc.prewarm("g", TemplateSpec.from_edge_string(es, name=f"edges{i}"),
                    args.engine, args.plan)
    httpd = serve_forever(svc, host=args.host, port=args.http)
    host, port = httpd.server_address[:2]
    print(f"serving HTTP on {host}:{port} (graph 'g', queue depth "
          f"{args.queue_depth}); POST /count, GET /result/<id>, "
          f"/metrics, /metrics.json, /healthz", flush=True)
    stop = threading.Event()
    for sig in (signal.SIGINT, signal.SIGTERM):
        signal.signal(sig, lambda *_: stop.set())
    try:
        stop.wait()
    finally:
        print("shutting down...", flush=True)
        httpd.shutdown()
        svc.close()
        if args.metrics_out:
            snap = obs_metrics.snapshot()
            validate_snapshot(snap)
            with open(args.metrics_out, "w") as f:
                json.dump(snap, f, indent=1, sort_keys=True)
            print(f"metrics snapshot -> {args.metrics_out}", flush=True)
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="rmat:12")
    ap.add_argument("--edge-list", default=None)
    ap.add_argument("--templates", default="u5,u7")
    ap.add_argument("--template-edges", action="append", default=[],
                    metavar="EDGES",
                    help="arbitrary tree template as 'u-v,u-v,...[@root]' "
                         "(repeatable); shares caches with any registry "
                         "name spelling the same tree")
    ap.add_argument("--iters", type=int, default=64,
                    help="iteration cap (exact budget when no --rel-stderr)")
    ap.add_argument("--rel-stderr", type=float, default=None,
                    help="adaptive precision target (stderr / |estimate|)")
    ap.add_argument("--ledger", default="/tmp/pgbsc_serve")
    ap.add_argument("--results-cache", default=None,
                    help="JSON path for the persistent estimate cache")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", default="pgbsc")
    ap.add_argument("--plan", default="optimized",
                    choices=["plain", "dedup", "optimized"])
    ap.add_argument("--round-size", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=None,
                    help="dispatch batch override (default: derived from "
                         "the memory budget by the executor's memory model)")
    ap.add_argument("--memory-budget-mb", type=float, default=None,
                    help="per-engine device table budget in MiB; sets the "
                         "dispatch batch size and, for large templates, "
                         "colorset-chunked execution")
    ap.add_argument("--engine-cache-size", type=int,
                    default=DEFAULT_MAX_ENTRIES,
                    help="max resident engines; evicted engines release "
                         "their device arrays and compiled fns")
    ap.add_argument("--fuse", action="store_true",
                    help="enable the fused SpMM->eMA Pallas kernel path")
    ap.add_argument("--reorder", default=None,
                    choices=("rcm", "degree"),
                    help="permute vertices once per engine for BSR "
                         "locality (rcm: fewer occupied tiles; degree: "
                         "gather-path balance); results stay in the "
                         "input vertex ids")
    ap.add_argument("--dtype", default=None,
                    choices=("float32", "float64", "bfloat16"),
                    help="node-table/adjacency storage dtype; bfloat16 "
                         "halves table bytes and accumulates in float32")
    ap.add_argument("--trace", action="store_true",
                    help="enable span tracing with device-sync timing; "
                         "prints a per-request latency breakdown "
                         "(queue/compile/execute) and a span summary")
    ap.add_argument("--metrics-out", default=None, metavar="FILE",
                    help="write the metrics-registry snapshot (validated "
                         "JSON, schema v1) to FILE on exit")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="arm a one-shot jax.profiler trace around the "
                         "first device dispatch, written to DIR")
    ap.add_argument("--http", type=int, default=None, metavar="PORT",
                    help="serving mode: run the async QoS service behind "
                         "an HTTP/JSON front end on PORT until SIGINT "
                         "(0 = ephemeral port, printed on startup)")
    ap.add_argument("--host", default="127.0.0.1",
                    help="bind address for --http")
    ap.add_argument("--queue-depth", type=int, default=1024,
                    help="async admission-queue bound; overflow requests "
                         "are shed (HTTP 429 / status SHED)")
    ap.add_argument("--no-warm-pool", action="store_true",
                    help="disable idle-time engine pre-materialization "
                         "in serving mode")
    ap.add_argument("--inject", default=None, metavar="PLAN",
                    help="arm the fault-injection harness: inline "
                         "'point:mode[:rate[:times]],...' specs or a JSON "
                         "plan file (chaos testing; see repro.resilience."
                         "faults)")
    ap.add_argument("--inject-seed", type=int, default=0,
                    help="seed for the deterministic fault schedule")
    ap.add_argument("--dispatch-timeout", type=float, default=120.0,
                    metavar="S",
                    help="wall-clock watchdog per device dispatch; a hung "
                         "dispatch is abandoned and retried (0 = off)")
    ap.add_argument("--dispatch-retries", type=int, default=4,
                    metavar="N",
                    help="retry budget per dispatch (jittered exponential "
                         "backoff between attempts)")
    args = ap.parse_args(argv)

    if args.trace:
        obs_tracing.configure(enabled=True, sync=True)
    if args.profile_dir:
        obs_tracing.arm_profiler(args.profile_dir)
    if args.inject:
        from repro.resilience import faults as _faults
        plan = _faults.FaultPlan.parse(args.inject, seed=args.inject_seed)
        _faults.install_plan(plan)
        print(f"fault injection armed: {len(plan.specs)} spec(s), "
              f"seed {args.inject_seed}", flush=True)

    g = _load_graph(args.graph, args.edge_list)
    print(f"serving graph: n={g.n} edge-slots={g.m} "
          f"avg_deg={g.avg_degree:.1f} fingerprint={g.fingerprint[:12]}")

    budget = None if args.memory_budget_mb is None \
        else int(args.memory_budget_mb * 2 ** 20)
    engine_kw = {}
    if args.fuse:
        engine_kw["fuse_spmm_ema"] = True
    if args.reorder:
        engine_kw["reorder"] = args.reorder
    if args.dtype:
        import jax.numpy as jnp
        engine_kw["dtype"] = getattr(jnp, args.dtype)
    if args.http is not None:
        return _serve_http(args, g, budget, engine_kw)
    svc = CountingService(
        ledger_root=args.ledger, round_size=args.round_size,
        default_max_iters=args.iters, batch_size=args.batch_size,
        memory_budget_bytes=budget,
        engine_cache=EngineCache(max_entries=args.engine_cache_size),
        estimate_cache=args.results_cache,
        engine_kw=engine_kw or None,
        retry_policy=_retry_policy(args))
    svc.add_graph("g", g)
    templates: list = [t for t in args.templates.split(",") if t]
    for i, es in enumerate(args.template_edges):
        templates.append(TemplateSpec.from_edge_string(es, name=f"edges{i}"))
    rids = []
    for tpl in templates:
        rid = svc.submit(CountRequest(
            graph="g", template=tpl, engine=args.engine, plan=args.plan,
            rel_stderr=args.rel_stderr, max_iters=args.iters,
            seed=args.seed))
        label = tpl if isinstance(tpl, str) else tpl.display_name
        rids.append((rid, label))
    svc.run()

    results = {}
    for rid, tname in rids:
        res = svc.result(rid)
        d = res.to_dict()
        results[f"{rid}:{tname}"] = d
        lo, hi = res.ci95
        tags = [t for t, on in (("cache", res.from_cache),
                                ("shared", res.shared_group)) if on]
        print(f"  {rid} {tname}: estimate={res.estimate:.6g} "
              f"+- {res.stderr:.3g} (rel={res.rel_stderr:.3g}, "
              f"ci95=[{lo:.6g}, {hi:.6g}], {res.iterations} iters, "
              f"{res.seconds:.1f}s{', ' + '+'.join(tags) if tags else ''})")
        if args.trace and res.breakdown:
            b = res.breakdown
            accounted = b["queue_s"] + b["compile_s"] + b["execute_s"]
            pct = 100.0 * accounted / b["total_s"] if b["total_s"] else 100.0
            print(f"      breakdown: queue={b['queue_s'] * 1e3:.1f}ms "
                  f"compile={b['compile_s'] * 1e3:.1f}ms "
                  f"execute={b['execute_s'] * 1e3:.1f}ms "
                  f"total={b['total_s'] * 1e3:.1f}ms "
                  f"({pct:.1f}% accounted)")

    stats = svc.stats()
    results["_service"] = stats
    ec = stats["engine_cache"]
    print(f"engine builds: {ec['builds']} for {len(rids)} requests "
          f"(cache hits {ec['hits']}, dispatch groups {stats['groups']})")
    if args.rel_stderr is not None:
        fixed = args.iters * len(rids)
        used = stats["unique_iterations"]
        print(f"adaptive stopping: {used} device iterations vs "
              f"{fixed} fixed-budget baseline "
              f"({100 * (1 - used / max(fixed, 1)):.0f}% saved)")

    if args.trace:
        agg = obs_tracing.get_tracer().breakdown()
        print("span summary (count, total seconds):")
        for name, ent in sorted(agg.items(),
                                key=lambda kv: -kv[1]["seconds"]):
            print(f"  {name:<24s} x{ent['count']:<5d} "
                  f"{ent['seconds']:.3f}s")
    if args.metrics_out:
        snap = obs_metrics.snapshot()
        validate_snapshot(snap)
        with open(args.metrics_out, "w") as f:
            json.dump(snap, f, indent=1, sort_keys=True)
        print(f"metrics snapshot (schema {snap['schema']}, "
              f"{len(snap['counters'])} counters, "
              f"{len(snap['histograms'])} histograms) "
              f"-> {args.metrics_out}")
    print(json.dumps(results, indent=1))


if __name__ == "__main__":
    main()
