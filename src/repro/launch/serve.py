"""Counting service launcher: batched subgraph-counting requests with
fault-tolerant execution — the serving driver for the paper's kind of system.

    PYTHONPATH=src python -m repro.launch.serve \
        --graph rmat:12 --templates u5,u7 --iters 32 --ledger /tmp/svc

Requests = (template, precision target); the service runs color-coding
iterations through the EstimatorRunner (resumable per request) and reports
estimates with standard errors. Use --edge-list to serve a real graph.
"""

from __future__ import annotations

import argparse
import json
import time

from repro.core import build_engine, get_template
from repro.core.runner import EstimatorRunner, engine_counter
from repro.graph import erdos_renyi, rmat


def _load_graph(spec: str, edge_list: str | None):
    if edge_list:
        from repro.graph.io import load_cached
        return load_cached(edge_list)
    kind, _, arg = spec.partition(":")
    if kind == "rmat":
        return rmat(int(arg or 12), 16, seed=0)
    if kind == "er":
        n = int(arg or 1000)
        return erdos_renyi(n, 8.0, seed=0)
    raise ValueError(f"unknown graph spec {spec!r}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="rmat:12")
    ap.add_argument("--edge-list", default=None)
    ap.add_argument("--templates", default="u5,u7")
    ap.add_argument("--iters", type=int, default=32)
    ap.add_argument("--ledger", default="/tmp/pgbsc_serve")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", default="pgbsc")
    ap.add_argument("--plan", default="optimized",
                    choices=["plain", "dedup", "optimized"])
    args = ap.parse_args(argv)

    g = _load_graph(args.graph, args.edge_list)
    print(f"serving graph: n={g.n} edge-slots={g.m} "
          f"avg_deg={g.avg_degree:.1f}")

    results = {}
    for tname in args.templates.split(","):
        t = get_template(tname)
        t0 = time.time()
        eng = build_engine(g, t, args.engine, plan=args.plan)
        runner = EstimatorRunner(
            engine_counter(eng, seed=args.seed), k=t.k,
            automorphisms=t.automorphisms, n_iterations=args.iters,
            ledger_dir=f"{args.ledger}/{tname}", checkpoint_every=8,
            seed=args.seed)
        res = runner.run()
        import numpy as np
        samples = None
        stderr = 0.0
        dt = time.time() - t0
        results[tname] = {
            "estimate": res.count,
            "iterations": len(res.completed),
            "restarts": res.restarts,
            "seconds": round(dt, 2),
            "flops_per_iter": eng.flops_per_iteration,
        }
        print(f"  {tname}: estimate={res.count:.6g} "
              f"({len(res.completed)} iters, {dt:.1f}s, "
              f"restarts={res.restarts})")
    print(json.dumps(results, indent=1))


if __name__ == "__main__":
    main()
