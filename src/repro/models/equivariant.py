"""NequIP-style E(3)-equivariant GNN (l_max = 2) in Cartesian form.

Irreps are represented in Cartesian tensors (equivalent to real spherical
harmonics up to an orthogonal change of basis, which preserves equivariance):

    l=0  scalars             (N, C0)
    l=1  vectors             (N, C1, 3)
    l=2  symmetric traceless (N, C2, 3, 3)

Edge "spherical harmonics": Y0 = 1, Y1 = r_hat, Y2 = r_hat r_hat^T - I/3.
Tensor-product paths (l1 x l2 -> l3) use closed Cartesian forms (dot, cross,
matvec, symmetric-traceless outer/anticommutator, Levi-Civita contraction),
weighted per channel by a radial MLP over n_rbf Bessel bases with a smooth
polynomial cutoff — the NequIP interaction block. Gates: scalars pass through
SiLU; l>0 features are gated by sigmoid(scalar channels).

Equivariance under proper rotations SO(3) (rotate inputs => outputs rotate
accordingly; energies invariant) is asserted in tests/test_models_gnn.py.
Parity (O(3) reflections) is not tracked per channel — cross-product paths mix
pseudo/true tensors; strict-NequIP parity bookkeeping is noted as a deviation
in DESIGN.md.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["init_nequip", "nequip_forward", "nequip_energy_loss"]

_EPS = 1e-9
_I3 = jnp.eye(3)


# ---------------------------------------------------------------- tensor ops
def sym_traceless(m: jnp.ndarray) -> jnp.ndarray:
    s = 0.5 * (m + jnp.swapaxes(m, -1, -2))
    tr = jnp.trace(s, axis1=-2, axis2=-1)[..., None, None]
    return s - tr * _I3 / 3.0


def _levi_civita_contract(m: jnp.ndarray, n: jnp.ndarray) -> jnp.ndarray:
    """(2 x 2 -> 1): v_i = eps_{ijk} (M N)_{jk}."""
    mn = m @ n
    return jnp.stack([mn[..., 1, 2] - mn[..., 2, 1],
                      mn[..., 2, 0] - mn[..., 0, 2],
                      mn[..., 0, 1] - mn[..., 1, 0]], axis=-1)


# ------------------------------------------------------------------- radial
def bessel_basis(r: jnp.ndarray, n_rbf: int, cutoff: float) -> jnp.ndarray:
    """(E,) -> (E, n_rbf) sinc-like Bessel bases with polynomial cutoff."""
    r = jnp.maximum(r, _EPS)
    n = jnp.arange(1, n_rbf + 1, dtype=r.dtype)
    basis = jnp.sqrt(2.0 / cutoff) * jnp.sin(
        n * jnp.pi * r[:, None] / cutoff) / r[:, None]
    # smooth cutoff envelope (p=6 polynomial, NequIP eq. 8)
    u = jnp.clip(r / cutoff, 0.0, 1.0)
    env = 1 - 28 * u**6 + 48 * u**7 - 21 * u**8
    return basis * env[:, None]


def _mlp(key, dims, dtype):
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": (jax.random.normal(k, (a, b)) * a ** -0.5).astype(dtype),
             "b": jnp.zeros((b,), dtype)}
            for k, a, b in zip(ks, dims[:-1], dims[1:])]


def _apply_mlp(layers, x):
    for i, p in enumerate(layers):
        x = x @ p["w"] + p["b"]
        if i < len(layers) - 1:
            x = jax.nn.silu(x)
    return x


# -------------------------------------------------------------------- model
# tensor-product paths used per interaction: (l_in, l_sh, l_out)
_PATHS = [(0, 0, 0), (0, 1, 1), (0, 2, 2),
          (1, 0, 1), (1, 1, 0), (1, 1, 1), (1, 1, 2), (1, 2, 1),
          (2, 0, 2), (2, 1, 1), (2, 2, 0), (2, 1, 2), (2, 2, 1), (2, 2, 2)]


def init_nequip(key, cfg, n_species: int = 16) -> dict:
    """cfg: GNNConfig(kind='nequip') with extras l_max, n_rbf, cutoff."""
    dt = cfg.param_dtype
    c = cfg.d_hidden                       # channels per l
    n_rbf = cfg.extra("n_rbf", 8)
    keys = jax.random.split(key, cfg.n_layers * 2 + 3)
    layers = []
    for i in range(cfg.n_layers):
        kr, ks = jax.random.split(keys[i])
        layers.append({
            # radial MLP emits one weight per (path, channel)
            "radial": _mlp(kr, (n_rbf, 32, len(_PATHS) * c), dt),
            # per-l self-interaction channel mixers
            "mix0": (jax.random.normal(ks, (c, c)) * c ** -0.5).astype(dt),
            "mix1": (jax.random.normal(jax.random.fold_in(ks, 1), (c, c))
                     * c ** -0.5).astype(dt),
            "mix2": (jax.random.normal(jax.random.fold_in(ks, 2), (c, c))
                     * c ** -0.5).astype(dt),
            # gate scalars for l=1 and l=2
            "gate": (jax.random.normal(jax.random.fold_in(ks, 3), (c, 2 * c))
                     * c ** -0.5).astype(dt),
        })
    return {
        "species_embed": (jax.random.normal(keys[-1], (n_species, c))
                          * 0.1).astype(dt),
        "layers": layers,
        "readout": _mlp(keys[-2], (c, 32, 1), dt),
    }


def _tp_accumulate(feats, sh, w, c):
    """Weighted tensor products of node feats with edge harmonics.

    feats: dict l -> per-edge gathered features (E, C[, 3[, 3]])
    sh:    dict l -> edge harmonics (E[, 3[, 3]])
    w:     (E, n_paths, C) radial weights
    Returns per-edge messages dict l -> (E, C, ...).
    """
    e = w.shape[0]
    out = {0: jnp.zeros((e, c)),
           1: jnp.zeros((e, c, 3)),
           2: jnp.zeros((e, c, 3, 3))}
    y1 = sh[1][:, None, :]                     # (E, 1, 3)
    y2 = sh[2][:, None, :, :]                  # (E, 1, 3, 3)
    x0, x1, x2 = feats[0], feats[1], feats[2]

    for pi, (li, ls, lo) in enumerate(_PATHS):
        wp = w[:, pi, :]                       # (E, C)
        if (li, ls, lo) == (0, 0, 0):
            r = x0
        elif (li, ls, lo) == (0, 1, 1):
            r = x0[..., None] * y1
        elif (li, ls, lo) == (0, 2, 2):
            r = x0[..., None, None] * y2
        elif (li, ls, lo) == (1, 0, 1):
            r = x1
        elif (li, ls, lo) == (1, 1, 0):
            r = jnp.einsum("eci,ei->ec", x1, sh[1])
        elif (li, ls, lo) == (1, 1, 1):
            r = jnp.cross(x1, jnp.broadcast_to(y1, x1.shape))
        elif (li, ls, lo) == (1, 1, 2):
            outer = x1[..., :, None] * y1[..., None, :]
            r = sym_traceless(outer)
        elif (li, ls, lo) == (1, 2, 1):
            r = jnp.einsum("eij,ecj->eci", sh[2], x1)
        elif (li, ls, lo) == (2, 0, 2):
            r = x2
        elif (li, ls, lo) == (2, 1, 1):
            r = jnp.einsum("ecij,ej->eci", x2, sh[1])
        elif (li, ls, lo) == (2, 2, 0):
            r = jnp.einsum("ecij,eij->ec", x2, sh[2])
        elif (li, ls, lo) == (2, 1, 2):
            # T_ij = sym_traceless( eps_iab y_a M_bj ): cross y with columns
            mc = jnp.swapaxes(x2, -1, -2)              # (E, C, j, b)
            yb = jnp.broadcast_to(y1[:, :, None, :], mc.shape)
            crossed = jnp.cross(yb, mc)                # (E, C, j, i)
            r = sym_traceless(jnp.swapaxes(crossed, -1, -2))
        elif (li, ls, lo) == (2, 2, 1):
            r = _levi_civita_contract(x2, jnp.broadcast_to(y2, x2.shape))
        elif (li, ls, lo) == (2, 2, 2):
            anti = x2 @ y2 + y2 @ x2
            r = sym_traceless(anti)
        else:  # pragma: no cover
            raise AssertionError((li, ls, lo))
        if lo == 0:
            out[0] = out[0] + wp * r
        elif lo == 1:
            out[1] = out[1] + wp[..., None] * r
        else:
            out[2] = out[2] + wp[..., None, None] * r
    return out


def nequip_forward(params: dict, cfg, batch: dict) -> jnp.ndarray:
    """batch: positions (N,3), species (N,), edge_index (2,E),
    node_graph (N,), n_graphs. Returns per-graph energies (n_graphs,)."""
    pos = batch["positions"]
    src, dst = batch["edge_index"]
    n = pos.shape[0]
    c = cfg.d_hidden
    cutoff = cfg.extra("cutoff", 5.0)
    n_rbf = cfg.extra("n_rbf", 8)

    rel = pos[src] - pos[dst]                          # (E, 3)
    dist = jnp.linalg.norm(rel + _EPS, axis=-1)
    r_hat = rel / jnp.maximum(dist, _EPS)[:, None]
    sh = {0: jnp.ones_like(dist),
          1: r_hat,
          2: sym_traceless(r_hat[:, :, None] * r_hat[:, None, :])}
    rbf = bessel_basis(dist, n_rbf, cutoff)

    feats = {0: params["species_embed"][batch["species"]],
             1: jnp.zeros((n, c, 3)),
             2: jnp.zeros((n, c, 3, 3))}

    def interact(lp, feats):
        w = _apply_mlp(lp["radial"], rbf).reshape(-1, len(_PATHS), c)
        gathered = {0: feats[0][src], 1: feats[1][src], 2: feats[2][src]}
        msg = _tp_accumulate(gathered, sh, w, c)
        agg = {l: jax.ops.segment_sum(msg[l], dst, num_segments=n)
               for l in (0, 1, 2)}
        # self-interaction + residual
        h0 = feats[0] + agg[0] @ lp["mix0"]
        h1 = feats[1] + jnp.einsum("ncI,cd->ndI", agg[1], lp["mix1"])
        h2 = feats[2] + jnp.einsum("ncIJ,cd->ndIJ", agg[2], lp["mix2"])
        # gated nonlinearity
        gates = jax.nn.sigmoid(h0 @ lp["gate"])        # (N, 2C)
        return {0: jax.nn.silu(h0),
                1: h1 * gates[:, :c, None],
                2: h2 * gates[:, c:, None, None]}

    # (remat per block was tried and refuted — see EXPERIMENTS.md §Perf 6b)
    for lp in params["layers"]:
        feats = interact(lp, feats)

    energy_per_node = _apply_mlp(params["readout"], feats[0])[:, 0]
    return jax.ops.segment_sum(energy_per_node, batch["node_graph"],
                               num_segments=batch["n_graphs"])


def nequip_energy_loss(params, cfg, batch) -> jnp.ndarray:
    e = nequip_forward(params, cfg, batch)
    return jnp.mean((e - batch["labels"].astype(e.dtype)) ** 2)
