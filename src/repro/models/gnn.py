"""GNN architectures: GraphSAGE, PNA, GatedGCN (+ NequIP in equivariant.py).

Message passing is built on ``jax.ops.segment_sum/max`` over an (2, E)
edge_index — the JAX-native scatter/gather substrate (no sparse library).
Inputs come in a uniform GraphBatch dict:

    x           (N, F) node features
    edge_index  (2, E) int32 [src; dst]
    edge_attr   (E, Fe) or None
    node_graph  (N,) graph id for batched small graphs (else zeros)
    n_graphs    static int
    labels      (N,) int32 node labels or (n_graphs,) regression targets

All models expose init(key, cfg, d_in) -> params and
apply(params, cfg, batch) -> node/graph outputs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["segment_mean", "segment_std", "init_gnn", "gnn_forward",
           "gnn_loss"]


# ------------------------------------------------------------ segment utils
def segment_mean(data, segment_ids, num_segments):
    s = jax.ops.segment_sum(data, segment_ids, num_segments=num_segments)
    c = jax.ops.segment_sum(jnp.ones_like(data[..., :1]), segment_ids,
                            num_segments=num_segments)
    return s / jnp.maximum(c, 1.0)


def segment_std(data, segment_ids, num_segments, eps=1e-5):
    mean = segment_mean(data, segment_ids, num_segments)
    sq = segment_mean(data * data, segment_ids, num_segments)
    return jnp.sqrt(jnp.maximum(sq - mean * mean, 0.0) + eps)


def _dense(key, d_in, d_out, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "w": (jax.random.normal(k1, (d_in, d_out)) * d_in ** -0.5).astype(dtype),
        "b": jnp.zeros((d_out,), dtype),
    }


def _apply_dense(p, x):
    return x @ p["w"] + p["b"]


# ----------------------------------------------------------------- GraphSAGE
def _init_sage_layer(key, d_in, d_out, dtype):
    k1, k2 = jax.random.split(key)
    return {"self": _dense(k1, d_in, d_out, dtype),
            "nbr": _dense(k2, d_in, d_out, dtype)}


def _sage_layer(p, x, edge_index, n):
    src, dst = edge_index
    agg = segment_mean(x[src], dst, n)
    h = _apply_dense(p["self"], x) + _apply_dense(p["nbr"], agg)
    h = jax.nn.relu(h)
    # L2 normalize (GraphSAGE §3.1)
    return h / jnp.maximum(jnp.linalg.norm(h, axis=-1, keepdims=True), 1e-6)


# ----------------------------------------------------------------------- PNA
_PNA_DEGREE_EPS = 1.0


def _init_pna_layer(key, d_in, d_out, dtype):
    # 4 aggregators x 3 scalers = 12 concatenated views + self
    k1, k2 = jax.random.split(key)
    return {"pre": _dense(k1, 2 * d_in, d_in, dtype),
            "post": _dense(k2, 13 * d_in, d_out, dtype)}


def _pna_layer(p, x, edge_index, n, mean_log_deg):
    src, dst = edge_index
    msg = jax.nn.relu(_apply_dense(
        p["pre"], jnp.concatenate([x[src], x[dst]], axis=-1)))
    deg = jax.ops.segment_sum(jnp.ones((src.shape[0], 1)), dst,
                              num_segments=n)
    mean = segment_mean(msg, dst, n)
    mx = jax.ops.segment_max(msg, dst, num_segments=n)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    mn = -jax.ops.segment_max(-msg, dst, num_segments=n)
    mn = jnp.where(jnp.isfinite(mn), mn, 0.0)
    std = segment_std(msg, dst, n)
    aggs = [mean, mx, mn, std]
    logd = jnp.log(deg + _PNA_DEGREE_EPS)
    amp = logd / mean_log_deg
    att = jnp.where(logd > 0, mean_log_deg / jnp.maximum(logd, 1e-6), 0.0)
    views = []
    for a in aggs:
        views.extend([a, a * amp, a * att])
    h = jnp.concatenate([x] + views, axis=-1)
    return jax.nn.relu(_apply_dense(p["post"], h))


# ------------------------------------------------------------------ GatedGCN
def _init_gated_layer(key, d, dtype):
    ks = jax.random.split(key, 5)
    return {c: _dense(k, d, d, dtype) for c, k in zip("UVABC", ks)} | {
        "ln_h": {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)},
        "ln_e": {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)},
    }


def _layer_norm(p, x, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["g"] + p["b"]


def _gated_layer(p, x, e, edge_index, n):
    src, dst = edge_index
    e_new = _apply_dense(p["A"], x)[src] + _apply_dense(p["B"], x)[dst] + \
        _apply_dense(p["C"], e)
    gate = jax.nn.sigmoid(e_new)
    num = jax.ops.segment_sum(gate * _apply_dense(p["V"], x)[src], dst,
                              num_segments=n)
    den = jax.ops.segment_sum(gate, dst, num_segments=n)
    h_new = _apply_dense(p["U"], x) + num / (den + 1e-6)
    x = x + jax.nn.relu(_layer_norm(p["ln_h"], h_new))
    e = e + jax.nn.relu(_layer_norm(p["ln_e"], e_new))
    return x, e


# ------------------------------------------------------------------- models
def init_gnn(key, cfg, d_in: int) -> dict:
    """cfg: GNNConfig (kind in graphsage|pna|gatedgcn)."""
    dt = cfg.param_dtype
    d = cfg.d_hidden
    keys = jax.random.split(key, cfg.n_layers + 3)
    p = {"embed": _dense(keys[-1], d_in, d, dt),
         "out": _dense(keys[-2], d, cfg.n_classes, dt)}
    if cfg.kind == "graphsage":
        p["layers"] = [_init_sage_layer(keys[i], d, d, dt)
                       for i in range(cfg.n_layers)]
    elif cfg.kind == "pna":
        p["layers"] = [_init_pna_layer(keys[i], d, d, dt)
                       for i in range(cfg.n_layers)]
    elif cfg.kind == "gatedgcn":
        p["layers"] = [_init_gated_layer(keys[i], d, dt)
                       for i in range(cfg.n_layers)]
        p["edge_embed"] = _dense(keys[-3], d_in, d, dt)
    else:
        raise ValueError(cfg.kind)
    return p


def gnn_forward(params: dict, cfg, batch: dict) -> jnp.ndarray:
    """-> (N, n_classes) node logits, or (n_graphs, n_classes) if pooling.

    Note: per-layer remat was tried for the 60M-edge cells and REFUTED —
    XLA:CPU's remat raised peak memory ~1.3x and the step bound ~1.4x
    (EXPERIMENTS.md §Perf iteration 6b); layers stay un-checkpointed."""
    x = batch["x"].astype(cfg.param_dtype)
    edge_index = batch["edge_index"]
    n = x.shape[0]
    h = jax.nn.relu(_apply_dense(params["embed"], x))

    if cfg.kind == "graphsage":
        for lp in params["layers"]:
            h = _sage_layer(lp, h, edge_index, n)
    elif cfg.kind == "pna":
        src, dst = edge_index
        deg = jax.ops.segment_sum(jnp.ones((src.shape[0], 1)), dst,
                                  num_segments=n)
        mean_log_deg = jnp.log(deg + _PNA_DEGREE_EPS).mean()
        for lp in params["layers"]:
            h = _pna_layer(lp, h, edge_index, n, mean_log_deg)
    elif cfg.kind == "gatedgcn":
        src, dst = edge_index
        if batch.get("edge_attr") is not None:
            ea = batch["edge_attr"].astype(cfg.param_dtype)
            d_in = params["edge_embed"]["w"].shape[0]
            if ea.shape[-1] < d_in:
                ea = jnp.pad(ea, ((0, 0), (0, d_in - ea.shape[-1])))
            e = _apply_dense(params["edge_embed"], ea[:, :d_in])
        else:
            e = h[src] + h[dst]
        for lp in params["layers"]:
            h, e = _gated_layer(lp, h, e, edge_index, n)

    if batch.get("pool", False):
        h = segment_mean(h, batch["node_graph"], batch["n_graphs"])
    return _apply_dense(params["out"], h)


def gnn_loss(params, cfg, batch) -> jnp.ndarray:
    logits = gnn_forward(params, cfg, batch)
    if batch.get("pool", False):
        # graph-level regression (molecule cells)
        target = batch["labels"].astype(logits.dtype)
        return jnp.mean((logits[:, 0] - target) ** 2)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1)[:, 0]
    mask = batch.get("label_mask")
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
