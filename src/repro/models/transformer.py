"""Decoder-only transformer LM covering all five assigned LM architectures.

Features: GQA + RoPE, optional sliding-window local attention with every-Nth
global layer (gemma3 5:1), optional QK-norm (qwen3), optional MoE FFN with
shared experts (deepseek/qwen3) and leading dense layers (deepseek),
scan-over-layers with optional remat (compile-time and memory control at 8B+
scale), KV-cache decode.

Layer params are stacked along a leading (n_layers,) axis so the layer stack
is a single `lax.scan` — the HLO stays O(1) in depth, which keeps the 40-cell
x 2-mesh dry-run tractable.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.models import layers as L
from repro.models.moe import init_moe, moe_ffn

__all__ = ["init_lm", "lm_forward", "lm_loss", "init_decode_cache",
           "lm_decode_step", "lm_prefill"]


def _layer_is_global(cfg: LMConfig, idx: int) -> bool:
    if cfg.sliding_window is None:
        return True
    if cfg.global_every <= 0:
        return False
    return (idx + 1) % cfg.global_every == 0


def init_lm(key, cfg: LMConfig) -> dict:
    dt = cfg.param_dtype
    keys = jax.random.split(key, cfg.n_layers + 3)

    def layer_params(k, moe_layer: bool):
        ka, kf = jax.random.split(k)
        p = {
            "attn": L.init_attention(ka, cfg.d_model, cfg.n_heads,
                                     cfg.n_kv_heads, cfg.head_dim, dt,
                                     cfg.use_qk_norm),
            "ln1": L.init_rms_norm(cfg.d_model, dt),
            "ln2": L.init_rms_norm(cfg.d_model, dt),
        }
        if moe_layer:
            p["moe"] = init_moe(kf, cfg.d_model, cfg.d_ff,
                                cfg.moe.n_experts, cfg.moe.n_shared, dt)
        else:
            d_ff = cfg.dense_d_ff or cfg.d_ff
            p["mlp"] = L.init_mlp(kf, cfg.d_model, d_ff, dt)
        return p

    n_scan = cfg.n_layers - (cfg.first_dense_layers if cfg.moe else 0)
    moe_scan = cfg.moe is not None

    # stacked params for the scanned (homogeneous) layers
    stacked = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs),
        *[layer_params(keys[i], moe_scan) for i in range(n_scan)],
    ) if n_scan else {}
    dense_front = [layer_params(keys[n_scan + i], False)
                   for i in range(cfg.first_dense_layers if cfg.moe else 0)]

    emb_scale = cfg.d_model ** -0.5
    return {
        "embed": (jax.random.normal(keys[-3], (cfg.vocab_size, cfg.d_model))
                  * emb_scale).astype(dt),
        "lm_head": (jax.random.normal(keys[-2], (cfg.d_model, cfg.vocab_size))
                    * emb_scale).astype(dt),
        "ln_f": L.init_rms_norm(cfg.d_model, dt),
        "layers": stacked,
        "dense_front": dense_front,
    }


def _window_flags(cfg: LMConfig, n: int) -> jnp.ndarray:
    """Per-scanned-layer flag: 1.0 = global attention, 0.0 = windowed."""
    offset = cfg.first_dense_layers if cfg.moe else 0
    return jnp.asarray(
        [1.0 if _layer_is_global(cfg, offset + i) else 0.0 for i in range(n)],
        jnp.float32)


def _block(cfg: LMConfig, p: dict, x: jnp.ndarray, is_global) -> tuple:
    """One transformer block; returns (x, aux_loss). The local/global mix
    (gemma3) is a traced per-layer flag folded into the attention mask."""
    h = L.attention(
        p["attn"], L.rms_norm(p["ln1"], x, cfg.norm_eps),
        n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, d_head=cfg.head_dim,
        theta=cfg.rope_theta, window=cfg.sliding_window, is_global=is_global,
        use_qk_norm=cfg.use_qk_norm, unroll_chunks=cfg.attn_unroll)
    x = x + h
    hn = L.rms_norm(p["ln2"], x, cfg.norm_eps)
    if "moe" in p:
        f, aux = moe_ffn(p["moe"], hn, top_k=cfg.moe.top_k,
                         capacity_factor=cfg.moe.capacity_factor,
                         groups=cfg.moe.groups)
    else:
        f, aux = L.mlp_swiglu(p["mlp"], hn), jnp.zeros((), jnp.float32)
    return x + f, aux


def lm_forward(params: dict, cfg: LMConfig,
               tokens: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """tokens (B, S) -> (logits (B, S, V) f32, aux_loss)."""
    x = params["embed"][tokens]
    aux_total = jnp.zeros((), jnp.float32)

    for p in params["dense_front"]:
        x, aux = _block(cfg, p, x, jnp.float32(1.0))
        aux_total += aux

    if params["layers"]:
        n_scan = jax.tree_util.tree_leaves(params["layers"])[0].shape[0]
        flags = _window_flags(cfg, n_scan)
        fn = jax.checkpoint(_block, static_argnums=(0,)) if cfg.remat \
            else _block

        if cfg.scan_layers:
            def body(carry, inputs):
                x, aux_acc = carry
                layer_p, flag = inputs
                x, aux = fn(cfg, layer_p, x, flag)
                return (x, aux_acc + aux), None

            (x, aux_total), _ = jax.lax.scan(
                body, (x, aux_total), (params["layers"], flags))
        else:
            # unrolled (dry-run cost analysis: while bodies count once)
            for i in range(n_scan):
                layer_p = jax.tree_util.tree_map(lambda a: a[i],
                                                 params["layers"])
                x, aux = fn(cfg, layer_p, x, flags[i])
                aux_total += aux

    x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
    logits = (x @ params["lm_head"]).astype(jnp.float32)
    return logits, aux_total


def lm_loss(params: dict, cfg: LMConfig, tokens: jnp.ndarray,
            targets: jnp.ndarray, aux_weight: float = 0.01) -> jnp.ndarray:
    """Cross-entropy written as reductions over the vocab axis (logsumexp +
    one-hot contraction) so a vocab-sharded lm_head never all-gathers the
    (B, S, V) logits — the sharded-friendly CE of Megatron/MaxText."""
    logits, aux = lm_forward(params, cfg, tokens)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
    tgt_logit = jnp.sum(logits * onehot, axis=-1)
    nll = lse - tgt_logit
    return nll.mean() + aux_weight * aux


# ------------------------------------------------------------------ serving
def init_decode_cache(cfg: LMConfig, batch: int, s_max: int,
                      dtype=jnp.bfloat16) -> dict:
    """Layer-stacked KV cache (n_scan, B, S_max, Hkv, Dh).

    Note: scan homogeneity keeps a full-length cache for gemma3's windowed
    layers too; the window-trimmed variant (6x cache saving at 500k) is a
    recorded §Perf optimization — see train/serve_step window_cache option.
    """
    n_scan = cfg.n_layers - (cfg.first_dense_layers if cfg.moe else 0)
    front = cfg.first_dense_layers if cfg.moe else 0
    shape = (n_scan, batch, s_max, cfg.n_kv_heads, cfg.head_dim)
    fshape = (front, batch, s_max, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
        "k_front": jnp.zeros(fshape, dtype), "v_front": jnp.zeros(fshape, dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def lm_decode_step(params: dict, cfg: LMConfig, cache: dict,
                   token: jnp.ndarray,
                   shard_hints: dict | None = None
                   ) -> tuple[jnp.ndarray, dict]:
    """token (B, 1) int32 -> (logits (B, 1, V), new cache).

    shard_hints (optional): {"cache", "logits"} NamedShardings pinning
    decode attention to sequence-sharding (see layers.decode_attention).
    """
    x = params["embed"][token]
    cache_len = cache["len"]

    for i, p in enumerate(params["dense_front"]):
        h, ck, cv = L.decode_attention(
            p["attn"], L.rms_norm(p["ln1"], x, cfg.norm_eps),
            cache["k_front"][i], cache["v_front"][i], cache_len,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, d_head=cfg.head_dim,
            theta=cfg.rope_theta, use_qk_norm=cfg.use_qk_norm,
            shard_hints=shard_hints)
        cache["k_front"] = cache["k_front"].at[i].set(ck)
        cache["v_front"] = cache["v_front"].at[i].set(cv)
        x = x + h
        hn = L.rms_norm(p["ln2"], x, cfg.norm_eps)
        x = x + L.mlp_swiglu(p["mlp"], hn)

    if params["layers"]:
        n_scan = jax.tree_util.tree_leaves(params["layers"])[0].shape[0]
        flags = _window_flags(cfg, n_scan)

        def body(x, inputs):
            layer_p, flag, ck, cv = inputs
            hn = L.rms_norm(layer_p["ln1"], x, cfg.norm_eps)
            h, ck_new, cv_new = L.decode_attention(
                layer_p["attn"], hn, ck, cv, cache_len,
                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                d_head=cfg.head_dim, theta=cfg.rope_theta,
                window=cfg.sliding_window, is_global=flag,
                use_qk_norm=cfg.use_qk_norm, shard_hints=shard_hints)
            x = x + h
            hn2 = L.rms_norm(layer_p["ln2"], x, cfg.norm_eps)
            if "moe" in layer_p:
                f, _ = moe_ffn(layer_p["moe"], hn2, top_k=cfg.moe.top_k,
                               capacity_factor=cfg.moe.capacity_factor,
                               groups=cfg.moe.groups)
            else:
                f = L.mlp_swiglu(layer_p["mlp"], hn2)
            return x + f, (ck_new, cv_new)

        if cfg.scan_layers:
            x, (k_all, v_all) = jax.lax.scan(
                body, x, (params["layers"], flags, cache["k"], cache["v"]))
        else:
            ks, vs = [], []
            for i in range(n_scan):
                layer_p = jax.tree_util.tree_map(lambda a: a[i],
                                                 params["layers"])
                x, (ck, cv) = body(
                    x, (layer_p, flags[i], cache["k"][i], cache["v"][i]))
                ks.append(ck)
                vs.append(cv)
            k_all, v_all = jnp.stack(ks), jnp.stack(vs)
        cache = dict(cache, k=k_all, v=v_all)

    x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
    logits = (x @ params["lm_head"]).astype(jnp.float32)
    cache = dict(cache, len=cache_len + 1)
    return logits, cache


def lm_prefill(params: dict, cfg: LMConfig,
               tokens: jnp.ndarray) -> jnp.ndarray:
    """Prefill forward (logits only; cache fill elided — the dry-run cost is
    the quadratic attention itself)."""
    logits, _ = lm_forward(params, cfg, tokens)
    return logits


def lm_prefill_chunked(params: dict, cfg: LMConfig, tokens: jnp.ndarray,
                       cache: dict, chunk: int = 1024
                       ) -> tuple[jnp.ndarray, dict]:
    """Chunked prefill (Sarathi-style): processes the prompt in sequence
    chunks, filling the KV cache as it goes — peak attention memory is
    O(chunk x prefix) instead of O(S^2), and the filled cache hands off
    directly to lm_decode_step. Returns (last-chunk logits, cache).

    MoE/dense-front handled like lm_forward; gemma3's local/global layer
    pattern flows through the same flag-masked attention.
    """
    b, s = tokens.shape
    assert s % chunk == 0, (s, chunk)
    n_scan = (jax.tree_util.tree_leaves(params["layers"])[0].shape[0]
              if params["layers"] else 0)
    flags = _window_flags(cfg, n_scan)

    for c0 in range(0, s, chunk):
        x = params["embed"][tokens[:, c0:c0 + chunk]]
        # (dense-front layers, if any, processed like scanned ones)
        front_caches = []
        for i, p in enumerate(params["dense_front"]):
            x, ck, cv = _prefill_block(
                cfg, p, x, cache["k_front"][i], cache["v_front"][i], c0,
                jnp.float32(1.0))
            front_caches.append((ck, cv))
        if front_caches:
            cache = dict(
                cache,
                k_front=jnp.stack([c[0] for c in front_caches]),
                v_front=jnp.stack([c[1] for c in front_caches]))

        if params["layers"]:
            def body(x, inputs):
                layer_p, flag, ck, cv = inputs
                x, ck2, cv2 = _prefill_block(cfg, layer_p, x, ck, cv, c0,
                                             flag)
                return x, (ck2, cv2)

            x, (k_all, v_all) = jax.lax.scan(
                body, x, (params["layers"], flags, cache["k"], cache["v"]))
            cache = dict(cache, k=k_all, v=v_all)

    x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
    logits = (x @ params["lm_head"]).astype(jnp.float32)
    cache = dict(cache, len=jnp.asarray(s, jnp.int32))
    return logits, cache


def _prefill_block(cfg, p, x, cache_k, cache_v, c0: int, flag):
    """One block over a prompt chunk starting at static offset c0; writes
    the chunk's K/V into the cache and attends to the whole prefix."""
    b, cs, _ = x.shape
    hn = L.rms_norm(p["ln1"], x, cfg.norm_eps)
    h, ck, cv = L.prefill_attention(
        p["attn"], hn, cache_k, cache_v, c0,
        n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, d_head=cfg.head_dim,
        theta=cfg.rope_theta, window=cfg.sliding_window, is_global=flag,
        use_qk_norm=cfg.use_qk_norm)
    x = x + h
    hn2 = L.rms_norm(p["ln2"], x, cfg.norm_eps)
    if "moe" in p:
        f, _ = moe_ffn(p["moe"], hn2, top_k=cfg.moe.top_k,
                       capacity_factor=cfg.moe.capacity_factor,
                       groups=cfg.moe.groups)
    else:
        f = L.mlp_swiglu(p["mlp"], hn2)
    return x + f, ck, cv
