"""Mixture-of-Experts FFN with GShard-style grouped capacity dispatch.

Tokens are split into ``groups`` (= the data-parallel shard count at scale) so
the dispatch buffer is (G, E, C_g, D): G rides the data axis, experts ride the
model axis, and the expert einsum parallelizes over BOTH mesh axes with no
communication — an ungrouped (E, C, D) buffer drops the data axis and
replicates expert compute across it (16x flops at mesh 16x16; EXPERIMENTS.md
§Perf iteration 3). Per-group capacity C_g = ceil(cf * T_g * K / E) matches
GShard semantics: overflowing tokens are dropped per group (the residual
stream carries them).

Positions within an expert queue use a log-depth associative scan — a plain
cumsum lowers to an O(n^2) reduce-window on some backends (§Perf iteration 1).

Aux load-balancing loss follows Switch Transformer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_mlp, mlp_swiglu

__all__ = ["init_moe", "moe_ffn"]


def init_moe(key, d_model: int, d_ff: int, n_experts: int, n_shared: int,
             dtype) -> dict:
    ks = jax.random.split(key, 4)
    s = d_model ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (d_model, n_experts)) * s
                   ).astype(jnp.float32),
        # stacked expert weights: (E, d, ff) / (E, ff, d)
        "w_gate": (jax.random.normal(ks[1], (n_experts, d_model, d_ff)) * s
                   ).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (n_experts, d_model, d_ff)) * s
                 ).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (n_experts, d_ff, d_model))
                   * d_ff ** -0.5).astype(dtype),
    }
    if n_shared:
        p["shared"] = init_mlp(key, d_model, d_ff * n_shared, dtype)
    return p


def _pick_groups(requested: int, n_tokens: int) -> int:
    """Largest divisor of n_tokens that is <= requested."""
    g = max(1, min(requested, n_tokens))
    while n_tokens % g:
        g -= 1
    return g


def moe_ffn(params: dict, x: jnp.ndarray, *, top_k: int,
            capacity_factor: float = 1.25,
            groups: int = 1) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss)."""
    b, s, d = x.shape
    e = params["router"].shape[1]
    n_tokens = b * s
    g = _pick_groups(groups, n_tokens)
    t_g = n_tokens // g
    xg = x.reshape(g, t_g, d)

    logits = (xg.astype(jnp.float32) @ params["router"])     # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)        # (G, Tg, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    capacity = max(1, int(capacity_factor * t_g * top_k / e))

    # Per-group position of each (token, k) in its expert queue, computed by
    # sorting slot->expert ids and enumerating within runs: O(Tg*K) memory.
    # (History: a (Tg*K, E) one-hot scan was E-times bigger and its cumsum
    # lowered to an O(n^2) reduce-window — §Perf iterations 1 and 7.)
    ids = gate_idx.reshape(g, t_g * top_k)                   # (G, S)
    order = jnp.argsort(ids, axis=1, stable=True)
    sorted_ids = jnp.take_along_axis(ids, order, axis=1)
    iota = jnp.broadcast_to(jnp.arange(t_g * top_k), ids.shape)
    is_start = jnp.concatenate(
        [jnp.ones((g, 1), bool), sorted_ids[:, 1:] != sorted_ids[:, :-1]],
        axis=1)
    run_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_start, iota, 0), axis=1)
    pos_sorted = iota - run_start
    inv = jnp.argsort(order, axis=1)
    pos = jnp.take_along_axis(pos_sorted, inv, axis=1
                              ).reshape(g, t_g, top_k)       # (G, Tg, K)
    keep = pos < capacity

    # dispatch: scatter tokens into (G, E*C, D) buffers (local per group)
    expert_slot = gate_idx * capacity + jnp.minimum(pos, capacity - 1)
    expert_slot = jnp.where(keep, expert_slot, e * capacity)  # overflow bin
    xk = jnp.broadcast_to(xg[:, :, None, :], (g, t_g, top_k, d))

    def scatter_group(xk_g, slot_g):
        return jax.ops.segment_sum(
            xk_g.reshape(-1, d), slot_g.reshape(-1),
            num_segments=e * capacity + 1)[:-1]

    buf = jax.vmap(scatter_group)(xk, expert_slot)           # (G, E*C, D)
    buf = buf.reshape(g, e, capacity, d).astype(x.dtype)

    # expert compute: parallel over G (data) x E (model)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, params["w_gate"]))
    h = h * jnp.einsum("gecd,edf->gecf", buf, params["w_up"])
    y = jnp.einsum("gecf,efd->gecd", h, params["w_down"])    # (G, E, C, D)

    # combine: gather each (token, k) slot's output, weight by gate
    y_flat = y.reshape(g, e * capacity, d)
    slot = jnp.where(keep, gate_idx * capacity + pos, 0)

    def gather_group(y_g, slot_g):
        return y_g[slot_g.reshape(-1)].reshape(t_g, top_k, d)

    gathered = jax.vmap(gather_group)(y_flat, slot)          # (G, Tg, K, D)
    gathered = jnp.where(keep[..., None], gathered, 0.0)
    out = (gathered * gate_vals[..., None].astype(gathered.dtype)).sum(2)
    out = out.reshape(b, s, d)

    if "shared" in params:
        out = out + mlp_swiglu(params["shared"], x.reshape(n_tokens, d)
                               ).reshape(b, s, d)

    # Switch-style load-balance aux loss (global over groups)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(gate_idx[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    mean_probs = probs.mean(axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * mean_probs)

    return out, aux
