"""AutoInt (arXiv:1810.11921): self-attentive feature interaction over sparse
field embeddings, plus the EmbeddingBag substrate JAX lacks natively.

EmbeddingBag = jnp.take over the table + segment/masked reduction — built
here as a first-class op (multi-hot bag fields), per the assignment spec.
Retrieval scoring (retrieval_cand cell) is one batched dot of the query
embedding against the candidate matrix — no loops.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["embedding_bag", "init_autoint", "autoint_forward",
           "autoint_loss", "retrieval_scores", "user_embedding"]


def embedding_bag(table: jnp.ndarray, indices: jnp.ndarray,
                  weights: jnp.ndarray | None = None,
                  mode: str = "mean") -> jnp.ndarray:
    """table (V, D); indices (B, L) with -1 padding -> (B, D).

    jnp.take + masked reduction (sum/mean/max) — the JAX EmbeddingBag.
    """
    mask = (indices >= 0)
    safe = jnp.where(mask, indices, 0)
    emb = jnp.take(table, safe, axis=0)                 # (B, L, D)
    m = mask[..., None].astype(emb.dtype)
    if weights is not None:
        m = m * weights[..., None].astype(emb.dtype)
    if mode == "sum":
        return (emb * m).sum(axis=1)
    if mode == "mean":
        return (emb * m).sum(axis=1) / jnp.maximum(m.sum(axis=1), 1e-6)
    if mode == "max":
        neg = jnp.where(mask[..., None], emb, -jnp.inf)
        out = neg.max(axis=1)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    raise ValueError(mode)


def init_autoint(key, cfg) -> dict:
    """cfg: RecsysConfig."""
    dt = cfg.param_dtype
    ks = jax.random.split(key, 8)
    d_att = cfg.d_attn
    n_fields = cfg.n_sparse + 1          # +1 projected dense-feature field
    p = {
        # one stacked table: (n_sparse, V, D) — vocab-sharded on the mesh
        "tables": (jax.random.normal(
            ks[0], (cfg.n_sparse, cfg.vocab_size, cfg.embed_dim)) * 0.05
        ).astype(dt),
        "dense_proj": (jax.random.normal(
            ks[1], (cfg.n_dense, cfg.embed_dim)) * 0.1).astype(dt),
        "field_proj": (jax.random.normal(
            ks[2], (cfg.embed_dim, d_att)) * cfg.embed_dim ** -0.5).astype(dt),
        "attn": [],
        "out": (jax.random.normal(ks[3], (n_fields * d_att,)) * 0.01
                ).astype(dt),
        "bias": jnp.zeros((), dt),
    }
    for i in range(cfg.n_attn_layers):
        kq, kk, kv, kr = jax.random.split(jax.random.fold_in(ks[4], i), 4)
        s = d_att ** -0.5
        p["attn"].append({
            "wq": (jax.random.normal(kq, (d_att, cfg.n_heads,
                                          d_att // cfg.n_heads)) * s).astype(dt),
            "wk": (jax.random.normal(kk, (d_att, cfg.n_heads,
                                          d_att // cfg.n_heads)) * s).astype(dt),
            "wv": (jax.random.normal(kv, (d_att, cfg.n_heads,
                                          d_att // cfg.n_heads)) * s).astype(dt),
            "res": (jax.random.normal(kr, (d_att, d_att)) * s).astype(dt),
        })
    return p


def _field_embeddings(params, cfg, batch) -> jnp.ndarray:
    """-> (B, n_fields, embed_dim)."""
    sparse = batch["sparse_ids"]                  # (B, n_sparse) int32
    # single-valued fields: per-field lookup from the stacked table
    field_ids = jnp.arange(cfg.n_sparse)
    emb = jax.vmap(
        lambda f, idx: jnp.take(params["tables"][f], idx, axis=0),
        in_axes=(0, 1), out_axes=1,
    )(field_ids, sparse)                          # (B, n_sparse, D)

    if cfg.bag_fields and batch.get("bag_ids") is not None:
        # leading fields are multi-hot bags: EmbeddingBag over (B, F_bag, L)
        bag_ids = batch["bag_ids"]
        bag = jax.vmap(
            lambda f, idx: embedding_bag(params["tables"][f], idx, mode="mean"),
            in_axes=(0, 1), out_axes=1,
        )(field_ids[: cfg.bag_fields], bag_ids)   # (B, F_bag, D)
        emb = jnp.concatenate([bag, emb[:, cfg.bag_fields:]], axis=1)

    dense = batch["dense"].astype(emb.dtype)      # (B, n_dense)
    dense_field = dense @ params["dense_proj"]    # (B, D)
    return jnp.concatenate([emb, dense_field[:, None, :]], axis=1)


def _interact(params, cfg, fields: jnp.ndarray) -> jnp.ndarray:
    """AutoInt interacting layers over (B, F, d_attn)."""
    h = fields
    for lp in params["attn"]:
        q = jnp.einsum("bfd,dhk->bfhk", h, lp["wq"])
        k = jnp.einsum("bfd,dhk->bfhk", h, lp["wk"])
        v = jnp.einsum("bfd,dhk->bfhk", h, lp["wv"])
        logits = jnp.einsum("bfhk,bghk->bhfg", q, k).astype(jnp.float32)
        logits *= (q.shape[-1]) ** -0.5
        probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        att = jnp.einsum("bhfg,bghk->bfhk", probs, v)
        att = att.reshape(h.shape)
        h = jax.nn.relu(att + h @ lp["res"])
    return h


def user_embedding(params, cfg, batch) -> jnp.ndarray:
    """(B, n_fields * d_attn) representation (retrieval tower)."""
    fields = _field_embeddings(params, cfg, batch)
    h = fields @ params["field_proj"]
    h = _interact(params, cfg, h)
    return h.reshape(h.shape[0], -1)


def autoint_forward(params, cfg, batch) -> jnp.ndarray:
    """-> (B,) CTR logits."""
    rep = user_embedding(params, cfg, batch)
    return (rep @ params["out"] + params["bias"]).astype(jnp.float32)


def autoint_loss(params, cfg, batch) -> jnp.ndarray:
    logits = autoint_forward(params, cfg, batch)
    y = batch["labels"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def retrieval_scores(params, cfg, batch, candidates: jnp.ndarray,
                     proj: jnp.ndarray) -> jnp.ndarray:
    """Score one (or few) queries against (n_cand, d_c) candidate embeddings:
    a single batched matmul."""
    rep = user_embedding(params, cfg, batch) @ proj      # (B, d_c)
    return rep @ candidates.T                            # (B, n_cand)
