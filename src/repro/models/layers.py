"""Transformer building blocks: RMSNorm, RoPE, GQA attention (full / blocked
flash-style / sliding-window / decode-with-cache), SwiGLU MLP.

Pure-function style: params are nested dicts of arrays; every init_* takes an
rng key and returns the param subtree. Attention math accumulates in f32
regardless of param dtype.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

__all__ = [
    "rms_norm", "init_rms_norm", "rope", "init_attention", "attention",
    "decode_attention", "init_mlp", "mlp_swiglu",
]

_NEG_INF = -1e30


# --------------------------------------------------------------------- norms
def init_rms_norm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------- rope
def _rope_freqs(d_head: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32)
                            / d_head))


def rope(x: jnp.ndarray, positions: jnp.ndarray,
         theta: float = 10_000.0) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = _rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- attention
def init_attention(key, d_model: int, n_heads: int, n_kv: int, d_head: int,
                   dtype, use_qk_norm: bool = False) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = d_model ** -0.5
    p = {
        "wq": (jax.random.normal(kq, (d_model, n_heads, d_head)) * s).astype(dtype),
        "wk": (jax.random.normal(kk, (d_model, n_kv, d_head)) * s).astype(dtype),
        "wv": (jax.random.normal(kv, (d_model, n_kv, d_head)) * s).astype(dtype),
        "wo": (jax.random.normal(ko, (n_heads, d_head, d_model)) * s).astype(dtype),
    }
    if use_qk_norm:
        p["q_norm"] = init_rms_norm(d_head, dtype)
        p["k_norm"] = init_rms_norm(d_head, dtype)
    return p


def _qkv(params, x, positions, theta, use_qk_norm):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if use_qk_norm:
        q = rms_norm(params["q_norm"], q)
        k = rms_norm(params["k_norm"], k)
    q = rope(q, positions, theta)
    k = rope(k, positions, theta)
    return q, k, v


def _expand_kv(k: jnp.ndarray, groups: int) -> jnp.ndarray:
    """(B, S, Hkv, D) -> (B, S, Hkv*groups, D) by repetition (GQA)."""
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=2)


def _mask_ok(qpos, kpos, window: int | None, is_global):
    """Boolean keep-mask: causal, optionally windowed. ``is_global`` is a
    traced scalar (>0.5 disables the window) so a scanned layer stack can mix
    local/global layers without duplicating compute (gemma3 5:1)."""
    ok = kpos <= qpos
    if window is not None:
        in_window = kpos > qpos - window
        if is_global is None:
            ok = ok & in_window
        else:
            ok = ok & (in_window | (is_global > 0.5))
    return ok


def attention(params: dict, x: jnp.ndarray, *, n_heads: int, n_kv: int,
              d_head: int, theta: float = 10_000.0,
              window: int | None = None, is_global=None,
              use_qk_norm: bool = False,
              q_chunk: int = 1024, kv_chunk: int = 1024,
              unroll_chunks: bool = False) -> jnp.ndarray:
    """Causal self-attention over (B, S, D); blocked online-softmax when S is
    large (flash-attention reference in pure jnp, memory O(chunk^2)).

    ``unroll_chunks`` replaces the chunk scans with python loops over S/4
    blocks — HLO-visible flops for the dry-run cost analysis (XLA's
    HloCostAnalysis counts while bodies once)."""
    b, s, d = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = _qkv(params, x, positions, theta, use_qk_norm)
    groups = n_heads // n_kv
    k = _expand_kv(k, groups)
    v = _expand_kv(v, groups)
    scale = d_head ** -0.5

    if s <= max(q_chunk, kv_chunk) and not unroll_chunks:
        logits = jnp.einsum("bqhk,bshk->bhqs", q, k).astype(jnp.float32)
        qpos = jnp.arange(s)[:, None]
        kpos = jnp.arange(s)[None, :]
        ok = _mask_ok(qpos, kpos, window, is_global)
        logits = logits * scale + jnp.where(ok, 0.0, _NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        out = jnp.einsum("bhqs,bshk->bqhk", probs, v)
    elif unroll_chunks:
        c = min(max(s // 4, 128), s)
        out = _unrolled_attention(q, k, v, scale, window, is_global, c)
    else:
        out = _blocked_attention(q, k, v, scale, window, is_global,
                                 q_chunk, kv_chunk)
    return jnp.einsum("bqhk,hkd->bqd", out, params["wo"])


def _unrolled_attention(q, k, v, scale, window, is_global, chunk):
    """Python-loop flash blocks (static trip counts; dry-run cost analysis)."""
    b, s, h, dh = q.shape
    assert s % chunk == 0, (s, chunk)
    nb = s // chunk
    outs = []
    for qi in range(nb):
        q_blk = q[:, qi * chunk:(qi + 1) * chunk]
        m = jnp.full((b, h, chunk), _NEG_INF, jnp.float32)
        l = jnp.zeros((b, h, chunk), jnp.float32)
        acc = jnp.zeros((b, h, chunk, dh), jnp.float32)
        for ki in range(qi + 1):           # causal: skip upper blocks
            k_blk = k[:, ki * chunk:(ki + 1) * chunk]
            v_blk = v[:, ki * chunk:(ki + 1) * chunk]
            logits = jnp.einsum("bqhk,bshk->bhqs", q_blk, k_blk
                                ).astype(jnp.float32) * scale
            qpos = qi * chunk + jnp.arange(chunk)[:, None]
            kpos = ki * chunk + jnp.arange(chunk)[None, :]
            ok = _mask_ok(qpos, kpos, window, is_global)
            logits = logits + jnp.where(ok, 0.0, _NEG_INF)[None, None]
            m_new = jnp.maximum(m, logits.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqs,bshk->bhqk", p, v_blk.astype(jnp.float32))
            m = m_new
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        outs.append(jnp.moveaxis(out, 1, 2))
    return jnp.concatenate(outs, axis=1).astype(v.dtype)


def _blocked_attention(q, k, v, scale, window, is_global, q_chunk, kv_chunk):
    """Online-softmax two-level blocking; causal (+ optional window)."""
    b, s, h, dh = q.shape
    nq = -(-s // q_chunk)
    q_pad = nq * q_chunk
    if q_pad != s:
        q = jnp.pad(q, ((0, 0), (0, q_pad - s), (0, 0), (0, 0)))
    nk = -(-s // kv_chunk)
    kv_pad = nk * kv_chunk
    if kv_pad != s:
        k = jnp.pad(k, ((0, 0), (0, kv_pad - s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kv_pad - s), (0, 0), (0, 0)))

    kq = k.reshape(b, nk, kv_chunk, h, dh)
    vq = v.reshape(b, nk, kv_chunk, h, dh)

    def q_block(qi, q_blk):
        q_off = qi * q_chunk

        def kv_block(carry, inputs):
            m, l, acc = carry
            ki, k_blk, v_blk = inputs
            k_off = ki * kv_chunk
            logits = jnp.einsum("bqhk,bshk->bhqs", q_blk, k_blk)
            logits = logits.astype(jnp.float32) * scale
            qpos = q_off + jnp.arange(q_chunk)[:, None]
            kpos = k_off + jnp.arange(kv_chunk)[None, :]
            ok = _mask_ok(qpos, kpos, window, is_global) & (kpos < s)
            logits = logits + jnp.where(ok, 0.0, _NEG_INF)[None, None]
            m_new = jnp.maximum(m, logits.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqs,bshk->bhqk", p, v_blk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, q_chunk), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, h, q_chunk, dh), jnp.float32)
        ks = jnp.arange(nk)
        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, a0),
            (ks, jnp.moveaxis(kq, 1, 0), jnp.moveaxis(vq, 1, 0)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(out, 1, 2)  # (b, q_chunk, h, dh)

    qs = q.reshape(b, nq, q_chunk, h, dh)
    out = jax.lax.map(lambda args: q_block(*args),
                      (jnp.arange(nq), jnp.moveaxis(qs, 1, 0)))
    out = jnp.moveaxis(out, 0, 1).reshape(b, q_pad, h, dh)[:, :s]
    return out.astype(v.dtype)


def _constrain(x, sharding):
    if sharding is None:
        return x
    return jax.lax.with_sharding_constraint(x, sharding)


def decode_attention(params: dict, x: jnp.ndarray, cache_k, cache_v,
                     cache_len, *, n_heads: int, n_kv: int, d_head: int,
                     theta: float = 10_000.0, window: int | None = None,
                     is_global=None, use_qk_norm: bool = False,
                     shard_hints: dict | None = None):
    """One-token decode. x: (B, 1, D); cache_[kv]: (B, S_max, Hkv, D).

    Returns (out (B,1,D), new_cache_k, new_cache_v). Softmax over the cache
    sequence axis in f32; positions masked beyond cache_len.

    ``shard_hints`` ({"cache": NamedSharding, "logits": NamedSharding},
    optional) pins the attention math to sequence-sharding (flash-decoding):
    without them XLA reconciles the head-sharded q against the seq-sharded
    cache by all-gathering the entire cache per layer (EXPERIMENTS.md §Perf
    iteration 2).
    """
    hints = shard_hints or {}
    b, one, d = x.shape
    s_max = cache_k.shape[1]
    positions = jnp.full((b, 1), cache_len, dtype=jnp.int32)
    q, k_new, v_new = _qkv(params, x, positions, theta, use_qk_norm)

    # size-1 dynamic_update_slice partitions cleanly on a sequence-sharded
    # cache when S rides a single mesh axis (configs/shapes.py picks it)
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k_new.astype(cache_k.dtype), cache_len, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v_new.astype(cache_v.dtype), cache_len, axis=1)
    cache_k = _constrain(cache_k, hints.get("cache"))
    cache_v = _constrain(cache_v, hints.get("cache"))

    # GQA-native: group the query heads instead of materializing the
    # repeated KV (a 4x llama3 cache blow-up per layer; §Perf iteration 2c)
    groups = n_heads // n_kv
    qg = q.reshape(b, 1, n_kv, groups, d_head)
    logits = jnp.einsum("bqhgk,bshk->bhgqs", qg, cache_k
                        ).astype(jnp.float32)
    logits = _constrain(logits * d_head ** -0.5, hints.get("logits"))
    kpos = jnp.arange(s_max)[None, None, None, None, :]
    ok = _mask_ok(cache_len, kpos, window, is_global)
    logits = jnp.where(ok, logits, _NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(cache_v.dtype)
    out = jnp.einsum("bhgqs,bshk->bqhgk", probs, cache_v)
    out = out.reshape(b, 1, n_heads, d_head)
    out = jnp.einsum("bqhk,hkd->bqd", out, params["wo"])
    return out, cache_k, cache_v


def prefill_attention(params: dict, x: jnp.ndarray, cache_k, cache_v,
                      c0: int, *, n_heads: int, n_kv: int, d_head: int,
                      theta: float = 10_000.0, window: int | None = None,
                      is_global=None, use_qk_norm: bool = False):
    """Chunked-prefill attention: x is the prompt chunk at static offset c0;
    writes the chunk's K/V into the cache (static-offset update) and attends
    causally over cache[:, :c0+chunk]. Returns (out, cache_k, cache_v)."""
    b, cs, d = x.shape
    positions = (c0 + jnp.arange(cs))[None, :]
    q, k_new, v_new = _qkv(params, x, positions, theta, use_qk_norm)

    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k_new.astype(cache_k.dtype), c0, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v_new.astype(cache_v.dtype), c0, axis=1)

    prefix = c0 + cs
    kk = jax.lax.slice_in_dim(cache_k, 0, prefix, axis=1)
    vv = jax.lax.slice_in_dim(cache_v, 0, prefix, axis=1)
    groups = n_heads // n_kv
    qg = q.reshape(b, cs, n_kv, groups, d_head)
    logits = jnp.einsum("bqhgk,bshk->bhgqs", qg, kk).astype(jnp.float32)
    logits *= d_head ** -0.5
    qpos = (c0 + jnp.arange(cs))[:, None]
    kpos = jnp.arange(prefix)[None, :]
    ok = _mask_ok(qpos, kpos, window, is_global)
    logits = logits + jnp.where(ok, 0.0, _NEG_INF)[None, None, None]
    probs = jax.nn.softmax(logits, axis=-1).astype(vv.dtype)
    out = jnp.einsum("bhgqs,bshk->bqhgk", probs, vv)
    out = out.reshape(b, cs, n_heads, d_head)
    out = jnp.einsum("bqhk,hkd->bqd", out, params["wo"])
    return out, cache_k, cache_v


# ----------------------------------------------------------------------- mlp
def init_mlp(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = d_model ** -0.5, d_ff ** -0.5
    return {
        "w_gate": (jax.random.normal(k1, (d_model, d_ff)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(k2, (d_model, d_ff)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (d_ff, d_model)) * s_out).astype(dtype),
    }


def mlp_swiglu(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    gate = jax.nn.silu(x @ params["w_gate"])
    return (gate * (x @ params["w_up"])) @ params["w_down"]
