"""Memory-aware execution of an :class:`ExecutionPlan` (paper §4.3–4.5).

Tree subgraph counting is memory bounded: at k >= 12 the ``C(k,t) x N``
count tables dominate the footprint, so the executor treats memory as a
managed resource instead of keeping every plan-node table (and every cached
SpMM result) alive for the whole bottom-up walk. Three cooperating pieces:

* **Liveness** (:func:`liveness`): for a given evaluation order, the last
  use of every node table and every ``y_cache`` SpMM entry is computed
  statically; :class:`PlanExecutor` drops each buffer at its last use, so
  the traced program's dataflow — and any eager/interpret execution — holds
  only the live frontier of the DP, not the whole history.
* **Scheduling** (:func:`compute_schedule`): the post-order plan admits many
  valid bottom-up orders. A greedy list scheduler picks, among the nodes
  whose children are ready, the one minimizing the step's modeled peak
  (Sethi–Ullman's "heavier subtree first" generalized to the dedup DAG);
  the better of {greedy, program order} is kept.
* **Analytic memory model** (:func:`peak_table_bytes` /
  :func:`pick_execution`): simulates the scheduled walk in units of table
  rows and turns a single ``memory_budget_bytes`` knob into the coloring
  batch size. When even batch=1 exceeds the budget, per-node **colorset
  chunking** is enabled: the ``C(k, t_p)`` passive axis of the SpMM/eMA is
  split so the passive neighbor-sum table is never materialized whole
  (see ``kernels/ema/ops.ema_chunked``) — k >= 12 templates then run under
  budgets where the always-live executor cannot run at all.

All three engines (fascia / pfascia / pgbsc) and the distributed pgbsc ride
the same :class:`PlanExecutor`; they differ only in the callbacks supplied
(neighbor-sum vs. SpMM passive transform, scan-eMA vs. kernel eMA combine).
"""

from __future__ import annotations

import dataclasses
from math import comb

import numpy as np

from repro.obs import tracing as _tracing

__all__ = [
    "Schedule", "ExecutionChoice", "PlanExecutor",
    "liveness", "compute_schedule", "simulate_peak_rows",
    "peak_table_bytes", "keep_everything_bytes", "pick_execution",
    "DEFAULT_MEMORY_BUDGET_BYTES", "MAX_AUTO_BATCH", "PAIR_BLOCK",
]

# Default budget when the caller gives none: generous enough that small
# problems batch freely, finite so huge plans still get a managed schedule.
DEFAULT_MEMORY_BUDGET_BYTES = 1 << 30
# Ceiling on the budget-derived coloring batch (diminishing returns past
# this; keeps first-call compile latency bounded for tiny graphs).
MAX_AUTO_BATCH = 64
# Rows of the (pair_block, N) working term buffer in the chunked eMA.
PAIR_BLOCK = 128


# --------------------------------------------------------------------------
# schedule representation
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Schedule:
    """A validated evaluation order plus static liveness for one plan.

    ``order``
        Topological order over *all* node indices (leaves included); the
        root is necessarily last (every plan node is in the root's cone).
    ``free_tables[s]`` / ``free_y[s]``
        Node-table indices / y-cache keys that are dead after step ``s``
        (the step evaluating ``order[s]``) and are dropped there.
    ``chunks``
        ``(node idx, n_chunks)`` pairs for colorset-chunked internal nodes
        (absent = unchunked). Chunked nodes bypass the y-cache.
    ``fused``
        Internal nodes whose SpMM -> eMA pair runs as ONE fused Pallas
        kernel (``kernels/fused``): the passive child table is consumed
        directly tile-by-tile and the ``C(k,t_p) x N`` neighbor-sum table is
        never materialized — the model charges such a step no y rows at all.
        Fused nodes bypass the y-cache; a node listed in both ``chunks`` and
        ``fused`` is treated as chunked (chunking wins, it exists because
        even the fused footprint exceeded budget).
    ``fused_groups``
        Disjoint tuples of ``fused`` nodes sharing ONE passive child that
        run as a single shared-passive launch: the members sit consecutively
        in ``order`` and all their tables materialize at the group's first
        member's step (the leader), with the SpMM leg paid once for the
        whole group. Every group member must also be listed in ``fused``
        (liveness treats members as direct passive consumers either way).
    ``passive_cache``
        Whether the walk materializes/caches the passive transform
        (SpMM / hoisted neighbor sum). False for FASCIA, whose neighbor
        sweep lives inside the split loop (paper §3.1).
    ``keep``
        Extra output nodes (beyond the implicit last node) that are never
        freed — fused multi-template plans keep every template's root table
        so :meth:`PlanExecutor.run` can return all of them.
    """

    order: tuple[int, ...]
    free_tables: tuple[tuple[int, ...], ...]
    free_y: tuple[tuple[int, ...], ...]
    chunks: tuple[tuple[int, int], ...] = ()
    passive_cache: bool = True
    keep: tuple[int, ...] = ()
    fused: tuple[int, ...] = ()
    fused_groups: tuple[tuple[int, ...], ...] = ()

    @property
    def chunk_map(self) -> dict[int, int]:
        return dict(self.chunks)

    @property
    def fused_set(self) -> frozenset[int]:
        return frozenset(self.fused)

    @property
    def group_of(self) -> dict[int, tuple[int, ...]]:
        """Member node index -> its shared-passive group tuple."""
        return {m: grp for grp in self.fused_groups for m in grp}


@dataclasses.dataclass(frozen=True)
class ExecutionChoice:
    """What the memory model decided for one (plan, graph, budget)."""

    batch_size: int
    schedule: Schedule
    peak_bytes_per_coloring: int   # modeled, batch=1
    budget_bytes: int
    fits: bool                     # batch_size colorings fit under budget

    @property
    def peak_bytes(self) -> int:
        return self.peak_bytes_per_coloring * self.batch_size


# --------------------------------------------------------------------------
# liveness
# --------------------------------------------------------------------------
def _validate_order(plan, order) -> dict[int, int]:
    pos = {idx: s for s, idx in enumerate(order)}
    if sorted(pos) != list(range(plan.n_nodes)) or len(order) != plan.n_nodes:
        raise ValueError("order must be a permutation of plan node indices")
    for idx, node in enumerate(plan.nodes):
        if not node.is_leaf:
            if pos[node.active] >= pos[idx] or pos[node.passive] >= pos[idx]:
                raise ValueError(f"order is not topological at node {idx}")
    return pos


def _regroup_order(order, groups):
    """Move each group's members so they sit consecutively at the position
    of the group's LATEST member (ascending by original position). Children
    of the moved members and consumers of any member can be violated by the
    move — the caller re-validates with :func:`_validate_order` and drops
    groups whose regrouped order is not topological.
    """
    pos = {i: s for s, i in enumerate(order)}
    anchor_of: dict[int, tuple[int, ...]] = {}
    member: set[int] = set()
    for grp in groups:
        anchor = max(grp, key=lambda i: pos[i])
        anchor_of[anchor] = tuple(sorted(grp, key=lambda i: pos[i]))
        member.update(grp)
    out: list[int] = []
    for i in order:
        if i in anchor_of:
            out.extend(anchor_of[i])
        elif i not in member:
            out.append(i)
    return tuple(out)


def liveness(plan, order, *, passive_cache: bool = True,
             chunks: dict[int, int] | None = None,
             keep: tuple[int, ...] = (),
             fused: tuple[int, ...] = (),
             ) -> tuple[tuple[tuple[int, ...], ...],
                        tuple[tuple[int, ...], ...]]:
    """Last-use analysis -> (free_tables, free_y), parallel to ``order``.

    A node table's life ends at the latest of: every step consuming it as
    the *active* child; every chunked/fused/uncached step consuming it as the
    *passive* child directly; the step that converts it into its cached
    y-entry (the first unchunked passive consumer in ``order``). A y-cache
    entry dies at its last unchunked passive consumer. The root table is
    never freed (it is the result); neither is any node in ``keep`` —
    the extra output roots of a fused multi-template plan.
    """
    pos = _validate_order(plan, order)
    cmap = dict(chunks or {})
    fset = frozenset(fused)
    n = plan.n_nodes
    table_last = {i: pos[i] for i in range(n)}
    y_steps: dict[int, list[int]] = {}
    for idx, node in enumerate(plan.nodes):
        if node.is_leaf:
            continue
        s = pos[idx]
        table_last[node.active] = max(table_last[node.active], s)
        direct = (not passive_cache) or cmap.get(idx, 1) > 1 or idx in fset
        if direct:
            table_last[node.passive] = max(table_last[node.passive], s)
        else:
            y_steps.setdefault(node.passive, []).append(s)
    y_last: dict[int, int] = {}
    for p, steps in y_steps.items():
        # the table is consumed where its y entry is created (min step);
        # the y entry itself lives until its last consumer (max step)
        table_last[p] = max(table_last[p], min(steps))
        y_last[p] = max(steps)
    keepset = {n - 1} | set(keep)
    free_tables: list[tuple[int, ...]] = [() for _ in order]
    free_y: list[tuple[int, ...]] = [() for _ in order]
    for i, last in table_last.items():
        if i not in keepset:
            free_tables[last] = free_tables[last] + (i,)
    for p, last in y_last.items():
        free_y[last] = free_y[last] + (p,)
    return tuple(free_tables), tuple(free_y)


# --------------------------------------------------------------------------
# the analytic memory model (row units; bytes = rows * n * itemsize * batch)
# --------------------------------------------------------------------------
def _step_peaks(plan, k: int, order, free_tables, free_y, *,
                passive_cache: bool, chunks: dict[int, int],
                fused: frozenset[int] = frozenset(),
                fused_groups: tuple[tuple[int, ...], ...] = (),
                pair_block: int = PAIR_BLOCK) -> list[int]:
    """Modeled live table rows at each step of the walk (working buffers
    included). Mirrors :meth:`PlanExecutor.run` exactly, including the
    mid-step release of a passive table right after its y entry is built
    and the all-members-at-once materialization of shared-passive groups."""
    rows = [comb(k, nd.size) for nd in plan.nodes]
    group_of = {m: grp for grp in fused_groups for m in grp}
    leaf_idxs = [i for i, nd in enumerate(plan.nodes) if nd.is_leaf]
    free_step: dict[int, int] = {}
    for s, fr in enumerate(free_tables):
        for i in fr:
            free_step[i] = s
    # all leaf tables alias ONE (k, N) one-hot buffer; it dies when the
    # last leaf index does (the root, never freed, pins it forever)
    leaf_death = max((free_step.get(i, len(order)) for i in leaf_idxs),
                    default=-1)
    live_t: dict[int, int] = {}    # internal-node idx -> rows
    leaf_live = False
    live_y: dict[int, int] = {}
    peaks: list[int] = []

    def cur() -> int:
        return sum(live_t.values()) + (k if leaf_live else 0) \
            + sum(live_y.values())

    for step, idx in enumerate(order):
        node = plan.nodes[idx]
        if node.is_leaf:
            leaf_live = True
            peaks.append(cur())
        else:
            out_r = rows[idx]
            q = chunks.get(idx, 1)
            if q > 1:
                # chunked: m_a and m_p stay live throughout; the extras are
                # one passive chunk, one pair-block term buffer, the output
                chunk_r = -(-rows[node.passive] // q)
                peaks.append(cur() + chunk_r + pair_block + out_r)
            elif idx in group_of:
                # shared-passive group: every member's table materializes at
                # the leader step (one launch); later member steps add nothing
                grp = group_of[idx]
                if idx not in live_t and not any(m in live_t for m in grp):
                    peaks.append(cur() + sum(rows[m] for m in grp))
                    for m in grp:
                        live_t[m] = rows[m]
                else:
                    peaks.append(cur())
            elif idx in fused:
                # fused SpMM->eMA kernel: the neighbor-sum table lives only
                # in VMEM scratch — no HBM rows beyond the output table
                peaks.append(cur() + out_r)
            elif not passive_cache:
                # FASCIA direct combine: the per-split neighbor sweep uses
                # a working buffer as wide as the output
                peaks.append(cur() + 2 * out_r)
            else:
                p = node.passive
                created = p not in live_y
                spmm_peak = cur() + (rows[p] if created else 0)
                if created:
                    live_y[p] = rows[p]
                    # mid-step release: the passive table dies here if this
                    # was its last use (PlanExecutor frees it pre-eMA)
                    if free_step.get(p) == step and p != node.active \
                            and not plan.nodes[p].is_leaf:
                        live_t.pop(p, None)
                peaks.append(max(spmm_peak, cur() + out_r))
            live_t[idx] = out_r
        for i in free_tables[step]:
            if not plan.nodes[i].is_leaf:
                live_t.pop(i, None)
        for p2 in free_y[step]:
            live_y.pop(p2, None)
        if leaf_live and step >= leaf_death:
            leaf_live = False
    return peaks


def simulate_peak_rows(plan, k: int, schedule: Schedule,
                       pair_block: int = PAIR_BLOCK) -> int:
    """Modeled peak live table rows (1 row = one length-N float vector)."""
    peaks = _step_peaks(plan, k, schedule.order, schedule.free_tables,
                        schedule.free_y, passive_cache=schedule.passive_cache,
                        chunks=schedule.chunk_map, fused=schedule.fused_set,
                        fused_groups=schedule.fused_groups,
                        pair_block=pair_block)
    return max(peaks) if peaks else 0


def peak_table_bytes(plan, k: int, n: int, batch: int = 1,
                     dtype=np.float32, schedule: Schedule | None = None
                     ) -> int:
    """Modeled peak live table bytes for one scheduled plan execution.

    ``batch`` colorings multiply every table (the leaf one-hot included);
    the static int32 split tables are negligible and excluded.
    """
    if schedule is None:
        schedule = compute_schedule(plan, k)
    itemsize = np.dtype(dtype).itemsize
    return simulate_peak_rows(plan, k, schedule) * n * itemsize * batch


def keep_everything_bytes(plan, k: int, n: int, batch: int = 1,
                          dtype=np.float32, passive_cache: bool = True
                          ) -> int:
    """Footprint of the pre-executor walk: every node table and every
    y-cache SpMM entry stays live until the end of the plan."""
    rows = 0
    leaf_seen = False
    y_seen: set[int] = set()
    for node in plan.nodes:
        if node.is_leaf:
            if not leaf_seen:      # all leaves alias one (k, N) one-hot
                rows += k
                leaf_seen = True
            continue
        rows += comb(k, node.size)
        if passive_cache and node.passive not in y_seen:
            rows += comb(k, plan.nodes[node.passive].size)
            y_seen.add(node.passive)
    itemsize = np.dtype(dtype).itemsize
    return rows * n * itemsize * batch


# --------------------------------------------------------------------------
# scheduling
# --------------------------------------------------------------------------
def _greedy_order(plan, k: int, *, passive_cache: bool,
                  chunks: dict[int, int],
                  keep: tuple[int, ...] = (),
                  fused: frozenset[int] = frozenset()) -> list[int]:
    """Greedy list scheduling: repeatedly evaluate the ready internal node
    whose modeled step peak (then post-step live size) is smallest.

    Leaves cost one shared (k, N) buffer and are emitted first. The final
    free lists always come from :func:`liveness` on the chosen order; the
    reference counts here only steer the choice.
    """
    rows = [comb(k, nd.size) for nd in plan.nodes]
    leaf_idxs = [i for i, nd in enumerate(plan.nodes) if nd.is_leaf]
    internal = [i for i, nd in enumerate(plan.nodes) if not nd.is_leaf]

    def buf(i: int):
        return "leaf" if plan.nodes[i].is_leaf else i

    # table-buffer reference counts: active uses + direct passive uses +
    # one per distinct cached passive child (consumed at y creation)
    refs: dict[object, int] = {}
    y_refs: dict[int, int] = {}
    for idx in internal:
        node = plan.nodes[idx]
        refs[buf(node.active)] = refs.get(buf(node.active), 0) + 1
        direct = (not passive_cache) or chunks.get(idx, 1) > 1 \
            or idx in fused
        if direct:
            refs[buf(node.passive)] = refs.get(buf(node.passive), 0) + 1
        else:
            if node.passive not in y_refs:
                refs[buf(node.passive)] = refs.get(buf(node.passive), 0) + 1
            y_refs[node.passive] = y_refs.get(node.passive, 0) + 1
    # kept outputs (fused-plan roots) are never droppable: pin their buffers
    for i in keep:
        refs[buf(i)] = refs.get(buf(i), 0) + plan.n_nodes + 1

    live_t: dict[object, int] = {}
    if leaf_idxs:
        live_t["leaf"] = k
    live_y: dict[int, int] = {}

    def step_cost(idx: int) -> tuple[int, int]:
        """(step peak, live rows after) if ``idx`` ran next — no mutation."""
        node = plan.nodes[idx]
        cur = sum(live_t.values()) + sum(live_y.values())
        out_r = rows[idx]
        q = chunks.get(idx, 1)
        if q > 1:
            peak = cur + -(-rows[node.passive] // q) + PAIR_BLOCK + out_r
        elif idx in fused:
            peak = cur + out_r
        elif not passive_cache:
            peak = cur + 2 * out_r
        else:
            creates = node.passive not in live_y
            peak = cur + (rows[node.passive] if creates else 0) + out_r
        after = cur + out_r
        direct = (not passive_cache) or q > 1 or idx in fused
        dead: set[object] = set()
        if refs.get(buf(node.active), 0) == 1:
            dead.add(buf(node.active))
        if direct or node.passive not in live_y:
            if refs.get(buf(node.passive), 0) == 1:
                dead.add(buf(node.passive))
        if not direct and y_refs.get(node.passive, 0) == 1 \
                and node.passive in live_y:
            after -= live_y[node.passive]
        for b in dead:
            after -= live_t.get(b, 0)
        return peak, after

    order = list(leaf_idxs)
    done = set(leaf_idxs)
    remaining = set(internal)
    while remaining:
        ready = [i for i in remaining
                 if plan.nodes[i].active in done
                 and plan.nodes[i].passive in done]
        pick = min(ready, key=lambda i: step_cost(i) + (i,))
        node = plan.nodes[pick]
        q = chunks.get(pick, 1)
        direct = (not passive_cache) or q > 1 or pick in fused

        def consume(b: object) -> None:
            refs[b] = refs.get(b, 0) - 1
            if refs[b] <= 0:
                live_t.pop(b, None)

        if direct:
            consume(buf(node.passive))
        else:
            if node.passive not in live_y:
                live_y[node.passive] = rows[node.passive]
                consume(buf(node.passive))
            y_refs[node.passive] -= 1
            if y_refs[node.passive] <= 0:
                live_y.pop(node.passive, None)
        consume(buf(node.active))
        live_t[pick] = rows[pick]
        order.append(pick)
        done.add(pick)
        remaining.discard(pick)
    return order


def compute_schedule(plan, k: int | None = None, *,
                     passive_cache: bool = True,
                     chunks: dict[int, int] | None = None,
                     order_mode: str = "auto",
                     keep: tuple[int, ...] = (),
                     fused: tuple[int, ...] = (),
                     fused_groups: tuple[tuple[int, ...], ...] = ()
                     ) -> Schedule:
    """Build a :class:`Schedule` for ``plan``.

    ``order_mode``: ``"program"`` keeps the plan's own post-order;
    ``"greedy"`` uses the min-peak list scheduler; ``"auto"`` (default)
    simulates both and keeps the one with the smaller modeled peak.
    ``keep`` lists extra output nodes never to free (fused-plan roots);
    ``fused`` lists nodes running the fused SpMM->eMA kernel (their
    neighbor-sum table never reaches HBM — see :class:`Schedule`).
    ``fused_groups`` lists shared-passive groups over ``fused`` nodes: each
    candidate order is regrouped so members run consecutively (one launch);
    a group whose regrouped order stops being topological — some member's
    consumer sits between the members — is dropped for that candidate, and
    its members leave ``fused`` entirely (back to the y-cache path, which
    still pays the shared SpMM once; singleton-fusing them would pay it per
    consumer).
    """
    k = k or plan.k
    cmap = dict(chunks or {})
    keep = tuple(sorted(set(keep)))
    fused = tuple(sorted(set(fused)))
    fset = frozenset(fused)
    candidates: list[tuple[int, ...]] = []
    if order_mode in ("program", "auto"):
        candidates.append(tuple(range(plan.n_nodes)))
    if order_mode in ("greedy", "auto"):
        candidates.append(tuple(_greedy_order(
            plan, k, passive_cache=passive_cache, chunks=cmap, keep=keep,
            fused=fset)))
    if not candidates:
        raise ValueError(f"unknown order_mode {order_mode!r}")
    best: Schedule | None = None
    best_peak: int | None = None
    for order in candidates:
        accepted: list[tuple[int, ...]] = []
        for grp in fused_groups:
            gset = set(grp)
            if any(plan.nodes[m].active in gset or plan.nodes[m].passive
                   in gset for m in grp):
                # a single launch cannot consume its own outputs
                continue
            trial = _regroup_order(order, accepted + [tuple(grp)])
            try:
                _validate_order(plan, trial)
            except ValueError:
                continue
            accepted.append(tuple(grp))
        if accepted:
            order = _regroup_order(order, accepted)
        kept_members = {m for grp in accepted for m in grp}
        dropped = {m for grp in fused_groups for m in grp} - kept_members
        fused_c = tuple(i for i in fused if i not in dropped)
        ft, fy = liveness(plan, order, passive_cache=passive_cache,
                          chunks=cmap, keep=keep, fused=fused_c)
        sched = Schedule(order=order, free_tables=ft, free_y=fy,
                         chunks=tuple(sorted(cmap.items())),
                         passive_cache=passive_cache, keep=keep,
                         fused=fused_c, fused_groups=tuple(accepted))
        peak = simulate_peak_rows(plan, k, sched)
        if best_peak is None or peak < best_peak:
            best, best_peak = sched, peak
    return best


# --------------------------------------------------------------------------
# budget -> (batch size, schedule)
# --------------------------------------------------------------------------
def pick_execution(plan, k: int, n: int, *,
                   memory_budget_bytes: int | None = None,
                   dtype=np.float32, max_batch: int = MAX_AUTO_BATCH,
                   passive_cache: bool = True,
                   allow_chunking: bool = True,
                   keep: tuple[int, ...] = (),
                   fused: tuple[int, ...] = (),
                   fused_groups: tuple[tuple[int, ...], ...] = ()
                   ) -> ExecutionChoice:
    """Turn one ``memory_budget_bytes`` knob into (batch size, schedule).

    The batch is the largest B with ``B * peak(batch=1) <= budget`` (capped
    at ``max_batch``). ``fused`` nodes run the fused SpMM->eMA kernel and
    are charged no neighbor-sum rows, so the same budget admits a larger
    batch. If even B=1 exceeds the budget and ``allow_chunking``,
    passive-axis chunk counts are doubled node by node — always at the step
    realizing the current peak — until the modeled peak fits or every
    chunkable node is at single-row chunks (the irreducible floor of
    active + passive + output tables; the choice is then best-effort with
    ``fits=False``). Shared-passive ``fused_groups`` survive only on the
    unchunked path: once chunking starts, groups are dropped (their members
    stay singleton-fused) — a group step materializes every member's output
    at once, the opposite of what a budget squeeze wants.
    """
    budget = memory_budget_bytes if memory_budget_bytes is not None \
        else DEFAULT_MEMORY_BUDGET_BYTES
    itemsize = np.dtype(dtype).itemsize
    fused = tuple(sorted(set(fused)))
    sched = compute_schedule(plan, k, passive_cache=passive_cache, keep=keep,
                             fused=fused, fused_groups=fused_groups)
    per1 = simulate_peak_rows(plan, k, sched) * n * itemsize
    if per1 <= budget:
        batch = max(1, min(max_batch, budget // max(per1, 1)))
        return ExecutionChoice(int(batch), sched, per1, budget, True)
    if not allow_chunking:
        return ExecutionChoice(1, sched, per1, budget, False)

    # chunked path: drop the shared groups AND their members from fused
    # (members return to the y-cache — one SpMM per shared passive, just
    # materialized in HBM; singleton-fusing them would pay it per consumer)
    if fused_groups:
        members = {m for grp in fused_groups for m in grp}
        fused = tuple(i for i in fused if i not in members)

    budget_rows = budget // (n * itemsize)
    cmap: dict[int, int] = {}

    def evaluate(chunk_map):
        s = compute_schedule(plan, k, passive_cache=passive_cache,
                             chunks=chunk_map, keep=keep, fused=fused)
        p = _step_peaks(plan, k, s.order, s.free_tables, s.free_y,
                        passive_cache=passive_cache, chunks=s.chunk_map,
                        fused=s.fused_set)
        return s, p, max(p)

    sched, peaks, peak = evaluate(cmap)
    while peak > budget_rows:
        # try chunking the node at the hottest step; accept only strict
        # improvements (chunking keeps m_a AND m_p live through the step,
        # so it can lose when the passive table is narrow)
        improved = False
        for s_idx in sorted(range(len(peaks)), key=lambda s: -peaks[s]):
            hot = sched.order[s_idx]
            node = plan.nodes[hot]
            if node.is_leaf:
                continue
            p_rows = comb(k, plan.nodes[node.passive].size)
            q = cmap.get(hot, 1)
            if q >= p_rows:
                continue
            for q_new in (min(2 * q, p_rows), p_rows):
                trial = dict(cmap)
                trial[hot] = q_new
                t_sched, t_peaks, t_peak = evaluate(trial)
                if t_peak < peak:
                    cmap, sched, peaks, peak = trial, t_sched, t_peaks, t_peak
                    improved = True
                    break
            if improved:
                break
        if not improved:   # irreducible floor for every hot step
            break
    per1 = peak * n * itemsize
    return ExecutionChoice(1, sched, per1, budget, per1 <= budget)


# --------------------------------------------------------------------------
# the executor
# --------------------------------------------------------------------------
class PlanExecutor:
    """Drives one scheduled plan walk; engine-specific math via callbacks.

    ``run(leaf, passive_op=, combine=, combine_direct=, on_step=)``:

    * ``leaf``: the shared leaf table (every leaf node aliases it);
    * ``passive_op(p_idx, m_p)``: passive transform (SpMM / neighbor sum),
      cached per distinct passive child — required iff the schedule has
      ``passive_cache=True``;
    * ``combine(idx, m_a, y_p)``: eMA of the active table with the cached
      transform;
    * ``combine_direct(idx, m_a, m_p)``: used for chunked nodes, fused
      SpMM->eMA nodes, and cache-less walks (FASCIA) — consumes the passive
      *table* directly (the engine picks chunked/fused kernel per node);
    * ``combine_group(members, m_as, m_p)``: one shared-passive launch for a
      whole ``fused_groups`` group — returns one table per member. Required
      iff the schedule carries groups; invoked at the group's first member's
      step, later member steps only process their frees;
    * ``on_step(step, live_bytes)``: optional instrumentation hook called
      twice per step (post-compute and post-free) with the live table bytes
      (unique buffers only), so measured peaks can be checked against
      :func:`peak_table_bytes`.

    Buffers are dropped at their statically computed last use; in traced
    code that shapes the dataflow XLA's buffer assignment sees, and in
    eager/interpret runs it releases device memory immediately.
    """

    def __init__(self, plan, schedule: Schedule):
        _validate_order(plan, schedule.order)
        self.plan = plan
        self.schedule = schedule

    @staticmethod
    def _live_bytes(tables: dict, y: dict) -> int:
        uniq: dict[int, object] = {}
        for v in list(tables.values()) + list(y.values()):
            if v is not None:
                uniq[id(v)] = v
        total = 0
        for v in uniq.values():
            size = int(np.prod(v.shape)) if hasattr(v, "shape") else 0
            total += size * np.dtype(v.dtype).itemsize
        return total

    def run(self, leaf, *, passive_op=None, combine=None,
            combine_direct=None, combine_group=None, on_step=None,
            outputs=None):
        """Walk the schedule; returns the root table, or — when ``outputs``
        (a tuple of node indices) is given — one table per output index.
        Every non-root output must be in the schedule's ``keep`` set, i.e.
        the schedule must have been built with ``keep=`` covering it."""
        plan, sched = self.plan, self.schedule
        chunks = sched.chunk_map
        fset = sched.fused_set
        group_of = sched.group_of
        if sched.passive_cache and passive_op is None:
            raise ValueError("schedule expects a passive_op "
                             "(built with passive_cache=True)")
        if not sched.passive_cache and combine_direct is None:
            raise ValueError("cache-less schedule needs combine_direct")
        if group_of and combine_group is None:
            raise ValueError("schedule carries fused_groups; run() needs a "
                             "combine_group callback")
        tables: dict[int, object] = {}
        y: dict[int, object] = {}
        root_idx = plan.n_nodes - 1
        keepset = {root_idx} | set(sched.keep)
        if outputs is not None:
            missing = [i for i in outputs if i not in keepset]
            if missing:
                raise ValueError(
                    f"outputs {missing} are not kept by this schedule; "
                    "build it with compute_schedule(..., keep=...)")
        for step, idx in enumerate(sched.order):
            node = plan.nodes[idx]
            if node.is_leaf:
                tables[idx] = leaf
            elif idx in group_of and chunks.get(idx, 1) <= 1:
                grp = group_of[idx]
                if idx not in tables:
                    # leader step: one launch materializes EVERY member
                    with _tracing.span("plan.node", idx=idx, size=node.size,
                                       mode="fused_shared", group=len(grp)):
                        outs_g = combine_group(
                            grp, [tables[plan.nodes[m].active] for m in grp],
                            tables[node.passive])
                    for m, t in zip(grp, outs_g):
                        tables[m] = t
                # non-leader member steps: table already present, only frees
            else:
                m_a = tables[node.active]
                direct = (not sched.passive_cache) \
                    or chunks.get(idx, 1) > 1 or idx in fset
                mode = ("chunked" if chunks.get(idx, 1) > 1
                        else "fused" if idx in fset
                        else "direct" if direct else "cached")
                # spans here run at jit-trace time (once per compiled
                # shape): they expose per-node plan structure, not device
                # time — that belongs to the engine's dispatch span
                with _tracing.span("plan.node", idx=idx, size=node.size,
                                   mode=mode):
                    if direct:
                        tables[idx] = combine_direct(idx, m_a,
                                                     tables[node.passive])
                    else:
                        if node.passive not in y:
                            y[node.passive] = passive_op(
                                node.passive, tables[node.passive])
                            # mid-step release: the passive table may die
                            # the moment its y entry exists
                            if node.passive in sched.free_tables[step] \
                                    and node.passive != node.active:
                                tables.pop(node.passive, None)
                        tables[idx] = combine(idx, m_a, y[node.passive])
                m_a = None
            if on_step is not None:
                on_step(step, self._live_bytes(tables, y))
            for i in sched.free_tables[step]:
                if i not in keepset:
                    tables.pop(i, None)
            for p in sched.free_y[step]:
                y.pop(p, None)
            if on_step is not None:
                on_step(step, self._live_bytes(tables, y))
        if outputs is not None:
            return tuple(tables[i] for i in outputs)
        return tables[root_idx]
