"""Fault-tolerant color-coding estimator runner.

Color-coding iterations are independent, idempotent units of work (the
coloring is derived from fold_in(seed, iteration)), which makes the
fault-tolerance model simple and strong:

* a **ledger** (checksummed JSON, atomically replaced) records which
  iterations are done and the accumulated colorful sum;
* on restart, only missing iterations run — a preempted/failed run loses at
  most ``checkpoint_every`` iterations of work; a *torn* ledger (kill -9
  mid-write, disk corruption) is detected by its CRC envelope, quarantined
  to ``ledger.json.corrupt``, and the run restarts cold instead of raising
  into the scheduler;
* stragglers / lost pods: iterations are dispatched in batches; any worker
  can pick up remaining ones because nothing is owner-pinned;
* elastic scaling: the ledger is mesh-shape independent, so a resumed run
  can use a different device mesh (or the single-device engine).

The same design scales the paper's §8 future work ("extending to distributed
systems") to thousands of nodes: the only global state is ~100 bytes of
ledger per iteration batch.
"""

from __future__ import annotations

import dataclasses
import os
import time

import numpy as np

from repro.core.colorsets import colorful_probability
from repro.obs import metrics as _metrics
from repro.obs import tracing as _tracing
from repro.resilience import faults as _faults
from repro.resilience import recovery as _recovery

__all__ = ["EstimatorRunner", "RunnerResult"]


@dataclasses.dataclass
class RunnerResult:
    count: float
    colorful_sum: float
    completed: list[int]
    elapsed_s: float
    restarts: int
    per_iteration: dict[int, float] = dataclasses.field(default_factory=dict)


class EstimatorRunner:
    """Drives ``n_iterations`` of any engine exposing per-iteration counting.

    ``counter(iterations: list[int]) -> dict[int, float]`` maps iteration ids
    to colorful sums. Both the single-device CountingEngine and the
    DistributedPgbsc adapt to this via the helpers below.

    Two driving modes share the ledger:

    * **fixed budget** — :meth:`run` executes iterations ``0..n_iterations``;
    * **adaptive** — construct with ``n_iterations=None`` and call
      :meth:`run_iterations` with explicit iteration ids chosen round by
      round (the service scheduler's mode); already-ledgered ids are served
      from the ledger, so a killed run resumes without recomputation and the
      total iteration count can grow until a precision target is met.
    """

    def __init__(self, counter, *, k: int, automorphisms: int,
                 n_iterations: int | None, ledger_dir: str,
                 checkpoint_every: int = 8, seed: int = 0):
        self.counter = counter
        self.k = k
        self.alpha = automorphisms
        self.n_iterations = n_iterations
        self.ledger_dir = ledger_dir
        self.ledger_path = os.path.join(ledger_dir, "ledger.json")
        self.checkpoint_every = checkpoint_every
        self.seed = seed
        self._led: dict | None = None

    # ---------------------------------------------------------------- ledger
    def _load_ledger(self) -> dict:
        led, status = _recovery.load_checked(self.ledger_path, kind="ledger")
        if status not in ("ok", "missing"):
            _metrics.counter("runner_ledger_corruptions_total",
                             reason=status).inc()
        if led is not None and isinstance(led.get("completed"), dict) \
                and led.get("seed") == self.seed \
                and led.get("n_iterations") == self.n_iterations:
            return led
        return {"seed": self.seed, "n_iterations": self.n_iterations,
                "completed": {}, "restarts": 0}

    def _ledger(self) -> dict:
        """Ledger loaded once per runner instance; a non-empty ledger on
        first load means this instance is resuming a previous run."""
        if self._led is None:
            self._led = self._load_ledger()
            if self._led["completed"]:
                self._led["restarts"] = self._led.get("restarts", 0) + 1
                _metrics.counter("runner_resumes_total").inc()
                _metrics.counter("runner_resumed_iterations_total").inc(
                    len(self._led["completed"]))
        return self._led

    def _save_ledger(self, led: dict) -> None:
        os.makedirs(self.ledger_dir, exist_ok=True)
        _recovery.write_checked(self.ledger_path, led,
                                fault_point="ledger.write")

    def completed_iterations(self) -> dict[int, float]:
        """Ledgered {iteration id: colorful sum} — work already done."""
        led = self._ledger()
        return {int(k): float(v) for k, v in led["completed"].items()}

    # ------------------------------------------------------------------ run
    def run_iterations(self, iterations) -> dict[int, float]:
        """Run explicit iteration ids, checkpointing; -> {id: colorful sum}.

        Ids already in the ledger are returned without recomputation; fresh
        ones run through the counter in ``checkpoint_every`` batches (each a
        single device dispatch for batched engines), the ledger being
        atomically replaced after every batch.
        """
        led = self._ledger()
        done = {int(k): v for k, v in led["completed"].items()}
        ids = [int(i) for i in iterations]
        pending = [i for i in ids if i not in done]
        if len(pending) < len(ids):
            _metrics.counter("runner_ledger_served_iterations_total").inc(
                len(ids) - len(pending))
        for base in range(0, len(pending), self.checkpoint_every):
            batch = pending[base: base + self.checkpoint_every]
            with _tracing.span("runner.checkpoint", n=len(batch)):
                results = self.counter(batch)
            for it, val in results.items():
                done[int(it)] = float(val)
            led["completed"] = {str(k): v for k, v in done.items()}
            self._save_ledger(led)
            _metrics.counter("runner_checkpoints_total").inc()
            _metrics.counter("runner_iterations_total").inc(len(batch))
        return {i: done[i] for i in ids}

    def run(self, max_iterations_this_call: int | None = None) -> RunnerResult:
        if self.n_iterations is None:
            raise ValueError("run() needs a fixed n_iterations; "
                             "adaptive runners use run_iterations()")
        t0 = time.time()
        led = self._ledger()
        done = {int(k): v for k, v in led["completed"].items()}
        pending = [i for i in range(self.n_iterations) if i not in done]
        if max_iterations_this_call is not None:
            pending = pending[:max_iterations_this_call]

        for base in range(0, len(pending), self.checkpoint_every):
            batch = pending[base: base + self.checkpoint_every]
            with _tracing.span("runner.checkpoint", n=len(batch)):
                results = self.counter(batch)
            for it, val in results.items():
                done[int(it)] = float(val)
            led["completed"] = {str(k): v for k, v in done.items()}
            self._save_ledger(led)
            _metrics.counter("runner_checkpoints_total").inc()
            _metrics.counter("runner_iterations_total").inc(len(batch))

        total = float(np.sum(list(done.values()))) if done else 0.0
        n_done = len(done)
        p = colorful_probability(self.k)
        est = total / max(n_done, 1) / (self.alpha * p)
        return RunnerResult(
            count=est, colorful_sum=total,
            completed=sorted(done), elapsed_s=time.time() - t0,
            restarts=led.get("restarts", 0),
            per_iteration=dict(sorted(done.items())),
        )


def engine_counter(engine, seed: int = 0, batch_size: int | None = None,
                   label: str | None = None):
    """Adapt a CountingEngine to the runner's counter interface.

    A whole checkpoint batch is dispatched as ONE device call through the
    engine's batched pipeline (colorings generated device-side from
    ``fold_in(seed, iteration)``); ``batch_size`` overrides the engine's
    chunking knob. Per-iteration values are independent of how iterations
    are grouped into batches, so resumed runs reproduce straight runs.

    ``label`` names this dispatch stream at the ``kernel.dispatch`` fault
    point (so chaos plans can target one group); defaults to the engine
    kind.
    """
    ctx = label if label is not None else getattr(engine, "engine", "engine")

    def counter(iterations):
        _faults.inject("kernel.dispatch", context=ctx)
        return engine.count_iterations_batch(list(iterations), seed=seed,
                                             batch_size=batch_size)

    return counter


def distributed_counter(dist, seed: int = 0, batch_size: int | None = None):
    """Adapt a DistributedPgbsc to the runner's counter interface.

    ``batch_size`` = coloring iterations per pod per device call (scanned
    inside the jit); None keeps the DistributedPgbsc default.
    """

    def counter(iterations):
        kw = {} if batch_size is None else {"batch_size": batch_size}
        _, per_iter = dist.count_iterations(list(iterations), seed=seed, **kw)
        return per_iter

    return counter
