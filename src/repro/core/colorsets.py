"""Color-set indexing (paper Eq. 1) and active/passive split tables.

A color set C = {c_1 < c_2 < ... < c_h} drawn from k colors is ranked into
``I_C = C(c_1,1) + C(c_2,2) + ... + C(c_h,h)`` — the combinatorial number
system, a bijection onto [0, C(k,h)).

For a sub-template of size t split into an active child of size t_a and a
passive child of size t_p (t_a + t_p = t), ``split_tables`` enumerates, for
every ranked color set of size t, all C(t, t_a) (active, passive) sub-set rank
pairs. These tables are static per template step and drive the eMA kernel.
"""

from __future__ import annotations

from functools import lru_cache
from itertools import combinations
from math import comb

import numpy as np

__all__ = [
    "comb",
    "rank_colorset",
    "unrank_colorset",
    "all_colorsets",
    "split_tables",
    "colorful_probability",
]


def rank_colorset(colors) -> int:
    """Rank a sorted color tuple via the combinatorial number system."""
    cs = sorted(colors)
    return sum(comb(c, i + 1) for i, c in enumerate(cs))


def unrank_colorset(index: int, h: int, k: int) -> tuple[int, ...]:
    """Inverse of rank_colorset for sets of size h drawn from k colors."""
    out = []
    rem = index
    for i in range(h, 0, -1):
        # largest c with comb(c, i) <= rem
        c = i - 1
        while comb(c + 1, i) <= rem:
            c += 1
        out.append(c)
        rem -= comb(c, i)
    return tuple(sorted(out))


@lru_cache(maxsize=None)
def all_colorsets(k: int, h: int) -> tuple[tuple[int, ...], ...]:
    """All size-h subsets of [0,k) ordered by their rank."""
    sets = list(combinations(range(k), h))
    sets.sort(key=rank_colorset)
    # ranks must be exactly 0..C(k,h)-1
    assert [rank_colorset(s) for s in sets] == list(range(comb(k, h)))
    return tuple(sets)


@lru_cache(maxsize=None)
def split_tables(k: int, t: int, t_a: int) -> tuple[np.ndarray, np.ndarray]:
    """Active/passive rank tables.

    Returns (IA, IP), both int32 of shape (C(k, t), C(t, t_a)):
    for ranked color set j of size t and split l, ``IA[j, l]`` is the rank of
    the active subset (size t_a) and ``IP[j, l]`` the rank of the passive
    complement (size t - t_a).
    """
    t_p = t - t_a
    n_sets = comb(k, t)
    n_splits = comb(t, t_a)
    ia = np.zeros((n_sets, n_splits), dtype=np.int32)
    ip = np.zeros((n_sets, n_splits), dtype=np.int32)
    for j, cset in enumerate(all_colorsets(k, t)):
        for l, a_sub in enumerate(combinations(cset, t_a)):
            p_sub = tuple(c for c in cset if c not in a_sub)
            assert len(p_sub) == t_p
            ia[j, l] = rank_colorset(a_sub)
            ip[j, l] = rank_colorset(p_sub)
    return ia, ip


def colorful_probability(k: int) -> float:
    """P(a fixed k-vertex embedding is colorful) = k!/k^k."""
    p = 1.0
    for i in range(1, k + 1):
        p *= i / k
    return p
