"""Tree templates and FASCIA-style partitioning (paper §2.1 phase 2).

A template T (tree on k vertices) rooted at ``root`` is recursively cut at an
edge adjacent to the current root: the *active* child keeps the root; the
*passive* child is the subtree hanging off the cut edge. Leaves are single
vertices. The resulting binary partition tree is evaluated bottom-up
(post-order) by the dynamic program.
"""

from __future__ import annotations

import dataclasses
from functools import cached_property

import numpy as np

__all__ = ["TreeTemplate", "PlanNode", "ExecutionPlan", "STANDARD_TEMPLATES",
           "get_template"]


@dataclasses.dataclass(frozen=True)
class PlanNode:
    """One sub-template in the DP, identified by its vertex set.

    ``active``/``passive`` are indices into ExecutionPlan.nodes (None = leaf).
    ``size`` = number of template vertices in this sub-template.
    """

    vertices: tuple[int, ...]
    root: int
    active: int | None
    passive: int | None

    @property
    def size(self) -> int:
        return len(self.vertices)

    @property
    def is_leaf(self) -> bool:
        return self.active is None


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Post-order list of sub-templates; the full template is ``nodes[-1]``."""

    nodes: tuple[PlanNode, ...]
    k: int

    def __post_init__(self):
        for i, nd in enumerate(self.nodes):
            if not nd.is_leaf:
                assert nd.active < i and nd.passive < i, "plan must be post-order"

    @property
    def n_nodes(self) -> int:
        return len(self.nodes)

    def table_widths(self, k: int | None = None):
        from math import comb
        k = k or self.k
        return [comb(k, nd.size) for nd in self.nodes]


class TreeTemplate:
    """An unrooted tree on vertices 0..k-1 given by its edge list."""

    def __init__(self, edges, root: int = 0, name: str = "t"):
        self.edges = tuple(tuple(sorted(e)) for e in edges)
        self.name = name
        self.root = root
        ks = {v for e in self.edges for v in e} | {root}
        self.k = (max(ks) + 1) if ks else 1
        if len(self.edges) != self.k - 1:
            raise ValueError(f"not a tree: {self.k} vertices, {len(self.edges)} edges")
        self._adj: dict[int, list[int]] = {v: [] for v in range(self.k)}
        for u, v in self.edges:
            self._adj[u].append(v)
            self._adj[v].append(u)
        # connectivity check
        seen = {0}
        stack = [0]
        while stack:
            v = stack.pop()
            for u in self._adj[v]:
                if u not in seen:
                    seen.add(u)
                    stack.append(u)
        if len(seen) != self.k:
            raise ValueError("template is not connected")

    def adjacency(self, v: int) -> list[int]:
        return self._adj[v]

    def subtree_vertices(self, root: int, banned: int) -> tuple[int, ...]:
        """Vertices reachable from ``root`` without passing through ``banned``."""
        seen = {root}
        stack = [root]
        while stack:
            v = stack.pop()
            for u in self._adj[v]:
                if u != banned and u not in seen:
                    seen.add(u)
                    stack.append(u)
        return tuple(sorted(seen))

    @cached_property
    def plan(self) -> ExecutionPlan:
        """FASCIA partitioning: cut the first adjacent edge of the root."""
        return self._build_plan(dedup=False)

    @cached_property
    def plan_dedup(self) -> ExecutionPlan:
        """Plan with isomorphic sub-templates shared (beyond-paper optimization).

        Two sub-templates with the same *rooted canonical form* provably have
        identical count tables (the DP result is independent of the partition
        choice), so their tables — and the SpMM over their passive children —
        can be computed once.
        """
        return self._build_plan(dedup=True)

    @cached_property
    def plan_optimized(self) -> ExecutionPlan:
        """Work-optimal partitioning (beyond-paper): instead of FASCIA's
        first-adjacent-edge cut, cut the edge whose passive subtree is
        smallest. The SpMM term of a sub-template costs E * C(k, t_p), so
        keeping t_p small (and the active chain long) minimizes traversal
        work; combined with canonical-form dedup. See EXPERIMENTS.md §Perf.
        """
        return self._build_plan(dedup=True, optimize=True)

    def _rooted_canon(self, vertices: tuple[int, ...], root: int) -> str:
        vset = set(vertices)

        def rec(v: int, parent: int) -> str:
            subs = sorted(
                rec(u, v) for u in self._adj[v] if u != parent and u in vset
            )
            return "(" + "".join(subs) + ")"

        return rec(root, -1)

    def _build_plan(self, dedup: bool, optimize: bool = False) -> ExecutionPlan:
        nodes: list[PlanNode] = []
        cache: dict = {}

        def pick_cut(vset: set, root: int) -> int:
            cands = [u for u in self._adj[root] if u in vset]
            if not optimize:
                return cands[0]
            # smallest passive subtree minimizes E * C(k, t_p)
            def psize(u):
                return len([v for v in self.subtree_vertices(u, root)
                            if v in vset])
            return min(cands, key=psize)

        def build(vertices: tuple[int, ...], root: int) -> int:
            key = self._rooted_canon(vertices, root) if dedup else (vertices, root)
            if key in cache:
                return cache[key]
            if len(vertices) == 1:
                nodes.append(PlanNode(vertices, root, None, None))
            else:
                vset = set(vertices)
                tau = pick_cut(vset, root)
                passive_vs = tuple(
                    v for v in self.subtree_vertices(tau, root) if v in vset
                )
                active_vs = tuple(v for v in vertices if v not in passive_vs)
                ai = build(active_vs, root)
                pi = build(passive_vs, tau)
                nodes.append(PlanNode(vertices, root, ai, pi))
            cache[key] = len(nodes) - 1
            return cache[key]

        build(tuple(range(self.k)), self.root)
        return ExecutionPlan(tuple(nodes), self.k)

    @property
    def dedup_savings(self) -> tuple[int, int]:
        """(nodes in plain plan, nodes in dedup plan)."""
        return self.plan.n_nodes, self.plan_dedup.n_nodes

    @cached_property
    def automorphisms(self) -> int:
        from repro.core.automorphism import tree_automorphisms
        return tree_automorphisms(self.edges, self.k)

    def to_arrays(self) -> np.ndarray:
        return np.asarray(self.edges, dtype=np.int32)

    def __repr__(self):
        return f"TreeTemplate({self.name}, k={self.k})"


def _path(k: int, name: str) -> TreeTemplate:
    return TreeTemplate([(i, i + 1) for i in range(k - 1)], name=name)


def _star(k: int, name: str) -> TreeTemplate:
    return TreeTemplate([(0, i) for i in range(1, k)], name=name)


def _caterpillar(spine: int, legs_at, k: int, name: str) -> TreeTemplate:
    """Path of ``spine`` vertices with extra leaves attached at given spine ids."""
    edges = [(i, i + 1) for i in range(spine - 1)]
    nxt = spine
    for s in legs_at:
        edges.append((s, nxt))
        nxt += 1
    assert nxt == k, (nxt, k)
    return TreeTemplate(edges, name=name)


def _binary(k: int, name: str) -> TreeTemplate:
    """Complete-ish binary tree on k vertices (heap numbering)."""
    edges = [((i - 1) // 2, i) for i in range(1, k)]
    return TreeTemplate(edges, name=name)


# Templates follow the paper's u10..u17 naming (FASCIA's test templates are
# paths/caterpillars/near-binary trees; exact shapes were "from the tests in
# [32] or created by us", so we create representative ones of each size).
STANDARD_TEMPLATES: dict[str, TreeTemplate] = {
    "u3": _path(3, "u3"),
    "u5": _caterpillar(3, [1, 1], 5, "u5"),
    "u7": _binary(7, "u7"),
    "u10": _caterpillar(6, [1, 2, 3, 4], 10, "u10"),
    "u12": _caterpillar(7, [1, 2, 3, 4, 5], 12, "u12"),
    "u13": _binary(13, "u13"),
    "u14": _caterpillar(8, [1, 2, 3, 4, 5, 6], 14, "u14"),
    "u15-1": _caterpillar(9, [1, 2, 3, 4, 5, 6], 15, "u15-1"),
    "u15-2": _binary(15, "u15-2"),
    "u16": _caterpillar(10, [1, 2, 3, 4, 5, 6], 16, "u16"),
    "u17": _caterpillar(11, [1, 2, 3, 4, 5, 6], 17, "u17"),
    "path5": _path(5, "path5"),
    "star5": _star(5, "star5"),
    "path4": _path(4, "path4"),
    "star4": _star(4, "star4"),
}


def get_template(name: str) -> TreeTemplate:
    if name not in STANDARD_TEMPLATES:
        raise KeyError(f"unknown template {name!r}; have {sorted(STANDARD_TEMPLATES)}")
    return STANDARD_TEMPLATES[name]
