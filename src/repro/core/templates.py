"""Tree templates and FASCIA-style partitioning (paper §2.1 phase 2).

A template T (tree on k vertices) rooted at ``root`` is recursively cut at an
edge adjacent to the current root: the *active* child keeps the root; the
*passive* child is the subtree hanging off the cut edge. Leaves are single
vertices. The resulting binary partition tree is evaluated bottom-up
(post-order) by the dynamic program.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import re
from functools import cached_property

import numpy as np

__all__ = ["TreeTemplate", "PlanNode", "ExecutionPlan", "TemplateSpec",
           "FusedPlan", "compile_fused_plan", "as_template",
           "STANDARD_TEMPLATES", "get_template"]


@dataclasses.dataclass(frozen=True)
class PlanNode:
    """One sub-template in the DP, identified by its vertex set.

    ``active``/``passive`` are indices into ExecutionPlan.nodes (None = leaf).
    ``size`` = number of template vertices in this sub-template.
    """

    vertices: tuple[int, ...]
    root: int
    active: int | None
    passive: int | None

    @property
    def size(self) -> int:
        return len(self.vertices)

    @property
    def is_leaf(self) -> bool:
        return self.active is None


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Post-order list of sub-templates; the full template is ``nodes[-1]``."""

    nodes: tuple[PlanNode, ...]
    k: int

    def __post_init__(self):
        for i, nd in enumerate(self.nodes):
            if not nd.is_leaf:
                assert nd.active < i and nd.passive < i, "plan must be post-order"

    @property
    def n_nodes(self) -> int:
        return len(self.nodes)

    def table_widths(self, k: int | None = None):
        from math import comb
        k = k or self.k
        return [comb(k, nd.size) for nd in self.nodes]


class TreeTemplate:
    """An unrooted tree on vertices 0..k-1 given by its edge list."""

    def __init__(self, edges, root: int = 0, name: str = "t"):
        raw = [tuple(e) for e in edges]
        for e in raw:
            if len(e) != 2:
                raise ValueError(f"edge {e!r} is not a vertex pair")
            u, v = e
            if u == v:
                raise ValueError(f"self-loop ({u}, {v}): tree templates have "
                                 "no self-loops")
            if u < 0 or v < 0:
                raise ValueError(f"edge ({u}, {v}) has a negative vertex id; "
                                 "template vertices are 0..k-1")
        self.edges = tuple(tuple(sorted(e)) for e in raw)
        if len(set(self.edges)) != len(self.edges):
            dup = next(e for e in self.edges if self.edges.count(e) > 1)
            raise ValueError(f"duplicate edge {dup} forms a cycle; "
                             "a tree has k-1 distinct edges")
        self.name = name
        self.root = root
        ks = {v for e in self.edges for v in e}
        self.k = (max(ks) + 1) if ks else 1
        if not 0 <= root < self.k:
            raise ValueError(f"root {root} is out of range: template "
                             f"vertices are 0..{self.k - 1}")
        if ks and ks != set(range(self.k)):
            missing = sorted(set(range(self.k)) - ks)
            raise ValueError(f"edge list skips vertices {missing}; template "
                             f"vertices must be exactly 0..{self.k - 1}")
        if len(self.edges) >= self.k:
            raise ValueError(f"not a tree: {self.k} vertices with "
                             f"{len(self.edges)} edges contain a cycle")
        if len(self.edges) < self.k - 1:
            raise ValueError(f"not a tree: {self.k} vertices, "
                             f"{len(self.edges)} edges (disconnected)")
        self._adj: dict[int, list[int]] = {v: [] for v in range(self.k)}
        for u, v in self.edges:
            self._adj[u].append(v)
            self._adj[v].append(u)
        # connectivity check (k-1 edges + a disconnection implies a cycle too)
        seen = {0}
        stack = [0]
        while stack:
            v = stack.pop()
            for u in self._adj[v]:
                if u not in seen:
                    seen.add(u)
                    stack.append(u)
        if len(seen) != self.k:
            unreached = sorted(set(range(self.k)) - seen)
            raise ValueError(f"template is not connected: vertices "
                             f"{unreached} are unreachable from vertex 0 "
                             "(so another component carries a cycle)")

    def adjacency(self, v: int) -> list[int]:
        return self._adj[v]

    def subtree_vertices(self, root: int, banned: int) -> tuple[int, ...]:
        """Vertices reachable from ``root`` without passing through ``banned``."""
        seen = {root}
        stack = [root]
        while stack:
            v = stack.pop()
            for u in self._adj[v]:
                if u != banned and u not in seen:
                    seen.add(u)
                    stack.append(u)
        return tuple(sorted(seen))

    @cached_property
    def plan(self) -> ExecutionPlan:
        """FASCIA partitioning: cut the first adjacent edge of the root."""
        return self._build_plan(dedup=False)

    @cached_property
    def plan_dedup(self) -> ExecutionPlan:
        """Plan with isomorphic sub-templates shared (beyond-paper optimization).

        Two sub-templates with the same *rooted canonical form* provably have
        identical count tables (the DP result is independent of the partition
        choice), so their tables — and the SpMM over their passive children —
        can be computed once.
        """
        return self._build_plan(dedup=True)

    @cached_property
    def plan_optimized(self) -> ExecutionPlan:
        """Work-optimal partitioning (beyond-paper): instead of FASCIA's
        first-adjacent-edge cut, cut the edge whose passive subtree is
        smallest. The SpMM term of a sub-template costs E * C(k, t_p), so
        keeping t_p small (and the active chain long) minimizes traversal
        work; combined with canonical-form dedup. See EXPERIMENTS.md §Perf.
        """
        return self._build_plan(dedup=True, optimize=True)

    def _rooted_canon(self, vertices: tuple[int, ...], root: int) -> str:
        vset = set(vertices)

        def rec(v: int, parent: int) -> str:
            subs = sorted(
                rec(u, v) for u in self._adj[v] if u != parent and u in vset
            )
            return "(" + "".join(subs) + ")"

        return rec(root, -1)

    def _build_plan(self, dedup: bool, optimize: bool = False) -> ExecutionPlan:
        nodes: list[PlanNode] = []
        self.grow_plan(nodes, {}, dedup=dedup, optimize=optimize)
        return ExecutionPlan(tuple(nodes), self.k)

    def grow_plan(self, nodes: list[PlanNode], cache: dict, *,
                  dedup: bool = True, optimize: bool = False) -> int:
        """Append this template's plan nodes to ``nodes`` (post-order) and
        return the index of this template's root node.

        With ``dedup`` the cache is keyed by the *rooted canonical form* of
        each sub-template — a structure-only key — so passing ONE shared
        ``(nodes, cache)`` pair across several same-k templates builds a
        fused plan in which canonically identical rooted sub-templates are
        computed once for all of them (the cross-template generalization of
        :attr:`plan_dedup`; see :func:`compile_fused_plan`). Without
        ``dedup`` keys carry the template identity, so nothing is shared.
        """

        def pick_cut(vset: set, root: int) -> int:
            cands = [u for u in self._adj[root] if u in vset]
            if not optimize:
                return cands[0]
            # smallest passive subtree minimizes E * C(k, t_p)
            def psize(u):
                return len([v for v in self.subtree_vertices(u, root)
                            if v in vset])
            return min(cands, key=psize)

        def build(vertices: tuple[int, ...], root: int) -> int:
            key = self._rooted_canon(vertices, root) if dedup \
                else (id(self), vertices, root)
            if key in cache:
                return cache[key]
            if len(vertices) == 1:
                nodes.append(PlanNode(vertices, root, None, None))
            else:
                vset = set(vertices)
                tau = pick_cut(vset, root)
                passive_vs = tuple(
                    v for v in self.subtree_vertices(tau, root) if v in vset
                )
                active_vs = tuple(v for v in vertices if v not in passive_vs)
                ai = build(active_vs, root)
                pi = build(passive_vs, tau)
                nodes.append(PlanNode(vertices, root, ai, pi))
            cache[key] = len(nodes) - 1
            return cache[key]

        return build(tuple(range(self.k)), self.root)

    @property
    def dedup_savings(self) -> tuple[int, int]:
        """(nodes in plain plan, nodes in dedup plan)."""
        return self.plan.n_nodes, self.plan_dedup.n_nodes

    @cached_property
    def automorphisms(self) -> int:
        from repro.core.automorphism import tree_automorphisms
        return tree_automorphisms(self.edges, self.k)

    @cached_property
    def rooted_canonical(self) -> str:
        """AHU canonical string of the full rooted template (structure only:
        vertex labels and the template name do not enter)."""
        return self._rooted_canon(tuple(range(self.k)), self.root)

    @cached_property
    def canonical_hash(self) -> str:
        """Content hash of :attr:`rooted_canonical`. Two templates with the
        same hash are the same rooted tree up to relabeling, so their plans,
        count tables, and estimates coincide — every cache in the stack
        (engine, estimate, dispatch group) keys on this, never on names."""
        return hashlib.sha256(self.rooted_canonical.encode()).hexdigest()[:16]

    def to_arrays(self) -> np.ndarray:
        return np.asarray(self.edges, dtype=np.int32)

    def __repr__(self):
        return f"TreeTemplate({self.name}, k={self.k})"


@dataclasses.dataclass(frozen=True)
class TemplateSpec:
    """Serializable, first-class template description (the query-API unit).

    A spec is *data*: an arbitrary tree edge list, a root choice, and an
    optional display name. It JSON round-trips (:meth:`to_json` /
    :meth:`from_json`), coerces from every template-ish thing the stack
    accepts (:meth:`of`: registry names — now sugar —, ``TreeTemplate``
    objects, other specs, raw edge lists), and exposes the template's
    :attr:`canonical_hash`, which is the identity every cache and dispatch
    group keys on: two specs naming the same rooted tree share engines,
    plans, sample streams, and persisted estimates.
    """

    edges: tuple[tuple[int, int], ...]
    root: int = 0
    name: str | None = None

    def __post_init__(self):
        object.__setattr__(self, "edges", tuple(
            tuple(int(v) for v in e) for e in self.edges))
        object.__setattr__(self, "root", int(self.root))

    # ------------------------------------------------------------- coercion
    @classmethod
    def of(cls, obj) -> "TemplateSpec":
        """Coerce a name / TreeTemplate / spec / edge list into a spec."""
        if isinstance(obj, TemplateSpec):
            return obj
        if isinstance(obj, TreeTemplate):
            spec = cls(edges=obj.edges, root=obj.root, name=obj.name)
            spec.__dict__["tree"] = obj     # reuse warm plan/automorphism caches
            return spec
        if isinstance(obj, str):
            return cls.of(get_template(obj))
        spec = cls(edges=tuple(tuple(e) for e in obj))
        spec.tree                           # validate eagerly: clear errors now
        return spec

    @classmethod
    def from_edge_string(cls, s: str, name: str | None = None
                         ) -> "TemplateSpec":
        """Parse the CLI form ``"0-1,1-2,1-3[@root]"``."""
        s = s.strip()
        root = 0
        if "@" in s:
            s, _, r = s.rpartition("@")
            root = int(r)
        edges = []
        for part in s.split(","):
            u, sep, v = part.strip().partition("-")
            if not sep:
                raise ValueError(f"bad edge {part!r}; expected 'u-v'")
            edges.append((int(u), int(v)))
        spec = cls(edges=tuple(edges), root=root, name=name)
        spec.tree
        return spec

    # ----------------------------------------------------------- derivation
    @cached_property
    def tree(self) -> TreeTemplate:
        return TreeTemplate(self.edges, root=self.root,
                            name=self.name or "spec")

    @property
    def k(self) -> int:
        return self.tree.k

    @property
    def canonical_hash(self) -> str:
        return self.tree.canonical_hash

    @property
    def automorphisms(self) -> int:
        return self.tree.automorphisms

    @property
    def display_name(self) -> str:
        return self.name or f"tpl:{self.canonical_hash[:8]}"

    # -------------------------------------------------------- serialization
    def to_dict(self) -> dict:
        d = {"edges": [list(e) for e in self.edges], "root": self.root}
        if self.name is not None:
            d["name"] = self.name
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "TemplateSpec":
        spec = cls(edges=tuple(tuple(e) for e in d["edges"]),
                   root=d.get("root", 0), name=d.get("name"))
        spec.tree
        return spec

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "TemplateSpec":
        return cls.from_dict(json.loads(s))


def as_template(obj) -> TreeTemplate:
    """Coerce a name / spec / edge list into a TreeTemplate (identity on
    TreeTemplate inputs, so warm plan caches are preserved)."""
    if isinstance(obj, TreeTemplate):
        return obj
    if isinstance(obj, str):
        return get_template(obj)
    return TemplateSpec.of(obj).tree


@dataclasses.dataclass(frozen=True)
class FusedPlan:
    """One :class:`ExecutionPlan` serving several same-k templates.

    ``roots[i]`` is the plan-node index holding template *i*'s full-template
    count table; interior nodes whose rooted canonical forms coincide across
    templates appear ONCE, so their tables — and the SpMM over their passive
    children — are computed once per coloring for the whole bundle.
    """

    plan: ExecutionPlan
    roots: tuple[int, ...]

    @property
    def k(self) -> int:
        return self.plan.k


def compile_fused_plan(templates, optimize: bool = True) -> FusedPlan:
    """Merge the ExecutionPlans of same-k templates into one fused plan by
    deduplicating canonical rooted sub-templates *across* templates.

    Two sub-templates with the same rooted canonical form provably have
    identical count tables for any coloring (the DP value is independent of
    the partition choice), so a motif-vector workload of N templates pays
    for the UNION of their sub-template sets, not the sum. ``optimize``
    selects the work-optimal (smallest-passive) cut, as
    :attr:`TreeTemplate.plan_optimized` does.
    """
    trees = [as_template(t) for t in templates]
    if not trees:
        raise ValueError("compile_fused_plan needs at least one template")
    ks = sorted({t.k for t in trees})
    if len(ks) != 1:
        raise ValueError(f"a fused plan shares one coloring, so all "
                         f"templates must have equal k; got k={ks} "
                         "(group by k first — repro.api.count_many does)")
    nodes: list[PlanNode] = []
    cache: dict = {}
    roots = tuple(t.grow_plan(nodes, cache, dedup=True, optimize=optimize)
                  for t in trees)
    return FusedPlan(ExecutionPlan(tuple(nodes), ks[0]), roots)


def _path(k: int, name: str) -> TreeTemplate:
    return TreeTemplate([(i, i + 1) for i in range(k - 1)], name=name)


def _star(k: int, name: str) -> TreeTemplate:
    return TreeTemplate([(0, i) for i in range(1, k)], name=name)


def _caterpillar(spine: int, legs_at, k: int, name: str) -> TreeTemplate:
    """Path of ``spine`` vertices with extra leaves attached at given spine ids."""
    edges = [(i, i + 1) for i in range(spine - 1)]
    nxt = spine
    for s in legs_at:
        edges.append((s, nxt))
        nxt += 1
    assert nxt == k, (nxt, k)
    return TreeTemplate(edges, name=name)


def _binary(k: int, name: str) -> TreeTemplate:
    """Complete-ish binary tree on k vertices (heap numbering)."""
    edges = [((i - 1) // 2, i) for i in range(1, k)]
    return TreeTemplate(edges, name=name)


# Templates follow the paper's u10..u17 naming (FASCIA's test templates are
# paths/caterpillars/near-binary trees; exact shapes were "from the tests in
# [32] or created by us", so we create representative ones of each size).
STANDARD_TEMPLATES: dict[str, TreeTemplate] = {
    "u3": _path(3, "u3"),
    "u5": _caterpillar(3, [1, 1], 5, "u5"),
    "u7": _binary(7, "u7"),
    "u10": _caterpillar(6, [1, 2, 3, 4], 10, "u10"),
    "u12": _caterpillar(7, [1, 2, 3, 4, 5], 12, "u12"),
    "u13": _binary(13, "u13"),
    "u14": _caterpillar(8, [1, 2, 3, 4, 5, 6], 14, "u14"),
    "u15-1": _caterpillar(9, [1, 2, 3, 4, 5, 6], 15, "u15-1"),
    "u15-2": _binary(15, "u15-2"),
    "u16": _caterpillar(10, [1, 2, 3, 4, 5, 6], 16, "u16"),
    "u17": _caterpillar(11, [1, 2, 3, 4, 5, 6], 17, "u17"),
    "path5": _path(5, "path5"),
    "star5": _star(5, "star5"),
    "path4": _path(4, "path4"),
    "star4": _star(4, "star4"),
}


_DYNAMIC_PATTERN = re.compile(r"^(path|star)([0-9]+)$")
_DYNAMIC_CACHE: dict[str, TreeTemplate] = {}


def get_template(name: str) -> TreeTemplate:
    """Registry lookup, plus dynamic ``path{k}`` / ``star{k}`` for any
    k >= 2 (``path9``, ``star23``, ...); dynamic results are memoized so
    repeated lookups share one object (and its warm plan caches)."""
    if name in STANDARD_TEMPLATES:
        return STANDARD_TEMPLATES[name]
    m = _DYNAMIC_PATTERN.match(name)
    if m and int(m.group(2)) >= 2:
        if name not in _DYNAMIC_CACHE:
            kind, k = m.group(1), int(m.group(2))
            _DYNAMIC_CACHE[name] = (_path if kind == "path" else _star)(k, name)
        return _DYNAMIC_CACHE[name]
    raise KeyError(
        f"unknown template {name!r}; have {sorted(STANDARD_TEMPLATES)} plus "
        "dynamic 'path{k}' / 'star{k}' for any k >= 2 (e.g. 'path6', "
        "'star9'), or submit an arbitrary tree via TemplateSpec")
