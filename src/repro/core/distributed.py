"""Distributed PGBSC over a (pod, data, model) device mesh.

Mapping of the algorithm's parallel axes (DESIGN.md §4):

* vertices  → ``data`` axis. The distributed SpMM is a **ring schedule**:
  each data shard owns a block of destination vertices and the matching
  column block of A_G (grouped by source block); count-table blocks rotate
  around the ring via ``collective_permute`` while each device accumulates
  the contribution of the currently-resident source block — compute and
  communication overlap across ring steps. This realizes the paper's
  future-work §2 (distributed memory) with jax-native collectives.
* color combinations → ``model`` axis. SpMM is embarrassingly parallel over
  combinations (each model shard rings over its own combo rows); the eMA
  all-gathers the (small) child tables over ``model`` once per sub-template,
  then each shard produces its own slice of output color sets.
* color-coding iterations → ``pod`` axis. Each pod runs an independent
  coloring derived from ``fold_in(seed, iteration)``; pods never communicate
  until the final mean. Iterations are the unit of fault tolerance
  (core/runner.py).

Tables are (C, N) sharded P(model, data) with both dims zero-padded to the
mesh multiples; padded combo rows are masked out of the final reduction.
"""

from __future__ import annotations

import dataclasses
from math import comb

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import colorsets as cs
from repro.core import executor as pexec
from repro.core.templates import TreeTemplate, as_template
from repro.graph.structure import Graph

__all__ = ["DistributedPgbsc", "build_ring_edges", "coloring_for_seed"]


def coloring_for_seed(seed, n_pad: int, n_true: int, k: int) -> jnp.ndarray:
    """Global coloring for an iteration seed; padding vertices get an
    out-of-range color so they never contribute. Mesh-shape independent."""
    key = jax.random.fold_in(jax.random.PRNGKey(0), seed)
    colors = jax.random.randint(key, (n_pad,), 0, k, dtype=jnp.int32)
    vid = jnp.arange(n_pad)
    return jnp.where(vid < n_true, colors, k + 1)


def build_ring_edges(g: Graph, n_shards: int,
                     pad_vertices_to: int = 128) -> dict[str, np.ndarray]:
    """Per-(dst shard, src block) padded edge arrays for the ring SpMM.

    Returns arrays of shape (n_shards, n_shards, e_max):
      src_local[d, s] — src offset within block s for edges into dst shard d
      dst_local[d, s] — dst offset within shard d
      mask[d, s]      — 1.0 for real edges
    plus n_pad (the padded vertex count; n_pad % (n_shards*pad_vertices_to)==0).
    """
    block = -(-g.n // (n_shards * pad_vertices_to)) * pad_vertices_to
    n_pad = block * n_shards
    src, dst = g.edges_by_dst
    d_shard = dst // block
    s_block = src // block
    counts = np.zeros((n_shards, n_shards), np.int64)
    np.add.at(counts, (d_shard, s_block), 1)
    e_max = max(int(counts.max()), 1)

    src_local = np.zeros((n_shards, n_shards, e_max), np.int32)
    dst_local = np.zeros((n_shards, n_shards, e_max), np.int32)
    mask = np.zeros((n_shards, n_shards, e_max), np.float32)
    if len(src):
        order = np.lexsort((s_block, d_shard))
        src_s, dst_s = src[order], dst[order]
        ds, ss = d_shard[order], s_block[order]
        # vectorized position-within-group: index minus group start
        key = ds * n_shards + ss
        change = np.r_[True, key[1:] != key[:-1]]
        group_start = np.maximum.accumulate(
            np.where(change, np.arange(len(key)), 0))
        pos = np.arange(len(key)) - group_start
        src_local[ds, ss, pos] = (src_s - ss * block).astype(np.int32)
        dst_local[ds, ss, pos] = (dst_s - ds * block).astype(np.int32)
        mask[ds, ss, pos] = 1.0
    return {
        "src_local": src_local, "dst_local": dst_local, "mask": mask,
        "n_pad": n_pad, "block": block, "e_max": e_max,
    }


@dataclasses.dataclass
class _NodeMeta:
    width: int          # true combo count C(k, t)
    width_pad: int      # padded to model-axis multiple
    ia: np.ndarray | None
    ip: np.ndarray | None
    active: int | None
    passive: int | None


class DistributedPgbsc:
    """PGBSC sharded over a Mesh with ('data', 'model') [+ leading 'pod'].

    ``count_step(seeds)`` is the jit-able unit the launcher lowers: for a
    multi-pod mesh it evaluates one coloring iteration per pod and returns
    the per-pod colorful sums.
    """

    def __init__(self, g: Graph | None, template, mesh: Mesh,
                 *, plan: str = "dedup", abstract_dims: dict | None = None,
                 memory_budget_bytes: int | None = None):
        # registry names / TemplateSpec / edge lists coerce like everywhere
        # else in the query API; TreeTemplate passes through untouched
        template: TreeTemplate = as_template(template)
        self.template = template
        self.k = template.k
        self.mesh = mesh
        self.axes = mesh.axis_names
        assert self.axes[-2:] == ("data", "model"), self.axes
        self.has_pod = len(self.axes) == 3
        self.d_data = mesh.shape["data"]
        self.d_model = mesh.shape["model"]
        self.plan = {"plain": template.plan, "dedup": template.plan_dedup,
                     "optimized": template.plan_optimized}[plan]
        self.abstract = g is None
        self.memory_budget_bytes = memory_budget_bytes
        # same liveness-managed, min-peak-ordered walk as the single-device
        # engines; each freed buffer here is a model/data-sharded table
        self.exec_schedule = pexec.compute_schedule(self.plan, self.k,
                                                    passive_cache=True)

        if g is not None:
            ring = build_ring_edges(g, self.d_data)
            self.n_pad = int(ring["n_pad"])
            self.block = int(ring["block"])
            self.edge_arrays = {k: ring[k]
                                for k in ("src_local", "dst_local", "mask")}
            self.n_true = g.n
        else:
            # dry-run mode: shapes only, nothing built or allocated
            n, e = abstract_dims["n"], abstract_dims["e"]
            block = -(-n // (self.d_data * 128)) * 128
            self.n_pad = block * self.d_data
            self.block = block
            self.n_true = n
            e_max = int(abstract_dims.get(
                "e_max", 1.3 * e / (self.d_data ** 2)) + 1)
            shp = (self.d_data, self.d_data, e_max)
            self.edge_arrays = {
                "src_local": jax.ShapeDtypeStruct(shp, jnp.int32),
                "dst_local": jax.ShapeDtypeStruct(shp, jnp.int32),
                "mask": jax.ShapeDtypeStruct(shp, jnp.float32),
            }

        # per-node metadata + padded split tables
        self.meta: list[_NodeMeta] = []
        for node in self.plan.nodes:
            width = comb(self.k, node.size)
            width_pad = -(-width // self.d_model) * self.d_model
            if node.is_leaf:
                self.meta.append(_NodeMeta(width, width_pad, None, None,
                                           None, None))
            else:
                t_a = self.plan.nodes[node.active].size
                ia, ip = cs.split_tables(self.k, node.size, t_a)
                ia_pad = np.zeros((width_pad, ia.shape[1]), np.int32)
                ip_pad = np.zeros((width_pad, ip.shape[1]), np.int32)
                ia_pad[:width] = ia
                ip_pad[:width] = ip
                self.meta.append(_NodeMeta(width, width_pad, ia_pad, ip_pad,
                                           node.active, node.passive))

    # ---------------------------------------------------------------- local
    def _ring_spmm(self, m_loc: jnp.ndarray, src_l, dst_l, msk) -> jnp.ndarray:
        """m_loc: (C_loc, block) — my combo rows, my vertex block.

        src_l/dst_l/msk: (D, e_max) edge arrays for MY dst shard, indexed by
        the owning source block. Ring: at step s the resident block belongs
        to shard (my + s) % D.
        """
        d = self.d_data
        my = jax.lax.axis_index("data")
        perm = [(i, (i - 1) % d) for i in range(d)]

        # The ring is unrolled (d is static): each step overlaps the permute
        # of the resident block with the local accumulate, and every step's
        # collective/segment-sum cost is visible to HLO cost analysis.
        m_cur, acc = m_loc, jnp.zeros_like(m_loc)
        for step in range(d):
            owner = (my + step) % d
            s = jax.lax.dynamic_index_in_dim(src_l, owner, 0, keepdims=False)
            t = jax.lax.dynamic_index_in_dim(dst_l, owner, 0, keepdims=False)
            w = jax.lax.dynamic_index_in_dim(msk, owner, 0, keepdims=False)
            contrib = m_cur[:, s] * w[None, :]            # (C_loc, e_max)
            acc = acc + jax.ops.segment_sum(
                contrib.T, t, num_segments=self.block).T  # (C_loc, block)
            if step < d - 1:  # rotate; last step has nothing left to feed
                m_cur = jax.lax.ppermute(m_cur, "data", perm)
        return acc

    def _ema_local(self, m_a_full, y_p_full, ia, ip) -> jnp.ndarray:
        # unrolled over the (static, small) split count for HLO-visible cost
        acc = jnp.zeros((ia.shape[0], m_a_full.shape[1]), m_a_full.dtype)
        for l in range(ia.shape[1]):
            acc = acc + m_a_full[ia[:, l], :] * y_p_full[ip[:, l], :]
        return acc

    def _ema_scatter(self, m_a_loc, y_p_full, ia, ip, a_rows: int
                     ) -> jnp.ndarray:
        """eMA without gathering the active child: each model shard computes
        the split-terms whose m_a row it owns (masked local gather), then the
        partial outputs are summed across the model axis and my output slice
        is kept (an all-reduce+slice = reduce-scatter). Cheaper than
        gathering both children when the active table is wider than the
        output (adaptive choice in _count_one; §Perf iteration P3).

        ia/ip here are the FULL padded split tables (S_pad, L).
        """
        my_m = jax.lax.axis_index("model")
        lo = my_m * a_rows
        acc = jnp.zeros((ia.shape[0], y_p_full.shape[1]), y_p_full.dtype)
        for l in range(ia.shape[1]):
            ga = ia[:, l]
            own = (ga >= lo) & (ga < lo + a_rows)
            local_idx = jnp.clip(ga - lo, 0, a_rows - 1)
            term = m_a_loc[local_idx, :] * y_p_full[ip[:, l], :]
            acc = acc + jnp.where(own[:, None], term, 0.0)
        total = jax.lax.psum(acc, "model")          # (S_pad, block)
        s_rows = ia.shape[0] // self.d_model
        return jax.lax.dynamic_slice_in_dim(total, my_m * s_rows, s_rows, 0)

    def _count_one(self, colors_loc: jnp.ndarray, src_l, dst_l, msk,
                   split_tabs: dict) -> jnp.ndarray:
        """Inside shard_map: colors_loc (block,) for my data shard.

        The plan walk itself (order, y-cache, buffer frees) is the shared
        :class:`~repro.core.executor.PlanExecutor`; only the callbacks are
        mesh-aware. Every table is stored model-sharded (my slice of the
        padded combo rows), so each freed buffer releases its slice on all
        model shards at once.
        """
        k = self.k
        my_m = jax.lax.axis_index("model")
        leaf_full = (jnp.arange(k, dtype=jnp.int32)[:, None]
                     == colors_loc[None, :]).astype(jnp.float32)

        def my_slice(full_pad: jnp.ndarray, width_pad: int) -> jnp.ndarray:
            rows = width_pad // self.d_model
            return jax.lax.dynamic_slice_in_dim(full_pad, my_m * rows, rows, 0)

        # all leaves are size-1 sub-templates: same width_pad, same table
        leaf_meta = self.meta[next(
            i for i, nd in enumerate(self.plan.nodes) if nd.is_leaf)]
        pad = jnp.zeros((leaf_meta.width_pad - k, colors_loc.shape[0]),
                        jnp.float32)
        leaf_loc = my_slice(jnp.concatenate([leaf_full, pad], axis=0),
                            leaf_meta.width_pad)

        def passive_op(p_idx, m_p):
            return self._ring_spmm(m_p, src_l, dst_l, msk)

        def combine(idx, m_a_loc, y_p_loc):
            node = self.plan.nodes[idx]
            meta = self.meta[idx]
            ia, ip = split_tabs[idx]
            # adaptive collective choice per node (bytes moved over `model`):
            #  gather-both: move Ca_pad + Cp_pad rows;
            #  scatter-out:  move Cp_pad + S_pad rows (psum of partials).
            a_pad = self.meta[node.active].width_pad
            p_pad = self.meta[node.passive].width_pad
            gather_cost = a_pad + p_pad
            # psum costs ~2x an all-gather of the same rows (ring algebra),
            # unless XLA fuses the trailing slice into a reduce-scatter
            scatter_cost = p_pad + 2 * meta.width_pad
            y_p_full = _allgather_rows(y_p_loc, "model")
            if scatter_cost < gather_cost:
                return self._ema_scatter(m_a_loc, y_p_full, ia, ip,
                                         a_pad // self.d_model)
            m_a_full = _allgather_rows(m_a_loc, "model")
            ia_my = my_slice(ia, meta.width_pad)
            ip_my = my_slice(ip, meta.width_pad)
            return self._ema_local(m_a_full, y_p_full, ia_my, ip_my)

        runner = pexec.PlanExecutor(self.plan, self.exec_schedule)
        root = runner.run(leaf_loc, passive_op=passive_op, combine=combine)
        root_meta = self.meta[-1]
        rows = root_meta.width_pad // self.d_model
        row_ids = my_m * rows + jnp.arange(rows)
        row_mask = (row_ids < root_meta.width).astype(root.dtype)
        local = (root * row_mask[:, None]).sum()
        total = jax.lax.psum(jax.lax.psum(local, "data"), "model")
        return total

    # ------------------------------------------------------------------ api
    def count_step_fn(self):
        """Returns (step_fn, input_arrays, in_shardings) for jit/lower.

        step_fn(seeds, src_l, dst_l, msk) -> per-pod colorful sums (or scalar
        for a single-pod mesh). ``seeds`` is int32 (n_pods,) [or (1,)].
        """
        from jax.experimental.shard_map import shard_map

        split_tabs = {
            i: (jnp.asarray(m.ia), jnp.asarray(m.ip))
            for i, m in enumerate(self.meta) if m.ia is not None
        }
        n_pods = self.mesh.shape["pod"] if self.has_pod else 1

        # edge arrays: shard dst-shard dim over data; replicated over
        # pod/model (axes unmentioned in the spec are replicated).
        edge_spec = P("data", None, None)

        def per_pod_count(seed, src_l, dst_l, msk):
            # seed: scalar int32. The coloring is derived *globally* (then
            # sliced per shard) so results are identical across mesh shapes —
            # the basis for elastic-restart determinism.
            colors_full = coloring_for_seed(seed, self.n_pad, self.n_true,
                                            self.k)
            my_d = jax.lax.axis_index("data")
            colors_loc = jax.lax.dynamic_slice_in_dim(
                colors_full, my_d * self.block, self.block)
            return self._count_one(colors_loc, src_l, dst_l, msk, split_tabs)

        def local_step(seeds, src_l, dst_l, msk):
            # inside shard_map: seeds (1,); edge arrays (1, D, e_max)
            total = per_pod_count(seeds[0], src_l[0], dst_l[0], msk[0])
            return jnp.reshape(total, (1,))

        in_specs = (
            P("pod") if self.has_pod else P(None),
            edge_spec, edge_spec, edge_spec,
        )
        out_specs = P("pod") if self.has_pod else P(None)

        step = shard_map(
            local_step, mesh=self.mesh, in_specs=in_specs,
            out_specs=out_specs, check_rep=False,
        )

        if self.abstract:
            src_l = self.edge_arrays["src_local"]
            dst_l = self.edge_arrays["dst_local"]
            msk = self.edge_arrays["mask"]
            seeds = jax.ShapeDtypeStruct((n_pods,), jnp.int32)
        else:
            src_l = jnp.asarray(self.edge_arrays["src_local"])
            dst_l = jnp.asarray(self.edge_arrays["dst_local"])
            msk = jnp.asarray(self.edge_arrays["mask"])
            seeds = jnp.arange(n_pods, dtype=jnp.int32)

        shardings = tuple(NamedSharding(self.mesh, s) for s in in_specs)
        return step, (seeds, src_l, dst_l, msk), shardings

    def _multi_step(self):
        """jit of N pod-rounds scanned inside one device call.

        Built once per DistributedPgbsc (the jit re-traces per distinct
        seed_mat shape but the device-resident edge arrays and the wrapper
        are shared): fn(seed_mat (bs, n_pods), *edges) -> (bs, n_pods)
        colorful sums.
        """
        if not hasattr(self, "_multi"):
            step, (_, src_l, dst_l, msk), _ = self.count_step_fn()

            def multi(seed_mat, a, b, c):
                def body(carry, seeds_row):
                    return carry, step(seeds_row, a, b, c)

                _, outs = jax.lax.scan(body, None, seed_mat)
                return outs  # (bs, n_pods)

            self._multi = (jax.jit(multi), (src_l, dst_l, msk))
        return self._multi

    def default_pod_batch(self) -> int:
        """Budget-derived pod rounds per device call.

        Scanned rounds reuse buffers, so live memory does not grow with the
        round count — but XLA may double-buffer the scan and larger calls
        raise the blast radius of a preemption (the runner loses at most one
        call's work). With a ``memory_budget_bytes`` the rounds scale with
        the headroom over one iteration's modeled per-device peak; without
        one, the historical default of 8 is kept.
        """
        if self.memory_budget_bytes is None:
            return 8
        shards = self.d_data * self.d_model
        per_iter = pexec.simulate_peak_rows(
            self.plan, self.k, self.exec_schedule) * self.n_pad * 4 // shards
        return int(max(1, min(32, self.memory_budget_bytes
                              // max(per_iter, 1))))

    def count_iterations(self, iterations: list[int], seed: int = 0,
                         batch_size: int | None = None) -> tuple[float, dict]:
        """Sum of colorful counts over explicit iteration ids (for the
        fault-tolerant runner; single-process execution on whatever mesh).

        Per-pod work is batched: each device call evaluates up to
        ``batch_size`` coloring iterations per pod (a ``lax.scan`` over pod
        rounds inside the jit), so a checkpoint batch of
        ``batch_size * n_pods`` iterations is one dispatch. ``None`` derives
        the knob from ``memory_budget_bytes`` (:meth:`default_pod_batch`).
        Ragged tails are padded with the last iteration id and discarded;
        per-iteration values are independent of the grouping, preserving
        elastic-restart determinism across mesh shapes AND batch sizes.
        """
        if batch_size is None:
            batch_size = self.default_pod_batch()
        n_pods = self.mesh.shape["pod"] if self.has_pod else 1
        # clamped to the pod-rounds actually needed: lax.scan serializes the
        # rounds, so padding a short checkpoint batch up to the knob would
        # multiply device compute for nothing; one compiled shape per
        # distinct call length is the cheaper side of the tradeoff
        bs = max(1, min(batch_size, -(-len(iterations) // n_pods)))
        multi, (src_l, dst_l, msk) = self._multi_step()
        group = bs * n_pods
        total = 0.0
        per_iter = {}
        for base in range(0, len(iterations), group):
            batch = iterations[base: base + group]
            padded = batch + [batch[-1]] * (group - len(batch))
            seed_mat = jnp.asarray(
                [seed * 1_000_003 + it for it in padded],
                jnp.int32).reshape(bs, n_pods)
            with self.mesh:
                out = np.asarray(multi(seed_mat, src_l, dst_l, msk)
                                 ).reshape(-1)
            for i, it in enumerate(batch):
                per_iter[it] = float(out[i])
                total += float(out[i])
        return total, per_iter


def _allgather_rows(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    g = jax.lax.all_gather(x, axis, axis=0)     # (D, rows, n_loc)
    return g.reshape(-1, x.shape[-1])
