"""Brute-force oracles for correctness validation (host-side, small graphs).

Two ground truths:

* ``count_embeddings`` — the number of injective edge-preserving maps of the
  template T into G ("labeled embeddings"). The number of *subgraphs of G
  isomorphic to T* is this divided by aut(T).
* ``count_colorful_embeddings`` — labeled embeddings whose image vertices all
  have distinct colors under a fixed coloring. This equals
  ``sum_v sum_C M_0`` produced by the DP for the same coloring, exactly.
"""

from __future__ import annotations

import numpy as np

from repro.core.templates import TreeTemplate
from repro.graph.structure import Graph

__all__ = [
    "count_embeddings",
    "count_colorful_embeddings",
    "count_subgraphs_exact",
]


def _embed(g: Graph, t: TreeTemplate, accept) -> int:
    """Count injective homomorphisms T -> G, filtered by ``accept(mapping)``.

    Template vertices are assigned in BFS order from the template root so each
    newly placed vertex has exactly one already-placed neighbor (tree).
    """
    order = [t.root]
    parent = {t.root: -1}
    for v in order:
        for u in t.adjacency(v):
            if u not in parent:
                parent[u] = v
                order.append(u)
    assert len(order) == t.k

    count = 0
    mapping = np.full(t.k, -1, dtype=np.int64)
    used = np.zeros(g.n, dtype=bool)

    def rec(pos: int) -> None:
        nonlocal count
        if pos == t.k:
            count += 1 if accept(mapping) else 0
            return
        tv = order[pos]
        if parent[tv] < 0:
            candidates = range(g.n)
        else:
            candidates = g.neighbors(int(mapping[parent[tv]]))
        for gv in candidates:
            gv = int(gv)
            if not used[gv]:
                used[gv] = True
                mapping[tv] = gv
                rec(pos + 1)
                used[gv] = False
                mapping[tv] = -1

    rec(0)
    return count


def count_embeddings(g: Graph, t: TreeTemplate) -> int:
    return _embed(g, t, lambda m: True)


def count_colorful_embeddings(g: Graph, t: TreeTemplate, colors: np.ndarray) -> int:
    colors = np.asarray(colors)

    def accept(mapping):
        cs = colors[mapping]
        return len(set(cs.tolist())) == t.k

    return _embed(g, t, accept)


def count_subgraphs_exact(g: Graph, t: TreeTemplate) -> float:
    """Exact number of subgraphs of G isomorphic to T."""
    return count_embeddings(g, t) / t.automorphisms
