"""PGBSC core: color-coding tree subgraph counting via GraphBLAS kernels."""

from repro.core.automorphism import tree_automorphisms
from repro.core.colorsets import (all_colorsets, colorful_probability,
                                  rank_colorset, split_tables,
                                  unrank_colorset)
from repro.core.engines import ENGINES, CountingEngine, build_engine
from repro.core.executor import (PlanExecutor, Schedule, compute_schedule,
                                 keep_everything_bytes, peak_table_bytes,
                                 pick_execution)
from repro.core.oracle import (count_colorful_embeddings, count_embeddings,
                               count_subgraphs_exact)
from repro.core.templates import (STANDARD_TEMPLATES, ExecutionPlan,
                                  FusedPlan, PlanNode, TemplateSpec,
                                  TreeTemplate, as_template,
                                  compile_fused_plan, get_template)

__all__ = [
    "tree_automorphisms",
    "all_colorsets", "colorful_probability", "rank_colorset",
    "split_tables", "unrank_colorset",
    "ENGINES", "CountingEngine", "build_engine",
    "PlanExecutor", "Schedule", "compute_schedule",
    "keep_everything_bytes", "peak_table_bytes", "pick_execution",
    "count_colorful_embeddings", "count_embeddings", "count_subgraphs_exact",
    "STANDARD_TEMPLATES", "ExecutionPlan", "PlanNode", "TreeTemplate",
    "TemplateSpec", "FusedPlan", "as_template", "compile_fused_plan",
    "get_template",
]
