"""Per-vertex motif-count features (GSN-style) from the PGBSC engine.

The root table M_0 of the DP holds, per vertex v, the number of colorful
embeddings rooted at v. Averaged over iterations and rescaled by 1/(P·alpha)
this estimates the number of template copies touching v at the root — a
structural feature vector usable by downstream GNNs (Graph Substructure
Networks; Bouritsas et al.). This is the integration point between the
paper's engine and the assigned GNN architectures.

Since the query-API redesign the template list runs as ONE fused-plan
engine per template size k (not a per-template engine loop): same-k
templates share a coloring stream, and canonical rooted sub-templates they
have in common — every star/path arm of a motif dictionary overlaps — are
computed once per coloring for the whole group, with every template's root
table a kept output of the same plan walk. Feature values are unchanged
(same colorings, same DP) up to floating-point reassociation.
"""

from __future__ import annotations

import numpy as np

from repro.core.colorsets import colorful_probability
from repro.core.engines import CountingEngine
from repro.core.templates import TemplateSpec
from repro.graph.coloring import iteration_key, random_coloring
from repro.graph.structure import Graph

__all__ = ["motif_features"]


def motif_features(g: Graph, templates: list, n_iters: int = 8, seed: int = 0,
                   engine: str = "pgbsc", log1p: bool = True) -> np.ndarray:
    """(n, len(templates)) float32 matrix of per-vertex motif count estimates.

    ``templates`` accepts registry names, :class:`TemplateSpec`,
    TreeTemplate objects, or raw edge lists, in any mix.
    """
    specs = [TemplateSpec.of(t) for t in templates]
    by_k: dict[int, list[int]] = {}
    for i, s in enumerate(specs):
        by_k.setdefault(s.k, []).append(i)

    feats: list[np.ndarray | None] = [None] * len(specs)
    for k, idxs in sorted(by_k.items()):
        trees = [specs[i].tree for i in idxs]
        eng = CountingEngine(g, trees if len(trees) > 1 else trees[0],
                             engine=engine, dedup=True)
        p = colorful_probability(k)
        acc = np.zeros((len(idxs), g.n), np.float64)
        for it in range(n_iters):
            colors = random_coloring(iteration_key(seed, it), g.n, k)
            _, roots = eng.count_colorful(colors)
            if not eng.fused:
                roots = (roots,)
            for j, root in enumerate(roots):
                acc[j] += np.asarray(root).sum(axis=0)
        for j, i in enumerate(idxs):
            feats[i] = acc[j] / n_iters / (p * trees[j].automorphisms)
    out = np.stack(feats, axis=1).astype(np.float32)
    return np.log1p(out) if log1p else out
