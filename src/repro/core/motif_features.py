"""Per-vertex motif-count features (GSN-style) from the PGBSC engine.

The root table M_0 of the DP holds, per vertex v, the number of colorful
embeddings rooted at v. Averaged over iterations and rescaled by 1/(P·alpha)
this estimates the number of template copies touching v at the root — a
structural feature vector usable by downstream GNNs (Graph Substructure
Networks; Bouritsas et al.). This is the integration point between the
paper's engine and the assigned GNN architectures.
"""

from __future__ import annotations

import numpy as np

from repro.core.colorsets import colorful_probability
from repro.core.engines import CountingEngine
from repro.core.templates import TreeTemplate, get_template
from repro.graph.coloring import iteration_key, random_coloring
from repro.graph.structure import Graph

__all__ = ["motif_features"]


def motif_features(g: Graph, templates: list[str | TreeTemplate],
                   n_iters: int = 8, seed: int = 0,
                   engine: str = "pgbsc", log1p: bool = True) -> np.ndarray:
    """(n, len(templates)) float32 matrix of per-vertex motif count estimates."""
    feats = []
    for tpl in templates:
        t = get_template(tpl) if isinstance(tpl, str) else tpl
        eng = CountingEngine(g, t, engine=engine, dedup=True)
        p = colorful_probability(t.k)
        acc = np.zeros(g.n, np.float64)
        for it in range(n_iters):
            key = iteration_key(seed, it)
            colors = random_coloring(key, g.n, t.k)
            _, root = eng.count_colorful(colors)
            acc += np.asarray(root).sum(axis=0)
        est = acc / n_iters / (p * t.automorphisms)
        feats.append(est)
    out = np.stack(feats, axis=1).astype(np.float32)
    return np.log1p(out) if log1p else out
