"""The three counting engines: FASCIA, PFASCIA, PGBSC (paper §3-4).

All three compute the same quantity — the number of colorful rooted
embeddings of each sub-template, bottom-up over the execution plan — but with
the paper's three performance regimes:

* ``fascia``   Algorithm 1: vertex-centric; the neighbor sum of the passive
               child is recomputed for every (color set, split) pair —
               O(E * C(k,t) * C(t,t_p)) per sub-template. Row-major (N, C)
               tables, padded-neighbor (ELL) traversal.
* ``pfascia``  + pruning (§4.1-4.2): neighbor sums hoisted out and computed
               once per distinct passive color set —
               O(E * C(k,t_p) + V * C(k,t) * C(t,t_a)). Still row-major.
* ``pgbsc``    + GraphBLAS (§4.3-4.5): combination-major (C, N) tables
               (vertices on TPU lanes), SpMM = A_G x M_p batched over all
               passive color sets, eMA fused multiply-add — optionally via
               the Pallas TPU kernels.

Exact arithmetic would make them identical (paper §7.4); floating-point
reassociation yields ~1e-6 relative differences, which the tests bound.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import colorsets as cs
from repro.core.templates import ExecutionPlan, TreeTemplate
from repro.graph.structure import Graph
from repro.kernels.ema import ops as ema_ops
from repro.kernels.spmm import ops as spmm_ops

__all__ = ["CountingEngine", "build_engine", "ENGINES"]

ENGINES = ("fascia", "pfascia", "pgbsc")


@dataclasses.dataclass
class WorkEstimate:
    """Static op counts per engine run (used by benchmarks / roofline)."""

    spmm_flops: int = 0
    ema_flops: int = 0
    table_bytes: int = 0

    @property
    def total_flops(self) -> int:
        return self.spmm_flops + self.ema_flops


class CountingEngine:
    """Counts colorful embeddings of a template for a given coloring.

    Call :meth:`count_colorful` with an (n,) int32 coloring; returns the
    scalar sum over the root table (= alpha x #colorful copies) and the root
    table itself. :meth:`estimate` runs the full color-coding estimator.
    """

    def __init__(self, g: Graph, template: TreeTemplate, engine: str = "pgbsc",
                 spmm_method: str = "segment", use_pallas_ema: bool = False,
                 interpret: bool = True, dedup: bool = False,
                 plan: str | None = None, dtype=jnp.float32):
        if engine not in ENGINES:
            raise ValueError(f"unknown engine {engine!r}")
        self.g = g
        self.template = template
        self.engine = engine
        self.k = template.k
        self.dtype = dtype
        plan_name = plan or ("dedup" if dedup else "plain")
        self.plan: ExecutionPlan = {
            "plain": template.plan, "dedup": template.plan_dedup,
            "optimized": template.plan_optimized}[plan_name]
        self.use_pallas_ema = use_pallas_ema
        self.interpret = interpret

        if engine == "pgbsc":
            self._spmm_prep = spmm_ops.prepare(g, spmm_method,
                                               interpret=interpret)
        else:
            nbr, mask = g.ell()
            self._nbr = jnp.asarray(nbr)
            self._mask = jnp.asarray(mask)

        # Static split tables per internal plan node.
        self._splits: dict[int, tuple[jnp.ndarray, jnp.ndarray]] = {}
        for idx, node in enumerate(self.plan.nodes):
            if node.is_leaf:
                continue
            t = node.size
            t_a = self.plan.nodes[node.active].size
            ia, ip = cs.split_tables(self.k, t, t_a)
            self._splits[idx] = (jnp.asarray(ia), jnp.asarray(ip))

        self.work = self._estimate_work()
        self._count_fn = jax.jit(self._build())

    # ------------------------------------------------------------------ api
    def count_colorful(self, colors: jax.Array) -> tuple[jax.Array, jax.Array]:
        """-> (sum over root table, root table)."""
        return self._count_fn(jnp.asarray(colors))

    def estimate(self, n_iters: int, seed: int = 0,
                 start_iteration: int = 0) -> dict:
        """Color-coding estimate averaged over ``n_iters`` colorings."""
        from repro.graph.coloring import iteration_key, random_coloring

        alpha = self.template.automorphisms
        p = cs.colorful_probability(self.k)
        samples = []
        for it in range(start_iteration, start_iteration + n_iters):
            key = iteration_key(seed, it)
            colors = random_coloring(key, self.g.n, self.k)
            total, _ = self.count_colorful(colors)
            samples.append(float(total) / (alpha * p))
        arr = np.asarray(samples)
        return {
            "count": float(arr.mean()),
            "std": float(arr.std(ddof=1)) if len(arr) > 1 else 0.0,
            "samples": samples,
            "n_iters": n_iters,
            "alpha": alpha,
            "colorful_probability": p,
        }

    # ------------------------------------------------------------- builders
    def _build(self) -> Callable:
        if self.engine == "pgbsc":
            return self._build_pgbsc()
        return self._build_rowmajor(pruned=self.engine == "pfascia")

    def _leaf_table_cn(self, colors: jax.Array) -> jnp.ndarray:
        """(k, N) one-hot of vertex colors — combination-major leaves."""
        return (jnp.arange(self.k, dtype=colors.dtype)[:, None]
                == colors[None, :]).astype(self.dtype)

    def _build_pgbsc(self) -> Callable:
        plan, splits, prep = self.plan, self._splits, self._spmm_prep

        def run(colors: jax.Array):
            leaf = self._leaf_table_cn(colors)
            tables: list[jnp.ndarray | None] = [None] * plan.n_nodes
            y_cache: dict[int, jnp.ndarray] = {}
            for idx, node in enumerate(plan.nodes):
                if node.is_leaf:
                    tables[idx] = leaf
                    continue
                ia, ip = splits[idx]
                # SpMM over *all* passive color sets at once (Algorithm 4 l.3);
                # with plan dedup, shared passive children reuse the result.
                if node.passive not in y_cache:
                    y_cache[node.passive] = spmm_ops.spmm(
                        tables[node.passive], prep
                    )
                y_p = y_cache[node.passive]
                m_a = tables[node.active]
                tables[idx] = ema_ops.ema(
                    m_a, y_p, ia, ip,
                    use_pallas=self.use_pallas_ema, interpret=self.interpret,
                )
            root = tables[-1]
            return root.sum(), root

        return run

    def _build_rowmajor(self, pruned: bool) -> Callable:
        """FASCIA / PFASCIA: row-major (N, C) tables + ELL traversal."""
        plan, splits = self.plan, self._splits
        nbr, mask = self._nbr, self._mask

        def nbr_sum(m_cols: jnp.ndarray) -> jnp.ndarray:
            # m_cols: (N, R) -> out[i, r] = sum_d m_cols[nbr[i, d], r] * mask
            def body(acc, nd):
                col_ids, msk = nd
                return acc + m_cols[col_ids, :] * msk[:, None], None

            acc0 = jnp.zeros_like(m_cols)
            acc, _ = jax.lax.scan(body, acc0, (nbr.T, mask.T))
            return acc

        def run(colors: jax.Array):
            leaf = self._leaf_table_cn(colors).T  # (N, k)
            tables: list[jnp.ndarray | None] = [None] * plan.n_nodes
            for idx, node in enumerate(plan.nodes):
                if node.is_leaf:
                    tables[idx] = leaf
                    continue
                ia, ip = splits[idx]
                m_a, m_p = tables[node.active], tables[node.passive]
                if pruned:
                    # PFASCIA: one neighbor sweep per distinct passive set.
                    y_p = nbr_sum(m_p)

                    def body(acc, idx_l):
                        ia_l, ip_l = idx_l
                        return acc + m_a[:, ia_l] * y_p[:, ip_l], None

                    acc0 = jnp.zeros((m_a.shape[0], ia.shape[0]), self.dtype)
                    acc, _ = jax.lax.scan(body, acc0, (ia.T, ip.T))
                    tables[idx] = acc
                else:
                    # FASCIA: the neighbor sweep is *inside* the split loop —
                    # the redundancy of paper §3.1, preserved deliberately.
                    def body(acc, idx_l):
                        ia_l, ip_l = idx_l
                        y_l = nbr_sum(m_p[:, ip_l])   # (N, S) sweep per split
                        return acc + m_a[:, ia_l] * y_l, None

                    acc0 = jnp.zeros((m_a.shape[0], ia.shape[0]), self.dtype)
                    acc, _ = jax.lax.scan(body, acc0, (ia.T, ip.T))
                    tables[idx] = acc
            root = tables[-1]
            return root.sum(), root

        return run

    # ------------------------------------------------------------- analysis
    @property
    def flops_per_iteration(self) -> int:
        return self.work.total_flops

    def _estimate_work(self) -> WorkEstimate:
        from math import comb
        w = WorkEstimate()
        n, e, k = self.g.n, self.g.m, self.k
        for idx, node in enumerate(self.plan.nodes):
            if node.is_leaf:
                continue
            t = node.size
            t_a = self.plan.nodes[node.active].size
            t_p = t - t_a
            n_sets, n_splits = comb(k, t), comb(t, t_a)
            if self.engine == "fascia":
                w.spmm_flops += e * n_sets * n_splits
            else:
                w.spmm_flops += e * comb(k, t_p)
            w.ema_flops += 2 * n * n_sets * n_splits
            w.table_bytes += 4 * n * n_sets
        return w


def build_engine(g: Graph, template: TreeTemplate, engine: str = "pgbsc",
                 **kw) -> CountingEngine:
    """Convenience constructor (see CountingEngine)."""
    return CountingEngine(g, template, engine=engine, **kw)
