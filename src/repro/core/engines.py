"""The three counting engines: FASCIA, PFASCIA, PGBSC (paper §3-4).

All three compute the same quantity — the number of colorful rooted
embeddings of each sub-template, bottom-up over the execution plan — but with
the paper's three performance regimes:

* ``fascia``   Algorithm 1: vertex-centric; the neighbor sum of the passive
               child is recomputed for every (color set, split) pair —
               O(E * C(k,t) * C(t,t_p)) per sub-template. Row-major (N, C)
               tables, padded-neighbor (ELL) traversal.
* ``pfascia``  + pruning (§4.1-4.2): neighbor sums hoisted out and computed
               once per distinct passive color set —
               O(E * C(k,t_p) + V * C(k,t) * C(t,t_a)). Still row-major.
* ``pgbsc``    + GraphBLAS (§4.3-4.5): combination-major (C, N) tables
               (vertices on TPU lanes), SpMM = A_G x M_p batched over all
               passive color sets, eMA fused multiply-add — optionally via
               the Pallas TPU kernels.

Exact arithmetic would make them identical (paper §7.4); floating-point
reassociation yields ~1e-6 relative differences, which the tests bound.

All three engines execute their plan through the shared
:class:`repro.core.executor.PlanExecutor`: one liveness-managed,
min-peak-scheduled bottom-up walk, parameterized only by the passive
transform (SpMM vs. hoisted neighbor sum vs. none) and the combine step.
"""

from __future__ import annotations

import dataclasses
from math import comb
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import colorsets as cs
from repro.core import executor as pexec
from repro.core.templates import (ExecutionPlan, as_template,
                                  compile_fused_plan)
from repro.graph.reorder import ORDERINGS, apply_order, inverse_order
from repro.graph.structure import Graph
from repro.kernels.ema import ops as ema_ops
from repro.kernels.fused import ops as fused_ops
from repro.kernels.spmm import ops as spmm_ops
from repro.obs import metrics as _metrics
from repro.obs import tracing as _tracing

__all__ = ["CountingEngine", "build_engine", "ENGINES"]

ENGINES = ("fascia", "pfascia", "pgbsc")


@dataclasses.dataclass
class WorkEstimate:
    """Static op counts for ONE coloring (used by benchmarks/roofline).

    All per-coloring fields share units, so flops/bytes ratios are valid
    arithmetic intensities. ``table_bytes`` is dtype-aware (C(k,t) x N x
    itemsize summed over internal plan nodes); ``batch`` records the
    engine's dispatch batch size, and the ``dispatch_*`` properties give
    the per-device-call totals.
    """

    spmm_flops: int = 0
    ema_flops: int = 0
    table_bytes: int = 0
    batch: int = 1

    @property
    def total_flops(self) -> int:
        return self.spmm_flops + self.ema_flops

    @property
    def dispatch_flops(self) -> int:
        return self.total_flops * self.batch

    @property
    def dispatch_table_bytes(self) -> int:
        return self.table_bytes * self.batch


class CountingEngine:
    """Counts colorful embeddings of one template — or a fused bundle of
    same-k templates — for a given coloring.

    Call :meth:`count_colorful` with an (n,) int32 coloring; returns the
    scalar sum over the root table (= alpha x #colorful copies) and the root
    table itself. :meth:`estimate` runs the full color-coding estimator.

    Multi-template fusion
    ---------------------
    Passing a list/tuple of equal-k templates builds ONE fused
    :class:`~repro.core.templates.FusedPlan`: canonical rooted sub-templates
    shared across the bundle are computed once per coloring (tables and
    their passive SpMMs alike), every template's root table is a kept output
    of the same walk, and the totals come back as a ``(T,)`` vector (or
    ``(B, T)`` batched). ``n_spmm_cols_dispatched`` counts the SpMM
    column-ops actually dispatched, so the cross-template savings are
    directly observable against a per-template engine sum.

    Memory management
    -----------------
    Plan execution is scheduled by ``core/executor.py``: node tables and
    cached SpMM results are freed at their statically computed last use and
    the bottom-up walk is ordered to minimize the peak live table bytes.
    A single ``memory_budget_bytes`` knob (default
    ``executor.DEFAULT_MEMORY_BUDGET_BYTES``) is turned into the coloring
    ``batch_size`` by the analytic memory model; when even one coloring
    exceeds the budget (large k), the pgbsc SpMM/eMA switch to
    colorset-chunked execution that splits the ``C(k, t_p)`` passive axis
    so the neighbor-sum table is never materialized whole. Pass
    ``batch_size`` explicitly to override the derived batch.

    Batching
    --------
    Color-coding iterations are independent, so the execution plan admits a
    batch dimension over colorings. :meth:`count_colorful_batch` takes a
    (B, n) batch and runs the whole plan as ONE jitted device call: for
    ``pgbsc`` the count tables become (B, C, N) and the SpMM/eMA kernels fold
    the batch into their row dimension (one kernel launch per plan node for
    the whole batch); for ``fascia``/``pfascia`` the single-coloring program
    is ``vmap``-ed. :meth:`count_iterations_batch` goes further and derives
    the colorings device-side from ``fold_in(seed, iteration)`` *inside* the
    jit, so an estimator checkpoint batch is a single dispatch with no
    host->device coloring transfers.

    ``batch_size`` bounds peak memory: a batch of B colorings holds, per live
    plan node of size t, a ``B x C(k, t) x N`` table (plus one SpMM output
    of the same shape), so chunks of ``batch_size`` colorings are
    dispatched at a time and ragged tails are padded to keep one compiled
    program shape. Batched results match the per-coloring path to ~1e-6
    relative error (floating-point reassociation only).
    """

    def __init__(self, g: Graph, template, engine: str = "pgbsc",
                 spmm_method: str = "segment", use_pallas_ema: bool = False,
                 interpret: bool = True, dedup: bool = False,
                 plan: str | None = None, dtype=jnp.float32,
                 batch_size: int | None = None,
                 memory_budget_bytes: int | None = None,
                 fuse_spmm_ema: bool = False,
                 autotune_blocks: bool = False,
                 reorder: str | None = None):
        if engine not in ENGINES:
            raise ValueError(f"unknown engine {engine!r}")
        if reorder not in (None, "", *ORDERINGS):
            raise ValueError(f"unknown reorder {reorder!r}; "
                             f"choose from {sorted(ORDERINGS)} or None")
        if isinstance(template, (list, tuple)):
            if not template:
                raise ValueError("engine needs at least one template")
            templates = tuple(as_template(t) for t in template)
        else:
            templates = (as_template(template),)
        ks = sorted({t.k for t in templates})
        if len(ks) != 1:
            raise ValueError(
                f"one engine fuses equal-k templates only, got k={ks}; "
                "group by k first (repro.api.count_many does)")
        # Vertex reordering: permute the graph ONCE here; the entire plan
        # walk runs in the permuted vertex space, and only the engine
        # boundary permutes (colorings in, root tables out) — see
        # _wrap_reorder. Block-count/density before vs after are published
        # as gauges so the locality win is observable per graph.
        self.reorder = reorder or None
        self.g_orig = g
        if self.reorder:
            before = g.bsr_block_stats()
            self._order = ORDERINGS[self.reorder](g)
            g = apply_order(g, self._order)
            after = g.bsr_block_stats()
            for stage, stats in (("before", before), ("after", after)):
                _metrics.gauge("reorder_bsr_occupied_blocks",
                               reorder=self.reorder, stage=stage
                               ).set(stats["occupied_blocks"])
                _metrics.gauge("reorder_bsr_block_density",
                               reorder=self.reorder, stage=stage
                               ).set(stats["block_density"])
        else:
            self._order = None
        self.g = g
        self.templates = templates
        self.template = templates[0]
        self.fused = len(templates) > 1
        self.engine = engine
        self.k = ks[0]
        self.dtype = dtype
        self.spmm_method = spmm_method
        self.memory_budget_bytes = memory_budget_bytes
        plan_name = plan or ("dedup" if dedup else "plain")
        if self.fused:
            if plan_name == "plain":
                raise ValueError(
                    "plan='plain' is meaningless for a fused multi-template "
                    "engine: cross-template fusion IS canonical dedup; use "
                    "plan='dedup' or plan='optimized'")
            # cross-template canonical dedup: one plan, one root per template
            fp = compile_fused_plan(templates,
                                    optimize=(plan_name == "optimized"))
            self.plan: ExecutionPlan = fp.plan
            self.roots: tuple[int, ...] = fp.roots
        else:
            self.plan = {
                "plain": self.template.plan, "dedup": self.template.plan_dedup,
                "optimized": self.template.plan_optimized}[plan_name]
            self.roots = (self.plan.n_nodes - 1,)
        self.use_pallas_ema = use_pallas_ema
        self.interpret = interpret
        self.autotune_blocks = autotune_blocks
        self.fuse_spmm_ema = bool(fuse_spmm_ema and engine == "pgbsc")
        # per-node fusion decisions (idx -> "admitted" | "admitted_shared" |
        # rejection reason); empty when fusion was not requested
        self.fusion_report: dict[int, str] = {}
        fused_nodes, fused_groups = (self._fused_candidates()
                                     if self.fuse_spmm_ema else ((), ()))

        # budget -> (derived batch size, liveness schedule, chunking); an
        # explicit batch_size only overrides the batch, not the schedule.
        # Every fused root is a kept output (never freed by the walk).
        keep = tuple(i for i in self.roots if i != self.plan.n_nodes - 1)
        self.exec_choice = pexec.pick_execution(
            self.plan, self.k, g.n,
            memory_budget_bytes=memory_budget_bytes, dtype=dtype,
            passive_cache=(engine != "fascia"),
            allow_chunking=(engine == "pgbsc"), keep=keep,
            fused=fused_nodes, fused_groups=fused_groups)
        self.schedule = self.exec_choice.schedule
        self.batch_size = int(batch_size if batch_size is not None
                              else self.exec_choice.batch_size)

        self._materialize()
        self.work = self._estimate_work()
        self.spmm_cols_per_coloring = self._spmm_cols_per_coloring()
        # dispatch accounting (service/benchmark introspection): device calls
        # through the batched pipeline, coloring rows computed by them
        # (padding rows included — they are real device work), and SpMM
        # column-ops those colorings cost (the fused-plan savings metric)
        self.n_batch_dispatches = 0
        self.n_colorings_dispatched = 0
        self.n_spmm_cols_dispatched = 0

    def _fused_candidates(self) -> tuple[tuple[int, ...],
                                         tuple[tuple[int, ...], ...]]:
        """Plan nodes eligible for the fused SpMM->eMA kernel, plus the
        shared-passive groups among them — returns ``(fused, groups)``.

        A sole consumer of its passive child fuses alone when (a) its
        resident tables fit one VMEM grid step and (b) the table dtype runs
        on the kernel path in this mode (otherwise the explicit XLA fallback
        would materialize y and the memory model would lie).

        Consumers SHARING a passive child fuse as a group: one launch whose
        SpMM leg runs once into shared VMEM scratch (the y-cache's dedup win
        without the HBM round-trip). A group is admitted only when it covers
        the passive's ENTIRE consumer set — partial groups would re-run the
        SpMM for the leftovers, regressing the once-per-child column count
        the y-cache guarantees — and only when it can actually run as one
        launch: no member's active child is itself a member (the launch
        cannot consume its own outputs), every member fits a singleton grid
        step, the combined working set passes the group VMEM fit, and the
        members can be made consecutive in program order (no outside
        consumer of a member sits at or before the latest member). The
        chain-shaped consumer sets of path-like templates fail the
        intra-dependency test by construction and stay on the y-cache; the
        win case is template ROOTS sharing a canonical passive sub-template
        (they have no consumers at all).

        Every decision lands in :attr:`fusion_report` (``{plan node idx:
        "admitted" | "admitted_shared" | rejection reason}``) and in the
        reason-labeled ``fusion_admissions_total`` counters, so a user
        asking for fusion can see exactly which nodes got it and why the
        rest did not.
        """
        dtype_ok = ema_ops.pallas_supports_dtype(self.dtype, self.interpret)
        consumers: dict[int, list[int]] = {}
        cons_any: dict[int, list[int]] = {}
        for idx, node in enumerate(self.plan.nodes):
            if node.is_leaf:
                continue
            consumers.setdefault(node.passive, []).append(idx)
            cons_any.setdefault(node.active, []).append(idx)
            cons_any.setdefault(node.passive, []).append(idx)

        def dims(idx: int) -> tuple[int, int, int, int]:
            node = self.plan.nodes[idx]
            t = node.size
            t_a = self.plan.nodes[node.active].size
            return (comb(self.k, t_a), comb(self.k, t - t_a),
                    comb(self.k, t), comb(t, t_a))

        def solo_fits(idx: int) -> bool:
            c_a, c_p, s, l = dims(idx)
            return fused_ops.fused_fits_vmem(c_a, c_p, s, l=l,
                                             dtype=self.dtype)

        def group_fits(members: list[int]) -> bool:
            c_p = dims(members[0])[1]
            c_as = [dims(m)[0] for m in members]
            ss = [dims(m)[2] for m in members]
            ls = [dims(m)[3] for m in members]
            return fused_ops.fused_group_fits_vmem(c_as, c_p, ss, ls,
                                                   dtype=self.dtype)

        def order_ok(members: list[int]) -> bool:
            # regrouping moves members to the LAST member's slot; any
            # outside consumer of a member scheduled at or before that slot
            # would then precede its producer
            anchor = max(members)
            mset = set(members)
            return all(c > anchor or c in mset
                       for m in members for c in cons_any.get(m, []))

        out: list[int] = []
        groups: list[tuple[int, ...]] = []
        for idx, node in enumerate(self.plan.nodes):
            if node.is_leaf:
                continue
            if not dtype_ok:
                self.fusion_report[idx] = "dtype_unsupported"
            elif len(consumers[node.passive]) == 1:
                if solo_fits(idx):
                    self.fusion_report[idx] = "admitted"
                    out.append(idx)
                else:
                    self.fusion_report[idx] = "vmem_overflow"
            else:
                # default for shared-passive consumers; members of an
                # accepted group are upgraded to "admitted_shared" below
                self.fusion_report[idx] = "multi_consumer"
        if dtype_ok:
            for p, cons in sorted(consumers.items()):
                if len(cons) < 2:
                    continue
                mset = set(cons)
                if (all(solo_fits(i) for i in cons)
                        and not any(self.plan.nodes[m].active in mset
                                    for m in cons)
                        and group_fits(cons)
                        and order_ok(cons)):
                    grp = tuple(sorted(cons))
                    groups.append(grp)
                    for m in grp:
                        self.fusion_report[m] = "admitted_shared"
                        out.append(m)
        for idx, verdict in self.fusion_report.items():
            if verdict == "admitted":
                _metrics.counter("fusion_admissions_total",
                                 outcome="admitted").inc()
            elif verdict == "admitted_shared":
                _metrics.counter("fusion_admissions_total",
                                 outcome="admitted", mode="shared").inc()
            else:
                _metrics.counter("fusion_admissions_total",
                                 outcome="rejected", reason=verdict).inc()
        return tuple(sorted(out)), tuple(groups)

    # -------------------------------------------------------- device state
    def _materialize(self) -> None:
        """Build device arrays and compiled callables (see :meth:`release`)."""
        with _tracing.span("engine.materialize", engine=self.engine,
                           k=self.k):
            self._materialize_inner()

    def _materialize_inner(self) -> None:
        g = self.g
        if self._order is not None:
            # device copies of the boundary permutation (order: coloring in,
            # inv: root table out); rebuilt after release() like every prep
            self._order_dev = jnp.asarray(self._order, jnp.int32)
            self._inv_dev = jnp.asarray(inverse_order(self._order), jnp.int32)
        else:
            self._order_dev = self._inv_dev = None
        if self.engine == "pgbsc":
            self._spmm_prep = spmm_ops.prepare(
                g, self.spmm_method, interpret=self.interpret,
                dtype=self.dtype, reorder=self.reorder or "")
            self._nbr = self._mask = None
            self._fused_prep = (
                fused_ops.prepare_fused(g, interpret=self.interpret,
                                        dtype=self.dtype,
                                        reorder=self.reorder or "")
                if self.schedule.fused else None)
        else:
            nbr, mask = g.ell()
            self._spmm_prep = None
            self._fused_prep = None
            self._nbr = jnp.asarray(nbr)
            self._mask = jnp.asarray(mask)

        # Static split tables per internal plan node (+ chunked repacking
        # for nodes the memory model decided to colorset-chunk).
        self._splits: dict[int, tuple[jnp.ndarray, jnp.ndarray]] = {}
        self._chunk_packs: dict[int, ema_ops.ChunkedSplits] = {}
        chunk_map = self.schedule.chunk_map
        for idx, node in enumerate(self.plan.nodes):
            if node.is_leaf:
                continue
            t = node.size
            t_a = self.plan.nodes[node.active].size
            ia, ip = cs.split_tables(self.k, t, t_a)
            self._splits[idx] = (jnp.asarray(ia), jnp.asarray(ip))
            q = chunk_map.get(idx, 1)
            if q > 1:
                self._chunk_packs[idx] = ema_ops.pack_chunked_splits(
                    ia, ip, comb(self.k, t - t_a), q,
                    pair_block=pexec.PAIR_BLOCK)

        self._count_fn = jax.jit(self._build())
        self._batch_fn = None    # built lazily on first batched call
        self._seeded_fn = None   # jit(seed, iteration ids) -> batch totals
        self._released = False
        # trace-time watermark: peak live table bytes observed by the
        # executor's on_step probe (high-watermark across traced shapes)
        self._trace_peak_bytes = 0
        # pre-resolved registry counters: one attribute add per dispatch
        label = self.templates[0].name or "t"
        self._m_dispatches = _metrics.counter(
            "engine_dispatches_total", engine=self.engine)
        self._m_colorings = _metrics.counter(
            "engine_colorings_dispatched_total", engine=self.engine)
        self._m_spmm_cols = _metrics.counter(
            "engine_spmm_cols_dispatched_total", engine=self.engine)
        self._mem_labels = dict(engine=self.engine, template=label,
                                k=self.k)

    def _peak_probe(self, step: int, live_bytes: int) -> None:
        """Executor ``on_step`` hook: record the measured (trace-time) peak
        live table bytes of the plan walk — the watermark the memory-model
        validation gauges publish next to the analytic prediction."""
        if live_bytes > self._trace_peak_bytes:
            self._trace_peak_bytes = live_bytes

    @property
    def measured_peak_bytes(self) -> int:
        """Watermark from the last traced plan walk(s); 0 before any
        count call. Compare against :attr:`peak_table_bytes` (the model)."""
        return self._trace_peak_bytes

    def _publish_memory_gauges(self, batch: int) -> None:
        measured = self._trace_peak_bytes
        if not measured:
            return
        model = self.exec_choice.peak_bytes_per_coloring * max(batch, 1)
        _metrics.gauge("memory_measured_peak_bytes",
                       **self._mem_labels).set(measured)
        _metrics.gauge("memory_model_peak_bytes",
                       **self._mem_labels).set(model)
        if model:
            _metrics.gauge("memory_model_ratio",
                           **self._mem_labels).set(measured / model)

    def release(self) -> None:
        """Drop device arrays and compiled executables.

        Called by the service's :class:`~repro.service.cache.EngineCache`
        on eviction so a bounded cache actually bounds device memory. The
        engine stays usable: the next count call rebuilds lazily from the
        host-side graph.
        """
        for name in ("_count_fn", "_batch_fn", "_seeded_fn"):
            fn = getattr(self, name, None)
            if fn is not None and hasattr(fn, "clear_cache"):
                try:
                    fn.clear_cache()
                except Exception:
                    pass
        self._count_fn = self._batch_fn = self._seeded_fn = None
        self._spmm_prep = None
        self._fused_prep = None
        self._nbr = self._mask = None
        self._order_dev = self._inv_dev = None
        self._splits = {}
        self._chunk_packs = {}
        self._released = True

    def _ensure(self) -> None:
        if self._released:
            self._materialize()

    # ------------------------------------------------------------------ api
    def count_colorful(self, colors: jax.Array) -> tuple[jax.Array, jax.Array]:
        """-> (sum over root table, root table).

        For a fused engine the sum is a ``(T,)`` vector (one entry per
        template) and the second element is the tuple of root tables.
        """
        self._ensure()
        self.n_spmm_cols_dispatched += self.spmm_cols_per_coloring
        self._m_spmm_cols.inc(self.spmm_cols_per_coloring)
        with _tracing.span("engine.dispatch", engine=self.engine, batch=1):
            out = self._count_fn(jnp.asarray(colors))
            _tracing.sync_ready(out)
        self._publish_memory_gauges(1)
        return out

    def count_colorful_batch(self, colorings: jax.Array,
                             batch_size: int | None = None
                             ) -> tuple[jax.Array, jax.Array]:
        """Batched :meth:`count_colorful` over a (B, n) coloring batch.

        -> (totals (B,), root tables (B, ...)); a fused engine returns
        totals (B, T) and a T-tuple of root-table batches. The batch is
        chunked to
        ``batch_size`` (default: the budget-derived knob) colorings per
        device call; ragged tails are padded with the last coloring (and
        sliced off) so every chunk reuses one compiled program shape.
        """
        self._ensure()
        colorings = jnp.asarray(colorings)
        if colorings.ndim != 2:
            raise ValueError(f"expected (B, n) colorings, got "
                             f"{colorings.shape}")
        b = colorings.shape[0]
        if b == 0:
            # totals come out of the accumulator-dtype reduction, so the
            # empty case must match (f32 for bf16 storage)
            empty = jnp.zeros((0, len(self.templates)) if self.fused
                              else (0,), ema_ops.accum_dtype(self.dtype))
            return empty, (() if self.fused else empty)
        # clamped to b: steady-state short calls (e.g. a runner checkpointing
        # every 4 with knob 16) must not pay 4x padded compute; the cost is
        # at most one extra compiled shape per distinct call length, and
        # ragged tails within a call still pad to bs below
        bs = min(batch_size or self.batch_size or b, b)
        if self._batch_fn is None:
            self._batch_fn = jax.jit(self._build_batch())
        totals, roots = [], []
        for base in range(0, b, bs):
            chunk = colorings[base: base + bs]
            pad = bs - chunk.shape[0]
            if pad:
                fill = jnp.broadcast_to(chunk[-1:], (pad,) + chunk.shape[1:])
                chunk = jnp.concatenate([chunk, fill])
            first = self.n_batch_dispatches == 0
            with _tracing.span("engine.dispatch", engine=self.engine,
                               batch=bs, first=first):
                tot, root = self._batch_fn(chunk)
                _tracing.sync_ready(tot)
            self.n_batch_dispatches += 1
            self.n_colorings_dispatched += bs
            self.n_spmm_cols_dispatched += self.spmm_cols_per_coloring * bs
            self._m_dispatches.inc()
            self._m_colorings.inc(bs)
            self._m_spmm_cols.inc(self.spmm_cols_per_coloring * bs)
            totals.append(tot[: bs - pad])
            roots.append(tuple(r[: bs - pad] for r in root) if self.fused
                         else root[: bs - pad])
        self._publish_memory_gauges(bs)
        if self.fused:
            root_out = tuple(jnp.concatenate([r[j] for r in roots])
                             for j in range(len(self.roots)))
        else:
            root_out = jnp.concatenate(roots)
        return jnp.concatenate(totals), root_out

    def count_iterations_batch(self, iterations, seed: int = 0,
                               batch_size: int | None = None
                               ) -> dict:
        """Colorful sums for explicit iteration ids, batched device-side.

        -> ``{iteration id: colorful sum}`` — a float per id, or a ``(T,)``
        float array per id for a fused engine (template order =
        ``self.templates``). The colorings are derived from
        ``fold_in(seed, iteration)`` *inside* the jit (no host-side
        generation or transfer) and the full execution plan runs once per
        ``batch_size`` chunk. Per-iteration values are bitwise independent
        of the batch composition, which keeps the fault-tolerant runner's
        resume-equals-straight invariant intact.
        """
        self._ensure()
        its = [int(i) for i in iterations]
        if not its:
            return {}
        # same clamping tradeoff as count_colorful_batch
        bs = min(batch_size or self.batch_size or len(its), len(its))
        if self._seeded_fn is None:
            n, k = self.g.n, self.k

            def seeded(seed_, ids):
                from repro.graph.coloring import batch_colorings
                colorings = batch_colorings(seed_, ids, n, k)
                totals, _ = self._build_batch()(colorings)
                return totals

            self._seeded_fn = jax.jit(seeded)
        out: dict = {}
        for base in range(0, len(its), bs):
            chunk = its[base: base + bs]
            padded = chunk + [chunk[-1]] * (bs - len(chunk))
            first = self.n_batch_dispatches == 0
            with _tracing.span("engine.dispatch", engine=self.engine,
                               batch=bs, first=first):
                # np.asarray already blocks on the device result, so this
                # span measures real device time without an extra sync
                totals = np.asarray(self._seeded_fn(
                    jnp.int32(seed), jnp.asarray(padded, jnp.int32)))
            self.n_batch_dispatches += 1
            self.n_colorings_dispatched += bs
            self.n_spmm_cols_dispatched += self.spmm_cols_per_coloring * bs
            self._m_dispatches.inc()
            self._m_colorings.inc(bs)
            self._m_spmm_cols.inc(self.spmm_cols_per_coloring * bs)
            for i, it in enumerate(chunk):
                out[it] = totals[i].copy() if self.fused else float(totals[i])
        self._publish_memory_gauges(bs)
        return out

    def estimate(self, n_iters: int, seed: int = 0,
                 start_iteration: int = 0,
                 batch_size: int | None = None) -> dict:
        """Color-coding estimate averaged over ``n_iters`` colorings.

        Iterations run through the batched pipeline (``batch_size`` per
        device call); samples are identical to the sequential per-coloring
        loop because the colorings derive from the same fold_in keys.
        """
        if self.fused:
            raise ValueError("estimate() is single-template; fused engines "
                             "use estimate_many()")
        return self.estimate_many(n_iters, seed=seed,
                                  start_iteration=start_iteration,
                                  batch_size=batch_size)[0]

    def estimate_many(self, n_iters: int, seed: int = 0,
                      start_iteration: int = 0,
                      batch_size: int | None = None) -> list[dict]:
        """Per-template color-coding estimates from ONE fused plan run.

        Returns one :meth:`estimate`-shaped dict per template (in
        ``self.templates`` order); every template's samples come from the
        same colorings, so a template also counted solo with the same seed
        reproduces its samples to floating-point reassociation.
        """
        p = cs.colorful_probability(self.k)
        ids = range(start_iteration, start_iteration + n_iters)
        per = self.count_iterations_batch(ids, seed=seed,
                                          batch_size=batch_size)
        vals = np.stack([np.atleast_1d(np.asarray(per[it])) for it in ids])
        results = []
        for j, t in enumerate(self.templates):
            alpha = t.automorphisms
            samples = [float(v) / (alpha * p) for v in vals[:, j]]
            arr = np.asarray(samples)
            results.append({
                "count": float(arr.mean()),
                "std": float(arr.std(ddof=1)) if len(arr) > 1 else 0.0,
                "samples": samples,
                "n_iters": n_iters,
                "alpha": alpha,
                "colorful_probability": p,
            })
        return results

    # ------------------------------------------------------------- builders
    def _wrap_reorder(self, fn: Callable) -> Callable:
        """Boundary permutation around a built count program: colorings are
        permuted INTO the engine's reordered vertex space on the way in and
        the root tables are inverse-permuted back to the caller's original
        vertex ids on the way out. Totals are sums over the whole table, so
        they need nothing (permutation-invariant up to float reassociation).
        """
        if self._order is None:
            return fn
        order_dev, inv_dev = self._order_dev, self._inv_dev
        # pgbsc tables are combination-major (..., C, N); fascia/pfascia are
        # row-major (..., N, C) — the vertex axis moves accordingly
        vaxis = -1 if self.engine == "pgbsc" else -2
        is_fused = self.fused

        def wrapped(colors: jax.Array):
            totals, roots = fn(jnp.take(colors, order_dev, axis=-1))
            if is_fused:
                roots = tuple(jnp.take(r, inv_dev, axis=vaxis)
                              for r in roots)
            else:
                roots = jnp.take(roots, inv_dev, axis=vaxis)
            return totals, roots

        return wrapped

    def _build(self) -> Callable:
        if self.engine == "pgbsc":
            return self._wrap_reorder(self._build_pgbsc())
        return self._wrap_reorder(
            self._build_rowmajor(pruned=self.engine == "pfascia"))

    def _build_batch(self) -> Callable:
        """(B, n) colorings -> (totals (B,), root tables (B, ...)).

        ``pgbsc`` executes the plan directly on (B, C, N) tables (the
        kernels are batch-aware); the row-major engines vmap the
        single-coloring program over the batch dimension.
        """
        if self.engine == "pgbsc":
            return self._wrap_reorder(self._build_pgbsc())
        return self._wrap_reorder(
            jax.vmap(self._build_rowmajor(pruned=self.engine == "pfascia")))

    def _leaf_table_cn(self, colors: jax.Array) -> jnp.ndarray:
        """(..., k, N) one-hot of vertex colors — combination-major leaves.

        A leading batch dimension on ``colors`` broadcasts straight through.
        """
        return (jnp.arange(self.k, dtype=colors.dtype)[:, None]
                == colors[..., None, :]).astype(self.dtype)

    def _build_pgbsc(self) -> Callable:
        splits, packs, prep = self._splits, self._chunk_packs, self._spmm_prep
        fprep = self._fused_prep
        runner = pexec.PlanExecutor(self.plan, self.schedule)
        autotune = self.autotune_blocks

        def passive_op(p_idx, m_p):
            # SpMM over *all* passive color sets at once (Algorithm 4 l.3);
            # with plan dedup, shared passive children reuse the result.
            return spmm_ops.spmm(m_p, prep, autotune=autotune)

        def combine(idx, m_a, y_p):
            ia, ip = splits[idx]
            return ema_ops.ema(
                m_a, y_p, ia, ip,
                use_pallas=self.use_pallas_ema, interpret=self.interpret,
                autotune=autotune)

        def combine_direct(idx, m_a, m_p):
            # direct (no materialized y_p) nodes; chunking wins over fusion
            # when the memory model assigned both (Schedule.fused_set doc)
            if idx in packs:
                # colorset-chunked node: the passive SpMM output is produced
                # and consumed one C(k, t_p)-axis slice at a time
                return ema_ops.ema_chunked(
                    m_a, m_p, packs[idx],
                    lambda m: spmm_ops.spmm(m, prep, autotune=autotune))
            # fused node: SpMM and eMA in one Pallas launch — the
            # (B, C(k,t_p), N) neighbor-sum table never leaves VMEM
            ia, ip = splits[idx]
            return fused_ops.fused_spmm_ema(m_a, m_p, ia, ip, fprep)

        def combine_group(members, m_as, m_p):
            # shared-passive group: ONE launch computes the passive child's
            # neighbor sums once in VMEM scratch and applies every member's
            # split combination against it
            ias = tuple(splits[m][0] for m in members)
            ips = tuple(splits[m][1] for m in members)
            return fused_ops.fused_spmm_ema_shared(m_as, m_p, ias, ips,
                                                   fprep)

        # sub-f32 storage sums its root tables in the accumulator dtype
        # (f32 for bf16) — the final reduction must not halve its mantissa
        acc_dt = ema_ops.accum_dtype(self.dtype)

        def run(colors: jax.Array):
            # colors: (N,) or batched (B, N) — every step below is
            # polymorphic over the leading batch dimension.
            leaf = self._leaf_table_cn(colors)
            outs = runner.run(leaf, passive_op=passive_op, combine=combine,
                              combine_direct=combine_direct,
                              combine_group=combine_group,
                              on_step=self._peak_probe,
                              outputs=self.roots)
            if not self.fused:
                root = outs[0]
                return root.astype(acc_dt).sum(axis=(-2, -1)), root
            # one fused walk, one (..., T) totals vector — template j's
            # entry comes from its own root table
            totals = jnp.stack(
                [r.astype(acc_dt).sum(axis=(-2, -1)) for r in outs], axis=-1)
            return totals, outs

        return run

    def _build_rowmajor(self, pruned: bool) -> Callable:
        """FASCIA / PFASCIA: row-major (N, C) tables + ELL traversal."""
        splits = self._splits
        nbr, mask = self._nbr, self._mask
        runner = pexec.PlanExecutor(self.plan, self.schedule)

        acc_dt = ema_ops.accum_dtype(self.dtype)

        def nbr_sum(m_cols: jnp.ndarray) -> jnp.ndarray:
            # m_cols: (N, R) -> out[i, r] = sum_d m_cols[nbr[i, d], r] * mask
            # Accumulate in acc_dt (f32 for bf16 tables) and downcast once at
            # the end — the scan carry must keep one dtype throughout.
            def body(acc, nd):
                col_ids, msk = nd
                gathered = m_cols[col_ids, :].astype(acc_dt)
                return acc + gathered * msk.astype(acc_dt)[:, None], None

            acc0 = jnp.zeros(m_cols.shape, acc_dt)
            acc, _ = jax.lax.scan(body, acc0, (nbr.T, mask.T))
            return acc.astype(m_cols.dtype)

        def passive_op(p_idx, m_p):
            # PFASCIA: one neighbor sweep per distinct passive set.
            return nbr_sum(m_p)

        def combine(idx, m_a, y_p):
            ia, ip = splits[idx]

            def body(acc, idx_l):
                ia_l, ip_l = idx_l
                prod = (m_a[:, ia_l].astype(acc_dt)
                        * y_p[:, ip_l].astype(acc_dt))
                return acc + prod, None

            acc0 = jnp.zeros((m_a.shape[0], ia.shape[0]), acc_dt)
            acc, _ = jax.lax.scan(body, acc0, (ia.T, ip.T))
            return acc.astype(self.dtype)

        def combine_direct(idx, m_a, m_p):
            # FASCIA: the neighbor sweep is *inside* the split loop —
            # the redundancy of paper §3.1, preserved deliberately.
            ia, ip = splits[idx]

            def body(acc, idx_l):
                y_l = nbr_sum(m_p[:, idx_l[1]])   # (N, S) sweep per split
                prod = m_a[:, idx_l[0]].astype(acc_dt) * y_l.astype(acc_dt)
                return acc + prod, None

            acc0 = jnp.zeros((m_a.shape[0], ia.shape[0]), acc_dt)
            acc, _ = jax.lax.scan(body, acc0, (ia.T, ip.T))
            return acc.astype(self.dtype)

        def run(colors: jax.Array):
            leaf = self._leaf_table_cn(colors).T  # (N, k)
            outs = runner.run(
                leaf,
                passive_op=None if not pruned else passive_op,
                combine=combine, combine_direct=combine_direct,
                on_step=self._peak_probe,
                outputs=self.roots)
            if not self.fused:
                root = outs[0]
                return root.astype(acc_dt).sum(), root
            totals = jnp.stack([r.astype(acc_dt).sum() for r in outs])
            return totals, outs

        return run

    # ------------------------------------------------------------- analysis
    @property
    def flops_per_iteration(self) -> int:
        return self.work.total_flops

    @property
    def peak_table_bytes(self) -> int:
        """Modeled peak live table bytes of one batched dispatch."""
        return self.exec_choice.peak_bytes_per_coloring * self.batch_size

    def _spmm_cols_per_coloring(self) -> int:
        """Static SpMM (passive-transform) column count of one coloring.

        ``pgbsc``/``pfascia`` pay ``C(k, t_p)`` columns once per *distinct*
        passive child (the executor's y-cache), which is where fused plans
        win: a passive sub-template shared across templates is one SpMM for
        the whole bundle. A shared-passive fused GROUP keeps that once-per-
        child cost — its single launch runs the SpMM leg once for every
        member. Singleton-fused and colorset-chunked nodes bypass the cache
        and pay per consumer; ``fascia`` recomputes the sweep inside the
        split loop (``C(k, t)`` columns per split, paper §3.1).
        """
        cols = 0
        seen: set[int] = set()
        counted_groups: set[tuple[int, ...]] = set()
        chunk_map = self.schedule.chunk_map
        fused_set = self.schedule.fused_set
        group_of = self.schedule.group_of
        for idx, node in enumerate(self.plan.nodes):
            if node.is_leaf:
                continue
            t = node.size
            t_a = self.plan.nodes[node.active].size
            if self.engine == "fascia":
                cols += comb(self.k, t) * comb(t, t_a)
            elif idx in group_of and chunk_map.get(idx, 1) <= 1:
                grp = group_of[idx]
                if grp not in counted_groups:
                    counted_groups.add(grp)
                    cols += comb(self.k, t - t_a)
            elif chunk_map.get(idx, 1) > 1 or idx in fused_set:
                cols += comb(self.k, t - t_a)
            elif node.passive not in seen:
                seen.add(node.passive)
                cols += comb(self.k, t - t_a)
        return cols

    def _estimate_work(self) -> WorkEstimate:
        w = WorkEstimate(batch=max(1, self.batch_size))
        n, e, k = self.g.n, self.g.m, self.k
        itemsize = jnp.dtype(self.dtype).itemsize
        for idx, node in enumerate(self.plan.nodes):
            if node.is_leaf:
                continue
            t = node.size
            t_a = self.plan.nodes[node.active].size
            t_p = t - t_a
            n_sets, n_splits = comb(k, t), comb(t, t_a)
            if self.engine == "fascia":
                w.spmm_flops += e * n_sets * n_splits
            else:
                w.spmm_flops += e * comb(k, t_p)
            w.ema_flops += 2 * n * n_sets * n_splits
            w.table_bytes += itemsize * n * n_sets
        return w


def build_engine(g: Graph, template, engine: str = "pgbsc",
                 **kw) -> CountingEngine:
    """Convenience constructor (see CountingEngine). ``template`` accepts a
    TreeTemplate / TemplateSpec / registry name, or a list of them (equal k)
    for a fused multi-template engine."""
    return CountingEngine(g, template, engine=engine, **kw)
