"""Tree automorphism counting via AHU canonical forms.

The final color-coding estimate divides by the automorphism count of the
template (paper Alg. 1 line 11-12). For a rooted tree,
``aut(v) = prod_children aut(c) * prod_(groups of identical child canon) g!``.
For the unrooted count we root at the tree's center; a bicentral tree with two
isomorphic halves gains an extra factor of 2.
"""

from __future__ import annotations

from collections import Counter
from math import factorial

__all__ = ["tree_automorphisms", "tree_centers", "canonical_form"]


def _adjacency(edges, k):
    adj = {v: [] for v in range(k)}
    for u, v in edges:
        adj[u].append(v)
        adj[v].append(u)
    return adj


def tree_centers(edges, k: int) -> list[int]:
    """1 or 2 centers found by iteratively stripping leaves."""
    if k == 1:
        return [0]
    adj = _adjacency(edges, k)
    degree = {v: len(adj[v]) for v in range(k)}
    leaves = [v for v in range(k) if degree[v] <= 1]
    removed = len(leaves)
    while removed < k:
        nxt = []
        for leaf in leaves:
            degree[leaf] = 0
            for u in adj[leaf]:
                if degree[u] > 1:
                    degree[u] -= 1
                    if degree[u] == 1:
                        nxt.append(u)
        removed += len(nxt)
        leaves = nxt
    return sorted(leaves)


def _canon_and_aut(adj, v: int, parent: int) -> tuple[str, int]:
    """AHU canonical string + automorphism count of subtree rooted at v."""
    child_data = sorted(
        _canon_and_aut(adj, u, v) for u in adj[v] if u != parent
    )
    canon = "(" + "".join(c for c, _ in child_data) + ")"
    aut = 1
    for _, a in child_data:
        aut *= a
    for _, g in Counter(c for c, _ in child_data).items():
        aut *= factorial(g)
    return canon, aut


def canonical_form(edges, k: int) -> str:
    """Canonical string of the unrooted tree (rooted at center(s))."""
    centers = tree_centers(edges, k)
    adj = _adjacency(edges, k)
    forms = sorted(_canon_and_aut(adj, c, -1)[0] for c in centers)
    return "|".join(forms)


def tree_automorphisms(edges, k: int) -> int:
    """Automorphism count of an unrooted tree on k vertices."""
    if k == 1:
        return 1
    adj = _adjacency(edges, k)
    centers = tree_centers(edges, k)
    if len(centers) == 1:
        return _canon_and_aut(adj, centers[0], -1)[1]
    u, v = centers
    cu, au = _canon_and_aut(adj, u, v)
    cv, av = _canon_and_aut(adj, v, u)
    aut = au * av
    if cu == cv:  # the two halves can be swapped
        aut *= 2
    return aut
