"""Seed-deterministic fault-injection harness for the counting stack.

Every layer of the stack exposes **named injection points** — places where
production code asks the process-wide :class:`FaultPlan` "should something
go wrong here, now?". With no plan installed the question costs one global
read and a ``None`` check; with a plan installed, each point draws from its
own seeded random stream, so a given ``(plan seed, point, hit index)``
always fires (or not) identically — chaos runs are reproducible.

Named points (the ``point`` label of ``fault_injections_total``):

=================  =====================================================
``kernel.dispatch``  the engine's batched device dispatch (counter call)
``engine.build``     engine construction inside the :class:`EngineCache`
``ledger.write``     the runner's checkpoint write (corruptible)
``cache.read``       persistent estimate-cache file read (corruptible)
``http.handler``     the HTTP front end's request handlers
``dispatch.hang``    start of a dispatch attempt (hang → watchdog)
``dispatch.loop``    top of the async dispatcher loop (supervisor test)
=================  =====================================================

Fault modes:

* ``raise`` — raise :class:`InjectedFault` (an ordinary ``RuntimeError``
  subclass: containment code must treat it like any crash);
* ``delay`` — sleep ``delay_s`` then continue (latency, not failure);
* ``hang`` — sleep ``hang_s`` (default 300 s — far past any watchdog);
* ``corrupt`` — only at write/read points that call :func:`corrupt_bytes`:
  truncate the payload at a deterministic offset, simulating a torn write
  (``kill -9`` mid-``write``).

A spec fires with probability ``rate`` per hit, after skipping the first
``after`` hits, at most ``times`` times, and only when ``match`` (a
substring) occurs in the injection context label — so a test can poison
exactly one dispatch group while the rest of the workload runs clean.

Install a plan process-wide with :func:`install_plan` (the ``serve
--inject`` path), or scoped with :func:`active_plan` (the test fixture
path). Compact spec-string form, for the CLI::

    kernel.dispatch:raise:0.1,ledger.write:corrupt:0.05,dispatch.hang:hang:0.02
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import random
import threading
import time

from repro.obs import metrics as _metrics

__all__ = [
    "POINTS", "MODES", "InjectedFault", "FaultSpec", "FaultPlan",
    "install_plan", "clear_plan", "current_plan", "active_plan",
    "inject", "corrupt_bytes",
]

POINTS = frozenset((
    "kernel.dispatch", "engine.build", "ledger.write", "cache.read",
    "http.handler", "dispatch.hang", "dispatch.loop",
))

MODES = frozenset(("raise", "delay", "hang", "corrupt"))


class InjectedFault(RuntimeError):
    """Raised by a ``raise``-mode fault. Deliberately a plain RuntimeError
    subclass: containment paths must handle it exactly like a real crash —
    code that special-cases InjectedFault is cheating the chaos suite."""

    def __init__(self, point: str, context: str = ""):
        self.point = point
        self.context = context
        super().__init__(f"injected fault at {point}"
                         + (f" ({context})" if context else ""))


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault class at one injection point."""

    point: str
    mode: str = "raise"
    rate: float = 1.0          # firing probability per (matched) hit
    times: int | None = None   # total firing budget (None = unlimited)
    after: int = 0             # skip the first N matched hits
    match: str = ""            # substring filter on the context label
    delay_s: float = 0.05      # sleep for mode="delay"
    hang_s: float = 300.0      # sleep for mode="hang"

    def __post_init__(self):
        if self.point not in POINTS:
            raise ValueError(f"unknown injection point {self.point!r}; "
                             f"known: {sorted(POINTS)}")
        if self.mode not in MODES:
            raise ValueError(f"unknown fault mode {self.mode!r}; "
                             f"known: {sorted(MODES)}")
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {self.rate}")


class FaultPlan:
    """A set of :class:`FaultSpec`\\ s with seed-deterministic firing.

    Each spec owns an independent ``random.Random`` stream seeded from
    ``(plan seed, point, spec index)`` plus hit counters, so the firing
    pattern is a pure function of the plan seed and the sequence of hits
    at each point — identical workloads see identical faults.
    """

    def __init__(self, specs, seed: int = 0):
        self.seed = int(seed)
        self.specs = tuple(specs)
        self._lock = threading.Lock()
        self._rngs = [random.Random(f"{self.seed}:{s.point}:{i}")
                      for i, s in enumerate(self.specs)]
        self._hits = [0] * len(self.specs)     # matched hits per spec
        self._fired = [0] * len(self.specs)    # firings per spec

    # ------------------------------------------------------------- parsing
    @classmethod
    def parse(cls, text: str, seed: int = 0) -> "FaultPlan":
        """``point:mode[:rate[:times]]`` specs, comma-separated; or a path
        to a JSON file (``{"seed": .., "faults": [{...}, ...]}``)."""
        text = text.strip()
        if os.path.isfile(text):
            with open(text) as f:
                doc = json.load(f)
            return cls([FaultSpec(**s) for s in doc.get("faults", [])],
                       seed=doc.get("seed", seed))
        specs = []
        for part in filter(None, (p.strip() for p in text.split(","))):
            fields = part.split(":")
            if len(fields) < 2:
                raise ValueError(f"fault spec {part!r}: want "
                                 "point:mode[:rate[:times]]")
            kw: dict = {"point": fields[0], "mode": fields[1]}
            if len(fields) > 2:
                kw["rate"] = float(fields[2])
            if len(fields) > 3:
                kw["times"] = int(fields[3])
            specs.append(FaultSpec(**kw))
        return cls(specs, seed=seed)

    # -------------------------------------------------------------- firing
    def _armed(self, point: str, context: str, modes) -> FaultSpec | None:
        """The first spec that fires for this hit (advances counters)."""
        with self._lock:
            for i, s in enumerate(self.specs):
                if s.point != point or s.mode not in modes:
                    continue
                if s.match and s.match not in context:
                    continue
                self._hits[i] += 1
                if self._hits[i] <= s.after:
                    continue
                if s.times is not None and self._fired[i] >= s.times:
                    continue
                if self._rngs[i].random() >= s.rate:
                    continue
                self._fired[i] += 1
                return s
        return None

    def stats(self) -> dict:
        """Per-spec hit/fire counts (tests, /healthz)."""
        with self._lock:
            return {f"{s.point}:{s.mode}": {"hits": h, "fired": f}
                    for s, h, f in zip(self.specs, self._hits, self._fired)}


# ------------------------------------------------------------- process plan
_plan: FaultPlan | None = None


def install_plan(plan: FaultPlan | None) -> FaultPlan | None:
    """Install (or, with None, remove) the process-wide fault plan."""
    global _plan
    _plan = plan
    return plan


def clear_plan() -> None:
    install_plan(None)


def current_plan() -> FaultPlan | None:
    return _plan


@contextlib.contextmanager
def active_plan(plan: FaultPlan):
    """Scoped installation (the chaos-test fixture path)."""
    prev = current_plan()
    install_plan(plan)
    try:
        yield plan
    finally:
        install_plan(prev)


def _record(spec: FaultSpec) -> None:
    _metrics.counter("fault_injections_total", point=spec.point,
                     mode=spec.mode).inc()


def inject(point: str, context: str = "") -> None:
    """Ask the installed plan whether a raise/delay/hang fault fires here.

    No-op without a plan (one global read). ``context`` is a free-form
    label (group key, engine name, request id) that specs can ``match``
    against and that travels in the raised error message.
    """
    plan = _plan
    if plan is None:
        return
    spec = plan._armed(point, context, ("raise", "delay", "hang"))
    if spec is None:
        return
    _record(spec)
    if spec.mode == "delay":
        time.sleep(spec.delay_s)
        return
    if spec.mode == "hang":
        time.sleep(spec.hang_s)
        return
    raise InjectedFault(point, context)


def corrupt_bytes(point: str, payload: bytes, context: str = "") -> bytes:
    """Possibly truncate ``payload`` — a torn write at a corruptible point.

    The truncation offset is deterministic in the spec's stream. An empty
    or one-byte payload passes through (nothing to tear).
    """
    plan = _plan
    if plan is None or len(payload) < 2:
        return payload
    spec = plan._armed(point, context, ("corrupt",))
    if spec is None:
        return payload
    _record(spec)
    cut = 1 + (zhash(point, plan.seed) % (len(payload) - 1))
    return payload[:cut]


def zhash(text: str, seed: int) -> int:
    """Small stable hash (process-hash-randomization-proof)."""
    h = 2166136261 ^ seed
    for ch in text.encode():
        h = ((h ^ ch) * 16777619) & 0xFFFFFFFF
    return h
