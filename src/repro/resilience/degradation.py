"""Degradation ladder and circuit breakers for the counting engines.

**Ladder.** A :class:`DegradationState` tracks, per engine-build identity
``(graph, template, engine, plan)``, how aggressive an execution config the
stack is allowed to use. Healthy groups run as requested (level
``as_built`` — possibly fused Pallas kernels on bf16 tables). Repeated
kernel/dispatch failures step the ladder *down*, one reliability rung at a
time, and the dispatch path rebuilds the engine at the new level before
retrying:

=========  =============================================================
level 0     ``as_built`` — the requested build options, untouched
level 1     ``unfused`` — drop SpMM→eMA fusion and block autotuning
level 2     ``xla`` — pure-XLA kernels (``spmm_method=segment``, no
            Pallas eMA) and f32 storage when the build asked for a
            sub-4-byte dtype
=========  =============================================================

Every transition is reason-labeled in ``degradation_steps_total{direction,
reason}`` and the current level published as ``degradation_level{engine,
template}``. After ``cooldown_s`` without a failure the ladder re-promotes
one level per dispatch (``direction="up"``), so a transient bad patch does
not permanently strand a group on the slow path.

**Circuit breaker.** A :class:`CircuitBreaker` per dispatch group
quarantines *poison* work: after ``threshold`` consecutive dispatch
failures (each already a full retry budget at the ladder's floor) the
circuit opens and further dispatches for that group fail fast — a
structured ``CircuitOpen`` error, no device work, no retry storm — while
every other group keeps serving. After ``cooldown_s`` the breaker goes
half-open and admits ONE trial dispatch: success closes it, failure
re-opens. ``circuit_open_total`` counts openings; :meth:`BreakerBoard.
snapshot` feeds ``/healthz`` so a load balancer can see a degraded-but-
alive process.
"""

from __future__ import annotations

import threading
import time

from repro.obs import metrics as _metrics

__all__ = ["LADDER_LEVELS", "DegradationState", "CircuitOpen",
           "CircuitBreaker", "BreakerBoard"]

LADDER_LEVELS = ("as_built", "unfused", "xla")

_NARROW_DTYPES = ("bfloat16", "float16")


def _dtype_name(dt) -> str:
    return getattr(dt, "__name__", None) or str(dt)


class DegradationState:
    """Per-engine-identity ladder position (see module docstring).

    ``label`` is the metric identity (``engine``/``template`` gauge
    labels); ``clock`` is injectable for cooldown tests.
    """

    def __init__(self, *, engine: str = "pgbsc", template: str = "",
                 step_after: int = 2, cooldown_s: float = 30.0,
                 clock=time.monotonic):
        self.engine = engine
        self.template = template
        self.step_after = max(int(step_after), 1)
        self.cooldown_s = float(cooldown_s)
        self.clock = clock
        self.level = 0
        self._consecutive = 0
        self._last_failure = None
        self._lock = threading.Lock()
        self._publish()

    @property
    def level_name(self) -> str:
        return LADDER_LEVELS[self.level]

    def _publish(self) -> None:
        _metrics.gauge("degradation_level", engine=self.engine,
                       template=self.template).set(self.level)

    def on_failure(self, reason: str = "dispatch_error") -> bool:
        """Record one failed attempt; returns True when the ladder stepped
        down (the caller should rebuild the engine at :meth:`apply`)."""
        with self._lock:
            self._consecutive += 1
            self._last_failure = self.clock()
            if (self._consecutive % self.step_after == 0
                    and self.level < len(LADDER_LEVELS) - 1):
                self.level += 1
                _metrics.counter("degradation_steps_total",
                                 direction="down", reason=reason).inc()
                self._publish()
                return True
        return False

    def on_success(self) -> None:
        with self._lock:
            self._consecutive = 0

    def maybe_promote(self) -> bool:
        """Step up one level if degraded and the cooldown elapsed since the
        last failure; returns True when promoted (engine rebuild due)."""
        with self._lock:
            if self.level == 0 or self._last_failure is None:
                return False
            if self.clock() - self._last_failure < self.cooldown_s:
                return False
            self.level -= 1
            self._last_failure = self.clock()   # one rung per cooldown
            _metrics.counter("degradation_steps_total",
                             direction="up", reason="cooldown").inc()
            self._publish()
            return True

    def apply(self, engine_kw: dict) -> dict:
        """The build options for the current level: ``engine_kw`` with the
        unreliable features stripped. Level 0 returns a copy unchanged."""
        kw = dict(engine_kw)
        if self.level >= 1:
            kw.pop("fuse_spmm_ema", None)
            kw.pop("autotune_blocks", None)
        if self.level >= 2:
            kw["spmm_method"] = "segment"
            kw.pop("use_pallas_ema", None)
            dt = kw.get("dtype")
            if dt is not None and _dtype_name(dt) in _NARROW_DTYPES:
                import jax.numpy as jnp
                kw["dtype"] = jnp.float32
        return kw

    def snapshot(self) -> dict:
        return {"level": self.level, "level_name": self.level_name,
                "consecutive_failures": self._consecutive}


class CircuitOpen(RuntimeError):
    """Dispatch refused: the group's circuit breaker is open (poison
    quarantine). Carries the group label for structured error bodies."""

    def __init__(self, label: str, failures: int):
        self.label = label
        self.failures = failures
        super().__init__(
            f"circuit open for group {label} after {failures} consecutive "
            f"dispatch failures; retry after cool-down")


class CircuitBreaker:
    """closed → (threshold consecutive failures) → open → (cooldown) →
    half-open → one trial → closed | open."""

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

    def __init__(self, *, threshold: int = 3, cooldown_s: float = 30.0,
                 label: str = "", clock=time.monotonic):
        self.threshold = max(int(threshold), 1)
        self.cooldown_s = float(cooldown_s)
        self.label = label
        self.clock = clock
        self.state = self.CLOSED
        self.failures = 0          # consecutive
        self._opened_at = 0.0
        self._lock = threading.Lock()

    def allow(self) -> bool:
        """May the caller dispatch now? An open breaker past its cooldown
        transitions to half-open and admits exactly one trial."""
        with self._lock:
            if self.state == self.CLOSED:
                return True
            if self.state == self.HALF_OPEN:
                return False           # a trial is already in flight
            if self.clock() - self._opened_at >= self.cooldown_s:
                self.state = self.HALF_OPEN
                return True
            return False

    def on_success(self) -> None:
        with self._lock:
            self.state = self.CLOSED
            self.failures = 0

    def on_failure(self) -> None:
        with self._lock:
            self.failures += 1
            if self.state == self.HALF_OPEN or \
                    self.failures >= self.threshold:
                if self.state != self.OPEN:
                    _metrics.counter("circuit_open_total").inc()
                self.state = self.OPEN
                self._opened_at = self.clock()

    def snapshot(self) -> dict:
        return {"state": self.state, "consecutive_failures": self.failures}


class BreakerBoard:
    """All of one service's breakers, keyed by dispatch-group key."""

    def __init__(self, *, threshold: int = 3, cooldown_s: float = 30.0,
                 clock=time.monotonic):
        self.threshold = threshold
        self.cooldown_s = cooldown_s
        self.clock = clock
        self._breakers: dict = {}
        self._lock = threading.Lock()

    def get(self, key, label: str = "") -> CircuitBreaker:
        with self._lock:
            br = self._breakers.get(key)
            if br is None:
                br = CircuitBreaker(threshold=self.threshold,
                                    cooldown_s=self.cooldown_s,
                                    label=label or str(key),
                                    clock=self.clock)
                self._breakers[key] = br
            return br

    def snapshot(self) -> dict:
        """State counts plus the non-closed breakers by label (healthz)."""
        with self._lock:
            counts = {CircuitBreaker.CLOSED: 0, CircuitBreaker.OPEN: 0,
                      CircuitBreaker.HALF_OPEN: 0}
            unhealthy = {}
            for br in self._breakers.values():
                counts[br.state] += 1
                if br.state != CircuitBreaker.CLOSED:
                    unhealthy[br.label] = br.snapshot()
            return {"counts": counts, "unhealthy": unhealthy}
