"""Failure containment for the counting service.

Four cooperating pieces, each threaded through a different layer of the
stack (see the README "Failure model & degradation ladder" section):

* :mod:`repro.resilience.faults` — the seed-deterministic fault-injection
  harness (named injection points, ``serve --inject``, the chaos suite's
  fixture);
* :mod:`repro.resilience.retry` — retry budgets, jittered exponential
  backoff, and the dispatch watchdog (hung-dispatch detection);
* :mod:`repro.resilience.degradation` — the per-engine degradation ladder
  (fused → unfused → XLA, bf16 → f32) and per-group circuit breakers;
* :mod:`repro.resilience.recovery` — checksummed, versioned JSON state
  with quarantine-on-corruption loads (ledgers, caches).

Design rule: containment code never special-cases injected faults — an
:class:`~repro.resilience.faults.InjectedFault` is an ordinary exception,
so surviving the chaos suite means surviving the real thing.
"""

from repro.resilience.degradation import (LADDER_LEVELS, BreakerBoard,
                                          CircuitBreaker, CircuitOpen,
                                          DegradationState)
from repro.resilience.faults import (FaultPlan, FaultSpec, InjectedFault,
                                     active_plan, clear_plan, current_plan,
                                     install_plan)
from repro.resilience.recovery import load_checked, quarantine, write_checked
from repro.resilience.retry import DispatchTimeout, RetryPolicy, \
    run_with_timeout

__all__ = [
    "FaultPlan", "FaultSpec", "InjectedFault",
    "install_plan", "clear_plan", "current_plan", "active_plan",
    "RetryPolicy", "DispatchTimeout", "run_with_timeout",
    "DegradationState", "CircuitBreaker", "CircuitOpen", "BreakerBoard",
    "LADDER_LEVELS",
    "load_checked", "write_checked", "quarantine",
]
