"""Retry budgets, jittered exponential backoff, and a dispatch watchdog.

The dispatch path wraps every device dispatch in
:func:`run_with_timeout` + a :class:`RetryPolicy` loop: a crashed dispatch
retries with backoff (the retries re-run the *same* iteration ids, and
samples are deterministic functions of ``(seed, id)``, so a retried
dispatch produces bitwise-identical results); a hung dispatch is detected
by wall clock and abandoned. Exhausting the budget FAILS the affected
requests with a structured error instead of killing the dispatcher.

The watchdog cannot kill a hung Python thread; it *abandons* it. The
abandoned worker receives a ``cancelled`` event so that, should it ever
wake up, it returns without side effects instead of racing the retry.
"""

from __future__ import annotations

import dataclasses
import random
import threading

__all__ = ["RetryPolicy", "DispatchTimeout", "run_with_timeout"]


class DispatchTimeout(TimeoutError):
    """A dispatch attempt exceeded its wall-clock budget and was abandoned."""

    def __init__(self, name: str, timeout_s: float):
        self.name = name
        self.timeout_s = timeout_s
        super().__init__(f"{name} exceeded {timeout_s:g}s wall clock "
                         "(abandoned by watchdog)")


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Budget + backoff shape for one retried operation.

    ``max_attempts`` counts total tries (1 = no retry). Backoff for the
    attempt-N retry is ``base_delay_s * 2**(N-1)`` capped at
    ``max_delay_s``, plus up to ``jitter`` of itself (drawn from the
    caller's RNG, so tests can pin it). ``timeout_s`` is the per-attempt
    wall-clock watchdog; None disables the watchdog thread entirely.
    """

    max_attempts: int = 4
    base_delay_s: float = 0.05
    max_delay_s: float = 2.0
    jitter: float = 0.5
    timeout_s: float | None = None

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, "
                             f"got {self.max_attempts}")
        if self.timeout_s is not None and self.timeout_s <= 0:
            raise ValueError(f"timeout_s must be > 0, got {self.timeout_s}")

    def delay(self, attempt: int, rng: random.Random | None = None) -> float:
        """Backoff before retry number ``attempt`` (1-based)."""
        base = min(self.base_delay_s * (2.0 ** max(attempt - 1, 0)),
                   self.max_delay_s)
        if self.jitter <= 0:
            return base
        r = rng.random() if rng is not None else random.random()
        return base * (1.0 + self.jitter * r)


def run_with_timeout(fn, timeout_s: float | None, name: str = "dispatch"):
    """Run ``fn(cancelled_event)``, abandoning it after ``timeout_s``.

    With ``timeout_s=None`` the call is direct (no thread, no overhead).
    Otherwise ``fn`` runs on a daemon worker; on timeout the worker's
    ``cancelled`` event is set, :class:`DispatchTimeout` raises here, and
    the worker — which must check ``cancelled`` after any blocking step —
    is left to die quietly. Exceptions inside ``fn`` re-raise here.
    """
    cancelled = threading.Event()
    if timeout_s is None:
        return fn(cancelled)
    box: dict = {}

    def work():
        try:
            box["result"] = fn(cancelled)
        except BaseException as exc:          # noqa: BLE001 — re-raised below
            box["error"] = exc

    t = threading.Thread(target=work, name=f"{name}-watchdog", daemon=True)
    t.start()
    t.join(timeout_s)
    if t.is_alive():
        cancelled.set()
        raise DispatchTimeout(name, timeout_s)
    if "error" in box:
        raise box["error"]
    return box.get("result")
