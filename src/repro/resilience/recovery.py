"""Crash-consistent JSON state: checksummed writes, quarantining loads.

The service persists three kinds of state — runner ledgers, the estimate
cache, metrics snapshots. A ``kill -9`` mid-write (or a torn NFS write, or
an injected ``corrupt`` fault) must never turn into an exception on the
*next* process's admission path. The contract here:

* :func:`write_checked` wraps the payload in a versioned envelope with a
  CRC32 over the canonical payload encoding and lands it via unique temp
  file + ``os.replace`` — a crashed writer can tear its temp file, never
  the live file;
* :func:`load_checked` verifies the envelope; a missing file is a clean
  cold start, while a truncated / garbage / checksum-failing file is
  **quarantined** — renamed to ``<path>.corrupt`` for post-mortem, counted
  in ``state_corruption_total{kind,reason}`` — and reported as a cold
  start. Pre-envelope files (a bare JSON dict from an older version) load
  as-is: the envelope is additive, not a migration.

Callers therefore always get *a* valid state dict; "rebuilt from scratch"
is the worst case, a crash is never one.
"""

from __future__ import annotations

import contextlib
import json
import os
import tempfile
import zlib

from repro.obs import metrics as _metrics
from repro.resilience import faults

__all__ = ["ENVELOPE_SCHEMA", "payload_crc", "write_checked",
           "load_checked", "quarantine"]

ENVELOPE_SCHEMA = 1


def payload_crc(payload) -> int:
    """CRC32 of the canonical (sorted-key, compact) JSON encoding."""
    body = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return zlib.crc32(body.encode())


def write_checked(path: str, payload: dict, *,
                  fault_point: str | None = None,
                  context: str = "") -> None:
    """Atomically replace ``path`` with the checksummed envelope of
    ``payload``. ``fault_point`` names the injection point whose ``raise``
    faults fire before the write and whose ``corrupt`` faults tear it."""
    if fault_point is not None:
        faults.inject(fault_point, context=context or path)
    body = json.dumps({"envelope": ENVELOPE_SCHEMA,
                       "crc": payload_crc(payload),
                       "payload": payload}).encode()
    if fault_point is not None:
        body = faults.corrupt_bytes(fault_point, body,
                                    context=context or path)
    d = os.path.dirname(path) or "."
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, prefix=os.path.basename(path) + ".")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(body)
        os.replace(tmp, path)
    except BaseException:
        with contextlib.suppress(OSError):
            os.unlink(tmp)
        raise


def quarantine(path: str, *, kind: str, reason: str) -> str | None:
    """Move a corrupt state file to its ``.corrupt`` sidecar (post-mortem
    evidence, and the load path won't trip on it again); returns the
    sidecar path, or None when even the rename fails."""
    sidecar = path + ".corrupt"
    _metrics.counter("state_corruption_total", kind=kind,
                     reason=reason).inc()
    try:
        os.replace(path, sidecar)
    except OSError:
        with contextlib.suppress(OSError):
            os.unlink(path)
        return None
    return sidecar


def load_checked(path: str, *, kind: str,
                 fault_point: str | None = None) -> tuple[dict | None, str]:
    """Load a checksummed state file; returns ``(payload, status)``.

    Statuses: ``"ok"`` (payload verified — or legacy pre-envelope dict),
    ``"missing"`` (no file; payload None), or the corruption reason
    (``"json"`` / ``"schema"`` / ``"crc"`` / ``"io"``; payload None and
    the file has been quarantined). Never raises on bad state.
    """
    if not os.path.isfile(path):
        return None, "missing"
    try:
        if fault_point is not None:
            faults.inject(fault_point, context=path)
        with open(path, "rb") as f:
            doc = json.loads(f.read().decode("utf-8", errors="strict"))
    except json.JSONDecodeError:
        quarantine(path, kind=kind, reason="json")
        return None, "json"
    except Exception:
        # OSError and injected faults alike: an unreadable state file must
        # not raise into the caller; treat as corrupt and start cold
        quarantine(path, kind=kind, reason="io")
        return None, "io"
    if not isinstance(doc, dict):
        quarantine(path, kind=kind, reason="schema")
        return None, "schema"
    if "envelope" not in doc:
        return doc, "ok"                       # legacy pre-envelope state
    if doc.get("envelope") != ENVELOPE_SCHEMA or "payload" not in doc:
        quarantine(path, kind=kind, reason="schema")
        return None, "schema"
    if payload_crc(doc["payload"]) != doc.get("crc"):
        quarantine(path, kind=kind, reason="crc")
        return None, "crc"
    return doc["payload"], "ok"
