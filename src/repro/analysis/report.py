"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m repro.analysis.report results/dryrun
"""

from __future__ import annotations

import json
import os
import sys


def load(outdir: str) -> list[dict]:
    recs = []
    for f in sorted(os.listdir(outdir)):
        if f.endswith(".json"):
            with open(os.path.join(outdir, f)) as fh:
                recs.append(json.load(fh))
    return recs


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b / 2**30:.2f}GiB"


def bottleneck_note(r: dict) -> str:
    """One sentence: what would move the dominant term down."""
    dom = r["roofline"]["dominant"]
    arch, cell = r["arch"], r["cell"]
    if arch == "pgbsc":
        if dom == "memory":
            return ("bf16/int compressed count tables or fewer table "
                    "streams via deeper sub-template dedup/partition search")
        return "reduce eMA child all-gathers via per-node gather-vs-"\
               "reduce-scatter cost model"
    if dom == "compute":
        return "lower capacity factor / expert-choice routing (MoE) or "\
               "fp8 matmuls"
    if dom == "collective":
        if "ogb" in cell or "minibatch" in cell:
            return ("graph partitioning (METIS-style) to localize edges and "
                    "cut cross-shard scatter-reduce volume")
        if "decode" in cell or "500k" in cell:
            return "kv-cache quantization (int8) halves gather payloads"
        return "overlap collectives with compute (async all-gather) or "\
               "int8-compressed gradient reduction"
    # memory
    if "train" in cell:
        return "more microbatches / bf16 master-grad accumulation to cut "\
               "activation traffic"
    if "decode" in cell or "500k" in cell:
        return "int8/int4 KV-cache quantization (2-4x cache-read bytes)"
    if arch == "autoint":
        return "fuse embedding-bag gathers with the interaction matmul "\
               "(single pass over field embeddings)"
    return "operator fusion to keep intermediates in registers/VMEM "\
           "(Pallas kernelization of the hot loop)"


def roofline_table(recs: list[dict], mesh: str = "single") -> str:
    rows = ["| arch | cell | flops/dev | bytes/dev | coll bytes | compute s "
            "| memory s | coll s | dominant | useful ratio | to improve |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if not r.get("ok"):
            rows.append(f"| {r['arch']} | {r['cell']} | FAILED: "
                        f"{r.get('error', '?')[:60]} | | | | | | | | |")
            continue
        rf = r["roofline"]
        ur = r.get("useful_flops_ratio")
        ur_s = f"{ur:.2f}" if ur is not None else "-"
        rows.append(
            f"| {r['arch']} | {r['cell']} | {rf['flops']:.3g} "
            f"| {rf['bytes']:.3g} | {rf['collective_bytes']:.3g} "
            f"| {rf['compute_s']:.4g} | {rf['memory_s']:.4g} "
            f"| {rf['collective_s']:.4g} | **{rf['dominant']}** "
            f"| {ur_s} | {bottleneck_note(r)} |")
    return "\n".join(rows)


def memory_table(recs: list[dict], mesh: str = "single") -> str:
    rows = ["| arch | cell | args/dev | output/dev | temp/dev | compile s |",
            "|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("mesh") != mesh or not r.get("ok"):
            continue
        m = r["memory"]
        rows.append(
            f"| {r['arch']} | {r['cell']} | {fmt_bytes(m['argument_bytes'])} "
            f"| {fmt_bytes(m['output_bytes'])} | {fmt_bytes(m['temp_bytes'])} "
            f"| {r['compile_s']} |")
    return "\n".join(rows)


def summary(recs: list[dict]) -> str:
    ok = [r for r in recs if r.get("ok")]
    fail = [r for r in recs if not r.get("ok")]
    lines = [f"total records: {len(recs)}  ok: {len(ok)}  failed: "
             f"{len(fail)}"]
    for r in fail:
        lines.append(f"  FAIL {r['arch']}/{r['cell']}/{r['mesh']}: "
                     f"{r.get('error', '')[:120]}")
    return "\n".join(lines)


def main():
    outdir = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    recs = load(outdir)
    print(summary(recs))
    print("\n## Roofline — single-pod 16x16 (256 chips)\n")
    print(roofline_table(recs, "single"))
    print("\n## Roofline — multi-pod 2x16x16 (512 chips)\n")
    print(roofline_table(recs, "multi"))
    print("\n## Memory analysis (single-pod)\n")
    print(memory_table(recs, "single"))


if __name__ == "__main__":
    main()
