"""Parse collective-communication bytes out of optimized HLO text.

``compiled.cost_analysis()`` has no collective term, so we walk
``compiled.as_text()`` for all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops. Optimized HLO lines look like

    %ppermute.90 = f32[1,37504]{1,0} collective-permute(%fusion), ...

operands are %refs without inline shapes, so we account the *result* shape
bytes per op — for all-reduce/permute/all-to-all this equals the operand
size; for all-gather it's the gathered size (an upper bound ~(g-1)/g of the
per-device wire traffic); for reduce-scatter we scale the result by the group
size parsed from replica_groups. The convention is recorded in EXPERIMENTS.md.
"""

from __future__ import annotations

import re
from collections import defaultdict

__all__ = ["collective_bytes", "collective_summary", "count_ops"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# "%name = f32[8,128]{1,0} op-name(" — capture dtype, dims, op
_LINE_RE = re.compile(
    r"=\s*(?:\([^=]*?\)\s*)?([a-z]+[0-9]*(?:e[0-9]+m[0-9]+)?)"
    r"\[([0-9,]*)\](?:\{[^}]*\})?\s+([a-z0-9\-]+?)(-start|-done)?\(")

# tuple-result async form: "%x = (f32[..], f32[..]) all-gather-start("
_TUPLE_RE = re.compile(r"=\s*\(([^)]*)\)\s*([a-z0-9\-]+?)(-start|-done)?\(")
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]*(?:e[0-9]+m[0-9]+)?)\[([0-9,]*)\]")

_GROUP_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    m = _GROUP_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUP_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def collective_summary(hlo_text: str) -> dict[str, dict]:
    """Per-collective-kind {count, bytes} using result-shape accounting."""
    out: dict[str, dict] = defaultdict(lambda: {"count": 0, "bytes": 0})
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        op = None
        nbytes = 0
        suffix = None
        m = _LINE_RE.search(s)
        if m and m.group(3) in _COLLECTIVES:
            op, suffix = m.group(3), m.group(4)
            nbytes = _shape_bytes(m.group(1), m.group(2))
        else:
            mt = _TUPLE_RE.search(s)
            if mt and mt.group(2) in _COLLECTIVES:
                op, suffix = mt.group(2), mt.group(3)
                # async tuple: (operand_shape, result_shape, ...) — take the
                # last shape (result) to match the sync-form convention
                shapes = _SHAPE_RE.findall(mt.group(1))
                if shapes:
                    nbytes = _shape_bytes(*shapes[-1])
        if op is None or suffix == "-done":
            continue
        if op == "reduce-scatter":
            nbytes *= _group_size(s)
        out[op]["count"] += 1
        out[op]["bytes"] += nbytes
    return dict(out)


def collective_bytes(hlo_text: str) -> int:
    return sum(v["bytes"] for v in collective_summary(hlo_text).values())


def count_ops(hlo_text: str, names=("fusion", "custom-call", "convolution",
                                    "dot")) -> dict[str, int]:
    counts = {n: 0 for n in names}
    pat = re.compile(r"=\s*(?:\([^=]*?\)\s*)?(?:[a-z0-9]+\[[0-9,]*\]"
                     r"(?:\{[^}]*\})?\s+)?([a-z0-9\-]+)\(")
    for line in hlo_text.splitlines():
        m = pat.search(line.strip())
        if m and m.group(1) in counts:
            counts[m.group(1)] += 1
    return counts
