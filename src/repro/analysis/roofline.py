"""Roofline analysis: compiled-artifact terms and measured kernel placement.

Two layers live here. :class:`KernelRoofline` + the ``spmm_ema_*`` traffic
models place a *measured* kernel dispatch (fused vs unfused SpMM->eMA)
against host peaks — benchmarks/bench_roofline.py drives them and commits
the result as BENCH_roofline.json. The rest derives roofline terms from a
compiled dry-run artifact (TPU v5e targets):

    compute    = HLO_FLOPs / (chips * 197e12)          [bf16 MXU]
    memory     = HLO_bytes / (chips * 819e9)           [HBM]
    collective = collective_bytes / (chips * 50e9)     [ICI per link]

cost_analysis() on the SPMD-partitioned module reports per-device FLOPs/bytes
in current jax (the module is the per-device program); we therefore divide by
one chip's peaks and report the dominant term + MODEL_FLOPS utilization.
"""

from __future__ import annotations

import dataclasses

__all__ = ["RooflineTerms", "roofline_from_compiled", "model_flops",
           "KernelRoofline", "spmm_ema_flops", "spmm_ema_hbm_bytes"]

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link


@dataclasses.dataclass
class RooflineTerms:
    flops: float
    bytes_accessed: float
    collective_bytes: float
    chips: int

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Perfect-overlap lower bound: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def compute_fraction(self) -> float:
        """Fraction of roofline-limited time spent on the compute term —
        1.0 means perfectly compute-bound (the ideal for training)."""
        t = self.step_time_s
        return self.compute_s / t if t > 0 else 0.0

    def as_dict(self) -> dict:
        return {
            "flops": self.flops, "bytes": self.bytes_accessed,
            "collective_bytes": self.collective_bytes, "chips": self.chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "step_time_s": self.step_time_s,
        }


def roofline_from_compiled(compiled, chips: int,
                           hlo_text: str | None = None) -> RooflineTerms:
    from repro.analysis.hlo import collective_bytes
    ca = compiled.cost_analysis()
    if isinstance(ca, list):   # older jax returns [dict]
        ca = ca[0]
    text = hlo_text if hlo_text is not None else compiled.as_text()
    return RooflineTerms(
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        collective_bytes=float(collective_bytes(text)),
        chips=chips,
    )


@dataclasses.dataclass
class KernelRoofline:
    """Achieved-vs-peak placement of ONE measured kernel dispatch.

    ``flops`` are the *useful* flops of the operation (nnz-based SpMM +
    split FMAs — not the dense/one-hot flops a given implementation happens
    to execute); ``hbm_bytes`` is that variant's modeled main-memory traffic.
    Peaks come from host microbenchmarks (see bench_roofline), so the
    fractions are comparable across variants on the same host.
    """

    name: str
    flops: float
    hbm_bytes: float
    seconds: float
    peak_flops: float
    peak_bw: float

    @property
    def achieved_flops(self) -> float:
        return self.flops / self.seconds if self.seconds > 0 else 0.0

    @property
    def achieved_bw(self) -> float:
        """Modeled traffic delivered per second — the roofline y-axis for a
        memory-bound kernel. A fused kernel that moves fewer bytes in less
        time scores higher than its unfused pair here; a fusion that merely
        shifts traffic without saving wall time does not."""
        return self.hbm_bytes / self.seconds if self.seconds > 0 else 0.0

    @property
    def oi(self) -> float:
        """Operational intensity (flops / byte)."""
        return self.flops / self.hbm_bytes if self.hbm_bytes > 0 else 0.0

    @property
    def bound(self) -> str:
        return ("compute" if self.oi * self.peak_bw > self.peak_flops
                else "memory")

    @property
    def roof_fraction(self) -> float:
        """Achieved flops as a fraction of the roofline at this OI."""
        roof = min(self.peak_flops, self.oi * self.peak_bw)
        return self.achieved_flops / roof if roof > 0 else 0.0

    def as_dict(self) -> dict:
        return {
            "name": self.name, "flops": self.flops,
            "hbm_bytes": self.hbm_bytes, "seconds": self.seconds,
            "achieved_gflops": self.achieved_flops / 1e9,
            "achieved_gbps": self.achieved_bw / 1e9,
            "oi": self.oi, "bound": self.bound,
            "roof_fraction": self.roof_fraction,
        }


def spmm_ema_flops(b: int, e: int, n: int, c_p: int, s: int, l: int) -> int:
    """Useful flops of one plan-node step over a coloring batch ``b``:
    nnz-based SpMM (2 flops per edge per passive color set) plus the split
    FMAs (2 flops per vertex per (set, split))."""
    return b * (2 * e * c_p + 2 * n * s * l)


def spmm_ema_hbm_bytes(b: int, n: int, c_a: int, c_p: int, s: int,
                       adj_bytes: int, itemsize: int, *,
                       fused: bool, adj_passes: int = 1) -> int:
    """Modeled HBM traffic of one plan-node step (tables + adjacency).

    Both variants read the active and passive tables and write the output
    table; the unfused pair additionally round-trips the ``(b, c_p, n)``
    neighbor-sum table through HBM (SpMM writes it, eMA reads it back) —
    exactly the traffic the fused kernel keeps in VMEM. The adjacency
    stream is charged ``adj_passes`` times (the fused kernel re-streams it
    once per batch block).

    ``itemsize`` is the *storage* dtype width: with
    ``compute_dtype=bfloat16`` the tables and adjacency values stream at
    2 bytes each while accumulation stays float32 in VMEM — halving this
    model's byte count without touching the FLOP count, which is how the
    bf16 rows in BENCH_roofline.json gain modeled bandwidth. Pass the
    bf16 itemsize through ``adj_bytes`` too (blocks are stored narrow).
    """
    tables = b * n * (c_a + c_p + s)
    if not fused:
        tables += 2 * b * n * c_p
    return tables * itemsize + adj_bytes * adj_passes


def model_flops(arch, cell) -> float:
    """6*N*D (dense LM) / 6*N_active*D (MoE) and family-specific analogues.

    These are *global* useful flops per step; divide by chips before
    comparing to the per-device HLO flops.
    """
    fam = arch.family
    m = arch.model
    if fam == "lm":
        tokens = cell.dims["batch"] * (cell.dims["seq"]
                                       if cell.kind != "decode" else 1)
        n = m.active_param_count() if m.moe else m.param_count()
        mult = 6 if cell.kind == "train" else 2
        return mult * n * tokens
    if fam == "gnn":
        d = m.d_hidden
        if cell.name in ("molecule", "smoke_molecule"):
            e = cell.dims["e"] * cell.dims["batch"]
            n = cell.dims["n"] * cell.dims["batch"]
        else:
            e, n = cell.dims["e"], cell.dims["n"]
        # message construction + aggregation + update, per layer
        per_layer = 2 * e * d * 2 + 2 * n * d * d * 2
        mult = 3 if cell.kind == "train" else 1
        return mult * m.n_layers * per_layer
    # recsys
    b = cell.dims["batch"]
    f = m.n_sparse + 1
    per_ex = (f * m.embed_dim * m.d_attn * 2
              + m.n_attn_layers * (3 * f * m.d_attn ** 2 * 2
                                   + 2 * f * f * m.d_attn * 2
                                   + f * m.d_attn ** 2 * 2)
              + f * m.d_attn * 2)
    total = b * per_ex
    if cell.kind == "retrieval":
        total += cell.dims["n_candidates"] * cell.dims["d_cand"] * 2 * b
    mult = 3 if cell.kind == "train" else 1
    return mult * total
