"""Merge dry-run result directories (later dirs override earlier) into a
final directory for reporting.

    PYTHONPATH=src python -m repro.analysis.merge_results \
        results/dryrun results/dryrun_v2 results/dryrun_v3 \
        --out results/dryrun_final
"""

from __future__ import annotations

import argparse
import os
import shutil


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("dirs", nargs="+")
    ap.add_argument("--out", required=True)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    merged = {}
    for d in args.dirs:
        if not os.path.isdir(d):
            continue
        for f in sorted(os.listdir(d)):
            if f.endswith(".json"):
                # pgbsc-opt replaces pgbsc in the final table
                target = f.replace("pgbsc-opt__", "pgbsc__")
                merged[target] = os.path.join(d, f)
    for target, src in merged.items():
        dst = os.path.join(args.out, target)
        if os.path.abspath(src) != os.path.abspath(dst):
            shutil.copyfile(src, dst)
    print(f"merged {len(merged)} records into {args.out}")


if __name__ == "__main__":
    main()
