"""Train/serve step builders for every architecture family.

``build_train_step(arch_cfg)`` returns (step_fn, abstract_state, state_specs,
batch_maker) where step_fn(state, batch) -> (state, metrics). The same builders
serve the real launcher (allocated params) and the dry-run (ShapeDtypeStruct
state via jax.eval_shape — nothing allocated).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import gnn as gnn_mod
from repro.models import equivariant as eq_mod
from repro.models import recsys as rec_mod
from repro.models import transformer as tfm
from repro.optim.optimizer import AdamWConfig, adamw_update, init_adamw
from repro.train import sharding as shd

__all__ = ["build_train_step", "build_serve_step", "abstract_train_state",
           "loss_fn_for"]


# ------------------------------------------------------------------- losses
def loss_fn_for(arch: ArchConfig):
    m = arch.model
    if arch.family == "lm":
        def loss(params, batch):
            return tfm.lm_loss(params, m, batch["tokens"], batch["targets"])
    elif arch.family == "gnn" and m.kind == "nequip":
        def loss(params, batch):
            return eq_mod.nequip_energy_loss(params, m, batch)
    elif arch.family == "gnn":
        def loss(params, batch):
            return gnn_mod.gnn_loss(params, m, batch)
    elif arch.family == "recsys":
        def loss(params, batch):
            return rec_mod.autoint_loss(params, m, batch)
    else:
        raise ValueError(arch.family)
    return loss


def init_params_fn(arch: ArchConfig, d_in: int | None = None):
    m = arch.model
    if arch.family == "lm":
        return lambda key: tfm.init_lm(key, m)
    if arch.family == "gnn" and m.kind == "nequip":
        return lambda key: eq_mod.init_nequip(key, m)
    if arch.family == "gnn":
        return lambda key: gnn_mod.init_gnn(key, m, d_in)
    if arch.family == "recsys":
        return lambda key: rec_mod.init_autoint(key, m)
    raise ValueError(arch.family)


def param_specs_for(arch: ArchConfig, params, mesh):
    if arch.family == "lm":
        return shd.lm_param_specs(params, mesh)
    if arch.family == "recsys":
        return shd.recsys_param_specs(params, mesh)
    return shd.gnn_param_specs(params, mesh)


# -------------------------------------------------------------- train state
def abstract_train_state(arch: ArchConfig, d_in: int | None = None):
    """ShapeDtypeStruct state via eval_shape — zero allocation (dry-run)."""
    init = init_params_fn(arch, d_in)

    def mk(key):
        params = init(key)
        return {"params": params, "opt": init_adamw(params)}

    return jax.eval_shape(mk, jax.random.PRNGKey(0))


def concrete_train_state(arch: ArchConfig, key, d_in: int | None = None):
    params = init_params_fn(arch, d_in)(key)
    return {"params": params, "opt": init_adamw(params)}


def build_train_step(arch: ArchConfig, opt_cfg: AdamWConfig | None = None,
                     statics: dict | None = None, microbatches: int = 1,
                     unroll_microbatches: bool = False):
    """``statics`` (e.g. GNN pool flag / n_graphs) are Python constants
    folded into the traced function, never jit arguments.

    ``microbatches`` > 1 enables gradient accumulation: the leading batch
    dim is split and scanned, shrinking activation memory ~k-fold (the knob
    that fits the 4k-token train cells into 16 GiB/chip — EXPERIMENTS.md
    §Perf iteration 4). ``unroll_microbatches`` uses a python loop instead of
    lax.scan so HLO cost analysis sees every microbatch (dry-run only).
    """
    opt_cfg = opt_cfg or AdamWConfig()
    loss_fn = loss_fn_for(arch)
    statics = statics or {}

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, {**batch, **statics})

    def step(state, batch):
        params = state["params"]
        if microbatches <= 1:
            loss, grads = grads_of(params, batch)
        else:
            split = jax.tree_util.tree_map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)

            def mb(carry, mbatch):
                loss_acc, g_acc = carry
                loss, g = grads_of(params, mbatch)
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
                return (loss_acc + loss, g_acc), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            if unroll_microbatches:
                carry = (jnp.zeros((), jnp.float32), zeros)
                for i in range(microbatches):
                    mbatch = jax.tree_util.tree_map(lambda x: x[i], split)
                    carry, _ = mb(carry, mbatch)
                loss_sum, grads = carry
            else:
                (loss_sum, grads), _ = jax.lax.scan(
                    mb, (jnp.zeros((), jnp.float32), zeros), split)
            loss = loss_sum / microbatches
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
        new_params, new_opt, metrics = adamw_update(
            opt_cfg, params, grads, state["opt"])
        metrics = dict(metrics, loss=loss)
        return {"params": new_params, "opt": new_opt}, metrics

    return step


# ------------------------------------------------------------------ serving
def build_serve_step(arch: ArchConfig, cell_kind: str,
                     statics: dict | None = None,
                     shard_hints: dict | None = None):
    m = arch.model
    statics = statics or {}
    if arch.family == "gnn":
        m_kind = m.kind
        inner = (eq_mod.nequip_forward if m_kind == "nequip"
                 else gnn_mod.gnn_forward)

        def serve(params, batch):
            return inner(params, m, {**batch, **statics})

        return serve
    if arch.family == "lm":
        if cell_kind == "prefill":
            def serve(params, batch):
                return tfm.lm_prefill(params, m, batch["tokens"])
        else:  # decode
            def serve(params, batch):
                logits, cache = tfm.lm_decode_step(
                    params, m, batch["cache"], batch["token"],
                    shard_hints=shard_hints)
                return logits, cache
        return serve
    if arch.family == "recsys":
        if cell_kind == "retrieval":
            def serve(params, batch):
                return rec_mod.retrieval_scores(
                    params, m, batch, batch["candidates"],
                    batch["retrieval_proj"])
        else:
            def serve(params, batch):
                return rec_mod.autoint_forward(params, m, batch)
        return serve
    raise ValueError(arch.family)
