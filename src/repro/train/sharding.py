"""Sharding rules: map param/batch pytrees to PartitionSpecs per family.

Conventions (DESIGN.md §4):
  data axis  — batch / vertices / tokens / edges ("dp" + "pod" for multi-pod)
  model axis — heads / ffn / experts / vocab / color-combinations ("tp"/"ep")

Rules are path-keyed: the most specific suffix match wins. Anything unmatched
is replicated.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["lm_param_specs", "gnn_param_specs", "recsys_param_specs",
           "batch_specs", "spec_to_sharding", "opt_state_specs",
           "DATA_AXES"]

DATA_AXES = ("pod", "data")  # batch shards over both on a multi-pod mesh


def _data(mesh: Mesh):
    return tuple(a for a in DATA_AXES if a in mesh.axis_names) or None


def lm_param_specs(params, mesh: Mesh):
    """Megatron-style TP: attention heads and FFN width over `model`;
    experts over `model` (EP); embeddings vocab-sharded over `model`.

    Dims that don't divide the model-axis size fall back (heads -> head_dim
    -> replicated): e.g. smollm's 15 heads or gemma3's 4 heads can't split 16
    ways, but their 64/256-wide head_dim can.
    """
    dm = mesh.shape["model"]

    def shardable(n: int) -> bool:
        return n % dm == 0

    def attn_spec(r: int, h: int, dh: int, trailing_d: bool):
        # layouts: (.., D, H, Dh) for wq/wk/wv; (.., H, Dh, D) for wo
        if trailing_d:
            if shardable(h):
                return P(*([None] * (r - 3) + ["model", None, None]))
            if shardable(dh):
                return P(*([None] * (r - 3) + [None, "model", None]))
            return P(*([None] * r))
        if shardable(h):
            return P(*([None] * (r - 2) + ["model", None]))
        if shardable(dh):
            return P(*([None] * (r - 1) + ["model"]))
        return P(*([None] * r))

    def rule(path: str, x):
        r = len(x.shape)
        if "q_norm" in path or "k_norm" in path:
            return P(*([None] * r))
        if "embed" in path and "species" not in path:  # (V, D)
            return P("model", None)
        if "lm_head" in path:                     # (D, V)
            return P(None, "model")
        if "wq" in path or "wk" in path or "wv" in path:
            return attn_spec(r, x.shape[-2], x.shape[-1], False)
        if "wo" in path:                          # (.., H, Dh, D)
            return attn_spec(r, x.shape[-3], x.shape[-2], True)
        if "moe" in path and "shared" not in path and \
                ("w_gate" in path or "w_up" in path or "w_down" in path):
            # (L, E, d, f) — experts over model
            return P(*([None] * (r - 3) + ["model", None, None]))
        if "router" in path:
            return P(*([None] * r))
        if "w_gate" in path or "w_up" in path:    # dense mlp (.., D, F)
            if shardable(x.shape[-1]):
                return P(*([None] * (r - 1) + ["model"]))
            return P(*([None] * r))
        if "w_down" in path:                      # (.., F, D)
            if shardable(x.shape[-2]):
                return P(*([None] * (r - 2) + ["model", None]))
            return P(*([None] * r))
        return P(*([None] * r))

    return _by_path(params, rule)


def gnn_param_specs(params, mesh: Mesh):
    dm = mesh.shape["model"]

    def rule(path: str, x):
        r = len(x.shape)
        if r == 2:
            if x.shape[-1] >= 64 and x.shape[-1] % dm == 0:
                return P(None, "model")           # wide layers over model
            if x.shape[0] >= 64 and x.shape[0] % dm == 0:
                return P("model", None)
        return P(*([None] * r))

    return _by_path(params, rule)


def recsys_param_specs(params, mesh: Mesh):
    def rule(path: str, x):
        r = len(x.shape)
        if "tables" in path:                      # (F, V, D): vocab-sharded
            return P(None, "model", None)
        return P(*([None] * r))

    return _by_path(params, rule)


def batch_specs(batch, mesh: Mesh, *, data_dims: dict | None = None):
    """Shard the leading dim of every batch array over the data axes,
    unless listed in data_dims with an explicit spec."""
    d = _data(mesh)

    def rule(path, x):
        if data_dims and path in data_dims:
            return data_dims[path]
        r = len(x.shape)
        if r == 0:
            return P()
        return P(*((d,) + (None,) * (r - 1)))

    return _by_path(batch, rule)


def opt_state_specs(param_specs, param_shapes=None, mesh: Mesh | None = None):
    """ZeRO-1: optimizer moments additionally shard over the data axes.

    fp32 Adam moments are 4x the bf16 params; sharding them only like the
    params leaves ~15 GiB/chip for the 30B MoE (EXPERIMENTS.md §Perf
    iteration 5). For each param we add the data axes to the largest
    unsharded dim that divides; XLA then emits the classic ZeRO pattern
    (reduce-scatter grads -> local moment update -> all-gather params).
    """
    if param_shapes is None or mesh is None:
        return {"mu": param_specs, "nu": param_specs,
                "step": jax.sharding.PartitionSpec()}
    d = _data(mesh)
    d_size = 1
    for ax in (d or ()):
        d_size *= mesh.shape[ax]

    def zero1(spec, shape_leaf):
        shape = shape_leaf.shape
        if d is None or not shape:
            return spec
        entries = list(spec) + [None] * (len(shape) - len(spec))
        cands = [i for i, e in enumerate(entries)
                 if e is None and shape[i] % d_size == 0 and shape[i] > 1]
        if not cands:
            return spec
        best = max(cands, key=lambda i: shape[i])
        entries[best] = d if len(d) > 1 else d[0]
        return jax.sharding.PartitionSpec(*entries)

    moment_specs = jax.tree_util.tree_map(
        zero1, param_specs, param_shapes,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    return {"mu": moment_specs, "nu": moment_specs,
            "step": jax.sharding.PartitionSpec()}


def spec_to_sharding(mesh: Mesh, tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))


def _by_path(tree, rule):
    def walk(path, t):
        if isinstance(t, dict):
            return {k: walk(f"{path}/{k}", v) for k, v in t.items()}
        if isinstance(t, (list, tuple)):
            out = [walk(f"{path}/{i}", v) for i, v in enumerate(t)]
            return type(t)(out)
        return rule(path, t)

    return walk("", tree)
