"""Sharded, atomic, resumable checkpointing (no external deps).

Layout of a checkpoint directory::

    <dir>/step_000042/
        meta.json            # step, tree structure, shapes/dtypes, extras
        shard_00000.npz      # flat arrays (possibly split across shards)
    <dir>/LATEST             # atomically-updated pointer file

Writes go to ``step_xxx.tmp`` then ``os.replace`` to the final name, so a
crash mid-write never corrupts the latest checkpoint — the restart path reads
``LATEST`` and falls back to the newest complete directory. Arrays are saved
logically-unsharded: restore works on any mesh shape (elastic scaling), the
caller re-applies shardings with ``jax.device_put``.
"""

from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "available_steps"]

_MAX_SHARD_BYTES = 1 << 30


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


_EXOTIC = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
           "float8_e5m2": np.uint8}


def _encode(arr: np.ndarray) -> tuple[np.ndarray, str | None]:
    """npz can't store ml_dtypes (bf16/f8); view as uint + remember dtype."""
    name = arr.dtype.name
    if name in _EXOTIC:
        return arr.view(_EXOTIC[name]), name
    return arr, None


def _decode(arr: np.ndarray, name: str | None) -> np.ndarray:
    if name is None:
        return arr
    import ml_dtypes
    return arr.view(np.dtype(getattr(ml_dtypes, name)))


def save_checkpoint(directory: str, step: int, tree, *, extras: dict | None
                    = None, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    leaves, treedef = _flatten(tree)
    encoded = [_encode(np.asarray(x)) for x in leaves]
    arrays = [a for a, _ in encoded]
    exotic = [d for _, d in encoded]

    name = f"step_{step:09d}"
    tmp = os.path.join(directory, name + ".tmp")
    final = os.path.join(directory, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    # shard arrays into ~1GB npz files
    shards: list[dict[str, np.ndarray]] = [{}]
    sizes = [0]
    index = []
    for i, arr in enumerate(arrays):
        if sizes[-1] + arr.nbytes > _MAX_SHARD_BYTES and shards[-1]:
            shards.append({})
            sizes.append(0)
        shards[-1][f"leaf_{i}"] = arr
        sizes[-1] += arr.nbytes
        index.append(len(shards) - 1)
    for si, shard in enumerate(shards):
        np.savez(os.path.join(tmp, f"shard_{si:05d}.npz"), **shard)

    meta = {
        "step": step,
        "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex()
        if hasattr(treedef, "serialize_using_proto") else None,
        "n_leaves": len(arrays),
        "shard_of_leaf": index,
        "exotic_dtypes": exotic,
        "extras": extras or {},
    }
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)

    # atomic LATEST pointer
    ptr_tmp = os.path.join(directory, "LATEST.tmp")
    with open(ptr_tmp, "w") as f:
        f.write(name)
    os.replace(ptr_tmp, os.path.join(directory, "LATEST"))

    # retention
    steps = available_steps(directory)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:09d}"),
                      ignore_errors=True)
    return final


def available_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp") and \
                os.path.isfile(os.path.join(directory, d, "meta.json")):
            out.append(int(d[5:]))
    return sorted(out)


def latest_step(directory: str) -> int | None:
    ptr = os.path.join(directory, "LATEST")
    if os.path.isfile(ptr):
        with open(ptr) as f:
            name = f.read().strip()
        if os.path.isfile(os.path.join(directory, name, "meta.json")):
            return int(name[5:])
    steps = available_steps(directory)
    return steps[-1] if steps else None


def restore_checkpoint(directory: str, tree_like, step: int | None = None):
    """Restore into the structure of ``tree_like``; returns (tree, extras).

    ``tree_like`` provides the treedef (its leaf values are ignored).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            return None, None
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    leaves_flat, treedef = jax.tree_util.tree_flatten(tree_like)
    assert meta["n_leaves"] == len(leaves_flat), (
        f"checkpoint has {meta['n_leaves']} leaves, expected "
        f"{len(leaves_flat)} — structure changed?")
    shard_files = {}
    out = []
    exotic = meta.get("exotic_dtypes") or [None] * meta["n_leaves"]
    for i in range(meta["n_leaves"]):
        si = meta["shard_of_leaf"][i]
        if si not in shard_files:
            shard_files[si] = np.load(
                os.path.join(path, f"shard_{si:05d}.npz"))
        out.append(_decode(shard_files[si][f"leaf_{i}"], exotic[i]))
    return jax.tree_util.tree_unflatten(treedef, out), meta["extras"]
