"""Explicit data-parallel (DDP) training step via shard_map, with optional
int8 error-feedback gradient compression.

The pjit path (train/step.py) lets XLA place the gradient all-reduce; this
builder makes it explicit so the all-reduce payload can be compressed 4x
(optim/optimizer.compressed_psum) — the bandwidth lever for collective-bound
data-parallel training on slow interconnects. Params are replicated; batches
shard over the data axis; the compression residual is part of the train
state (error feedback keeps the long-run update unbiased).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.optim.optimizer import (AdamWConfig, adamw_update, compressed_psum,
                                   init_adamw)
from repro.train.step import loss_fn_for

__all__ = ["build_ddp_step", "init_ddp_state"]


def init_ddp_state(params) -> dict:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"params": params, "opt": init_adamw(params), "residual": zeros}


def build_ddp_step(arch: ArchConfig, mesh: Mesh,
                   opt_cfg: AdamWConfig | None = None,
                   statics: dict | None = None,
                   compress: bool = True, axis: str = "data"):
    """Returns step(state, batch) -> (state, metrics); call under jit.

    batch arrays shard over ``axis`` on their leading dim; state replicates.
    """
    opt_cfg = opt_cfg or AdamWConfig()
    loss_fn = loss_fn_for(arch)
    statics = statics or {}
    n_shards = mesh.shape[axis]

    def local_step(state, batch):
        params = state["params"]
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, {**batch, **statics}))(params)
        if compress:
            grads, residual = compressed_psum(grads, axis,
                                              state["residual"])
            grads = jax.tree_util.tree_map(lambda g: g / n_shards, grads)
        else:
            grads = jax.lax.pmean(grads, axis)
            residual = state["residual"]
        loss = jax.lax.pmean(loss, axis)
        new_params, new_opt, metrics = adamw_update(
            opt_cfg, params, grads, state["opt"])
        metrics = dict(metrics, loss=loss)
        return {"params": new_params, "opt": new_opt,
                "residual": residual}, metrics

    def batch_specs(batch):
        return jax.tree_util.tree_map(
            lambda x: P(axis) if getattr(x, "ndim", 0) >= 1 else P(), batch)

    def step(state, batch):
        state_specs = jax.tree_util.tree_map(lambda _: P(), state)
        bspecs = batch_specs(batch)
        fn = shard_map(
            local_step, mesh=mesh,
            in_specs=(state_specs, bspecs),
            out_specs=(state_specs,
                       {"grad_norm": P(), "lr": P(), "loss": P()}),
            check_rep=False)
        return fn(state, batch)

    return step
