"""Deterministic synthetic data pipelines.

``make_batch(arch, cell_name, key)`` materializes a batch whose structure
matches configs.shapes.input_specs — used by smoke tests, examples and the
training driver. The LM stream is a reproducible zipf-ish token source; GNN
batches are random regular-ish graphs (or batched molecules with positions);
recsys batches are hashed ids + gaussian dense features.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.configs.shapes import input_specs

__all__ = ["make_batch", "statics_for", "lm_token_stream"]


def lm_token_stream(key, batch: int, seq: int, vocab: int) -> jnp.ndarray:
    """Zipf-flavored token ids (sorted uniform^3 concentrates mass)."""
    u = jax.random.uniform(key, (batch, seq))
    return jnp.clip((u ** 3 * vocab).astype(jnp.int32), 0, vocab - 1)


def statics_for(arch: ArchConfig, cell_name: str) -> dict:
    _, _, statics = input_specs(arch, cell_name)
    return statics


def make_batch(arch: ArchConfig, cell_name: str, key=None) -> dict:
    key = key if key is not None else jax.random.PRNGKey(0)
    specs, _, statics = input_specs(arch, cell_name)
    cell = arch.cell(cell_name)
    m = arch.model

    if arch.family == "lm":
        b, s = cell.dims["batch"], cell.dims["seq"]
        if cell.kind == "train":
            toks = lm_token_stream(key, b, s + 1, m.vocab_size)
            return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
        if cell.kind == "prefill":
            return {"tokens": lm_token_stream(key, b, s, m.vocab_size)}
        from repro.models.transformer import init_decode_cache
        cache = init_decode_cache(m, b, s, dtype=m.param_dtype)
        # pretend we've already decoded half the window
        cache = dict(cache, len=jnp.asarray(s // 2, jnp.int32))
        return {"token": lm_token_stream(key, b, 1, m.vocab_size),
                "cache": cache}

    if arch.family == "gnn":
        return _gnn_batch(arch, cell, specs, statics, key)

    # recsys
    b = cell.dims["batch"]
    ks = jax.random.split(key, 5)
    batch = {
        "sparse_ids": jax.random.randint(ks[0], (b, m.n_sparse), 0,
                                         m.vocab_size, dtype=jnp.int32),
        "bag_ids": jax.random.randint(ks[1], (b, m.bag_fields, m.bag_size),
                                      -1, m.vocab_size, dtype=jnp.int32),
        "dense": jax.random.normal(ks[2], (b, m.n_dense), jnp.float32),
    }
    if cell.kind == "train":
        batch["labels"] = jax.random.bernoulli(ks[3], 0.3, (b,)
                                               ).astype(jnp.float32)
    if cell.kind == "retrieval":
        nc, dc = cell.dims["n_candidates"], cell.dims["d_cand"]
        n_fields = m.n_sparse + 1
        batch["candidates"] = jax.random.normal(ks[3], (nc, dc), jnp.float32)
        batch["retrieval_proj"] = jax.random.normal(
            ks[4], (n_fields * m.d_attn, dc), jnp.float32) * 0.05
    return batch


def _gnn_batch(arch: ArchConfig, cell, specs, statics, key):
    m = arch.model
    d = cell.dims
    rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 1 << 30)))
    if cell.name in ("molecule", "smoke_molecule"):
        n_per, e_per, bs = d["n"], d["e"], d["batch"]
        n, e = n_per * bs, e_per * bs
        src = rng.integers(0, n_per, (bs, e_per)) + \
            (np.arange(bs) * n_per)[:, None]
        dst = rng.integers(0, n_per, (bs, e_per)) + \
            (np.arange(bs) * n_per)[:, None]
        edge_index = np.stack([src.ravel(), dst.ravel()]).astype(np.int32)
        node_graph = np.repeat(np.arange(bs), n_per).astype(np.int32)
        pooled, n_graphs = True, bs
    else:
        n, e = specs["edge_index"].shape[1], 0  # placeholder
        n = specs[("positions" if m.kind == "nequip" else "x")].shape[0]
        e = specs["edge_index"].shape[1]
        edge_index = rng.integers(0, n, (2, e)).astype(np.int32)
        node_graph = np.zeros(n, np.int32)
        pooled, n_graphs = False, 1

    batch = {"edge_index": jnp.asarray(edge_index),
             "node_graph": jnp.asarray(node_graph)}
    if m.kind == "nequip":
        batch["positions"] = jnp.asarray(
            rng.normal(size=(n, 3)).astype(np.float32) * 2.0)
        batch["species"] = jnp.asarray(rng.integers(0, 8, n).astype(np.int32))
        batch["labels"] = jnp.asarray(
            rng.normal(size=(n_graphs,)).astype(np.float32))
        return batch
    batch["x"] = jnp.asarray(
        rng.normal(size=(n, d["d_feat"])).astype(np.float32))
    if pooled:
        batch["labels"] = jnp.asarray(
            rng.normal(size=(n_graphs,)).astype(np.float32))
    else:
        batch["labels"] = jnp.asarray(
            rng.integers(0, m.n_classes, n).astype(np.int32))
        mask = np.zeros(n, np.float32)
        mask[: max(1, n // 4)] = 1.0
        batch["label_mask"] = jnp.asarray(mask)
    return batch
