"""Zero-dependency span tracer with a no-op fast path.

One process-wide :class:`Tracer` (swap it with :func:`set_tracer`) produces
nested, labeled :class:`Span`\\ s via the :func:`span` context manager::

    from repro.obs import tracing
    with tracing.span("service.dispatch", group="u5", n=8):
        ...

Disabled (the default), :func:`span` returns one shared no-op context
manager — no allocation beyond the kwargs dict, no clock read — so hot
loops can be instrumented unconditionally. The tests bound this overhead.

Two timing refinements for jit-dispatch instrumentation:

* ``sync=True`` makes :func:`sync_ready` call ``jax.block_until_ready``
  inside the enclosing span, so the span measures device time instead of
  async dispatch time (jax is imported lazily; the tracer itself has no
  jax dependency).
* :func:`arm_profiler` arms a one-shot ``jax.profiler`` trace: the next
  :func:`profiled_dispatch` block writes a device profile to the armed
  directory, then disarms — one dispatch, not the whole run.

Spans measure *host wall time of the code they wrap*. Code that runs under
``jax.jit`` executes its Python body once per compiled shape (tracing), so
spans inside jitted functions — e.g. the executor's per-node spans — record
trace/compile-time structure; device time belongs to the span around the
dispatch, with ``sync`` enabled.
"""

from __future__ import annotations

import contextlib
import threading
import time

__all__ = [
    "Span", "Tracer", "get_tracer", "set_tracer", "configure", "span",
    "enabled", "sync_ready", "arm_profiler", "profiled_dispatch",
]


class Span:
    """One timed, labeled region; nested spans become children."""

    __slots__ = ("name", "attrs", "t0", "t1", "children", "_tracer")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self.t0 = 0.0
        self.t1 = 0.0
        self.children: list[Span] = []

    @property
    def seconds(self) -> float:
        return max(0.0, self.t1 - self.t0)

    def set(self, **attrs) -> "Span":
        """Attach attributes mid-span (e.g. a result computed inside)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        self._tracer._push(self)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        self.t1 = time.perf_counter()
        self._tracer._pop(self)
        return False

    def to_dict(self) -> dict:
        return {"name": self.name, "seconds": self.seconds,
                "attrs": dict(self.attrs),
                "children": [c.to_dict() for c in self.children]}

    def __repr__(self) -> str:
        return f"Span({self.name}, {self.seconds * 1e3:.3f}ms, " \
               f"{len(self.children)} children)"


class _NullSpan:
    """Shared do-nothing span: the disabled-tracer fast path."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_NullSpan":
        return self


_NULL = _NullSpan()


class Tracer:
    """Collects finished root spans; nesting follows a per-thread stack."""

    def __init__(self, enabled: bool = True, sync: bool = False,
                 max_roots: int = 10_000):
        self.enabled = bool(enabled)
        self.sync = bool(sync)
        self.max_roots = int(max_roots)
        self.roots: list[Span] = []
        self._local = threading.local()

    # ------------------------------------------------------------- plumbing
    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _push(self, sp: Span) -> None:
        self._stack().append(sp)

    def _pop(self, sp: Span) -> None:
        st = self._stack()
        if st and st[-1] is sp:
            st.pop()
        if st:
            st[-1].children.append(sp)
        elif len(self.roots) < self.max_roots:
            self.roots.append(sp)

    # ------------------------------------------------------------------ api
    def span(self, name: str, **attrs):
        if not self.enabled:
            return _NULL
        return Span(self, name, attrs)

    def reset(self) -> None:
        self.roots = []
        self._local = threading.local()

    def to_dicts(self) -> list[dict]:
        return [r.to_dict() for r in self.roots]

    def breakdown(self) -> dict[str, dict]:
        """Aggregate ``{span name: {count, seconds}}`` over the whole tree."""
        agg: dict[str, dict] = {}

        def walk(sp: Span) -> None:
            ent = agg.setdefault(sp.name, {"count": 0, "seconds": 0.0})
            ent["count"] += 1
            ent["seconds"] += sp.seconds
            for c in sp.children:
                walk(c)

        for r in self.roots:
            walk(r)
        return agg


# ---------------------------------------------------------------- globals
_tracer = Tracer(enabled=False)


def get_tracer() -> Tracer:
    return _tracer


def set_tracer(t: Tracer) -> Tracer:
    global _tracer
    _tracer = t
    return t


def configure(enabled: bool | None = None, sync: bool | None = None) -> Tracer:
    """Flip the process tracer's switches in place; returns it."""
    if enabled is not None:
        _tracer.enabled = bool(enabled)
    if sync is not None:
        _tracer.sync = bool(sync)
    return _tracer


def span(name: str, **attrs):
    """Context manager for one span on the process tracer (no-op when
    tracing is disabled — safe in hot loops)."""
    t = _tracer
    if not t.enabled:
        return _NULL
    return Span(t, name, attrs)


def enabled() -> bool:
    return _tracer.enabled


def sync_ready(x) -> None:
    """Block on a jax value inside the enclosing span iff the tracer asks
    for device-sync timing (``sync=True``); otherwise free."""
    if _tracer.enabled and _tracer.sync:
        import jax
        jax.block_until_ready(x)


# ------------------------------------------------------- one-shot profiler
_profile_dir: list[str | None] = [None]


def arm_profiler(trace_dir: str | None) -> None:
    """Arm a one-shot ``jax.profiler`` trace: the next
    :func:`profiled_dispatch` block writes a profile to ``trace_dir``."""
    _profile_dir[0] = trace_dir


@contextlib.contextmanager
def profiled_dispatch():
    """Wrap one dispatch; emits a jax profiler trace if one is armed."""
    d = _profile_dir[0]
    if d is None:
        yield
        return
    _profile_dir[0] = None     # one-shot: disarm before running
    try:
        import jax.profiler as prof
        prof.start_trace(d)
    except Exception:
        yield
        return
    try:
        yield
    finally:
        try:
            prof.stop_trace()
        except Exception:
            pass
