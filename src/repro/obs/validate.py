"""Schema validation for :meth:`MetricsRegistry.snapshot` JSON files.

Library: :func:`validate_snapshot` raises ``ValueError`` with a pointed
message on the first violation. CLI (the CI obs-smoke step)::

    python -m repro.obs.validate SNAPSHOT.json \\
        --require-nonzero fusion --require-nonzero cache \\
        --require-hist 'qos='

``--require-nonzero PREFIX`` additionally demands at least one counter
whose name starts with (or contains) ``PREFIX`` with a nonzero value —
the smoke check that the instrumented paths actually ran.
``--require-hist PREFIX`` does the same for histograms (at least one
matching histogram with ``count > 0``), e.g. the per-QoS-class latency
histograms the serving smoke asserts on.
"""

from __future__ import annotations

import argparse
import json
import math
import sys

from repro.obs.metrics import SNAPSHOT_SCHEMA

__all__ = ["validate_snapshot", "main"]

_HIST_KEYS = {"count", "sum", "le", "bucket_counts", "p50", "p95", "p99"}


def _num(x) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool) \
        and math.isfinite(x)


def validate_snapshot(snap: object) -> dict:
    """Validate a snapshot dict; returns it (for chaining) or raises
    ``ValueError`` describing the first problem found."""
    if not isinstance(snap, dict):
        raise ValueError(f"snapshot must be a dict, got {type(snap).__name__}")
    if snap.get("schema") != SNAPSHOT_SCHEMA:
        raise ValueError(f"snapshot schema {snap.get('schema')!r} != "
                         f"expected {SNAPSHOT_SCHEMA}")
    for sect in ("counters", "gauges", "histograms"):
        if not isinstance(snap.get(sect), dict):
            raise ValueError(f"snapshot[{sect!r}] must be a dict")
    for sect in ("counters", "gauges"):
        for k, v in snap[sect].items():
            if not isinstance(k, str) or not _num(v):
                raise ValueError(f"{sect}[{k!r}] = {v!r}: want finite number")
    for k, h in snap["histograms"].items():
        if not isinstance(h, dict) or not _HIST_KEYS <= set(h):
            raise ValueError(f"histograms[{k!r}] missing keys "
                             f"{sorted(_HIST_KEYS - set(h or {}))}")
        le = h["le"]
        if (not isinstance(le, list) or not le
                or any(not _num(b) for b in le) or le != sorted(le)):
            raise ValueError(f"histograms[{k!r}].le must be ascending finite "
                             "numbers")
        bc = h["bucket_counts"]
        if not isinstance(bc, list) or len(bc) != len(le) + 1 \
                or any(not isinstance(c, int) or c < 0 for c in bc):
            raise ValueError(f"histograms[{k!r}].bucket_counts must be "
                             f"{len(le) + 1} non-negative ints")
        if not isinstance(h["count"], int) or sum(bc) != h["count"]:
            raise ValueError(f"histograms[{k!r}]: bucket_counts sum "
                             f"{sum(bc)} != count {h['count']!r}")
        if not _num(h["sum"]) or any(not _num(h[p])
                                     for p in ("p50", "p95", "p99")):
            raise ValueError(f"histograms[{k!r}]: sum/percentiles must be "
                             "finite numbers")
    return snap


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("snapshot", help="path to a MetricsRegistry.snapshot() "
                                     "JSON file")
    ap.add_argument("--require-nonzero", action="append", default=[],
                    metavar="PREFIX",
                    help="demand >=1 nonzero counter whose key contains "
                         "PREFIX (repeatable)")
    ap.add_argument("--require-hist", action="append", default=[],
                    metavar="PREFIX",
                    help="demand >=1 histogram whose key contains PREFIX "
                         "with count > 0 (repeatable)")
    args = ap.parse_args(argv)
    with open(args.snapshot) as f:
        snap = json.load(f)
    validate_snapshot(snap)
    for prefix in args.require_nonzero:
        hits = {k: v for k, v in snap["counters"].items()
                if prefix in k and v > 0}
        if not hits:
            print(f"FAIL: no nonzero counter matching {prefix!r}",
                  file=sys.stderr)
            return 1
        print(f"ok: {prefix!r} -> {len(hits)} nonzero counter(s), e.g. "
              f"{next(iter(hits))}")
    for prefix in args.require_hist:
        hits = {k: h for k, h in snap["histograms"].items()
                if prefix in k and h["count"] > 0}
        if not hits:
            print(f"FAIL: no populated histogram matching {prefix!r}",
                  file=sys.stderr)
            return 1
        print(f"ok: {prefix!r} -> {len(hits)} populated histogram(s), e.g. "
              f"{next(iter(hits))}")
    n = (len(snap["counters"]), len(snap["gauges"]), len(snap["histograms"]))
    print(f"valid snapshot: {n[0]} counters, {n[1]} gauges, "
          f"{n[2]} histograms")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
