"""Observability layer: tracing spans + metrics registry + snapshot schema.

Zero-dependency (stdlib only; jax is imported lazily and only for optional
device-sync timing / profiler hooks), so every layer of the stack —
scheduler, caches, engines, executor, kernels, autotuner — can import it
without cycles or cost. See :mod:`repro.obs.tracing` and
:mod:`repro.obs.metrics` for the two halves, :mod:`repro.obs.validate`
for the snapshot schema contract, and the README "Observability" section
for the operator's view.
"""

from repro.obs import metrics, tracing
from repro.obs.metrics import (MetricsRegistry, counter, gauge, get_registry,
                               histogram, set_registry, snapshot,
                               to_prometheus)
from repro.obs.tracing import (Tracer, arm_profiler, configure, get_tracer,
                               profiled_dispatch, set_tracer, span,
                               sync_ready)
from repro.obs.validate import validate_snapshot

__all__ = [
    "metrics", "tracing",
    "MetricsRegistry", "counter", "gauge", "histogram", "get_registry",
    "set_registry", "snapshot", "to_prometheus",
    "Tracer", "span", "configure", "get_tracer", "set_tracer", "sync_ready",
    "arm_profiler", "profiled_dispatch",
    "validate_snapshot",
]
