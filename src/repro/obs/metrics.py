"""Process-wide metrics registry: counters, gauges, fixed-bucket histograms.

One :class:`MetricsRegistry` per process (swap with :func:`set_registry`)
absorbs the stack's runtime accounting — cache hits, kernel fallbacks with
reasons, fusion admissions, dispatch counts, request latencies, memory-model
watermarks — so "what did the service actually do" is one snapshot away
instead of scattered ad-hoc attributes.

* **Counter** — monotonically increasing float (``inc``).
* **Gauge** — last-write-wins float (``set``).
* **Histogram** — fixed-bucket accumulation; p50/p95/p99 come from linear
  interpolation inside the winning bucket, so percentile error is bounded
  by the bucket width (the tests check this against numpy quantiles).

Metrics are identified by ``(name, sorted label pairs)``; the snapshot and
Prometheus forms render this as ``name{k="v",...}``. Export:

* :meth:`MetricsRegistry.snapshot` — a JSON-ready dict (schema versioned,
  validated by :mod:`repro.obs.validate`);
* :meth:`MetricsRegistry.to_prometheus` — Prometheus text format
  (``*_bucket``/``*_sum``/``*_count`` series for histograms).

Instrumentation that runs under ``jax.jit`` (kernel dispatch decisions)
increments counters at *trace* time — once per compiled shape, which is
exactly the granularity at which those decisions are made.
"""

from __future__ import annotations

import threading

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "get_registry", "set_registry", "counter", "gauge", "histogram",
    "snapshot", "to_prometheus", "DEFAULT_TIME_BUCKETS", "SNAPSHOT_SCHEMA",
]

SNAPSHOT_SCHEMA = 1

# Log-spaced latency buckets (seconds): 10us .. 100s, {1, 2.5, 5} per decade.
DEFAULT_TIME_BUCKETS: tuple[float, ...] = tuple(
    m * 10.0 ** e for e in range(-5, 3) for m in (1.0, 2.5, 5.0))


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed upper-bound buckets (ascending, finite) plus an overflow slot."""

    __slots__ = ("le", "bucket_counts", "count", "sum")

    def __init__(self, buckets: tuple[float, ...] = DEFAULT_TIME_BUCKETS):
        le = tuple(float(b) for b in buckets)
        if not le or list(le) != sorted(le):
            raise ValueError("histogram buckets must be ascending and "
                             "non-empty")
        self.le = le
        self.bucket_counts = [0] * (len(le) + 1)   # last slot = overflow
        self.count = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        for i, ub in enumerate(self.le):
            if v <= ub:
                self.bucket_counts[i] += 1
                return
        self.bucket_counts[-1] += 1

    def percentile(self, q: float) -> float:
        """Linear interpolation inside the bucket holding the q-quantile
        (0 <= q <= 1); error is bounded by that bucket's width."""
        if self.count == 0:
            return 0.0
        target = q * self.count
        cum = 0
        lo = 0.0
        for i, ub in enumerate(self.le):
            c = self.bucket_counts[i]
            if cum + c >= target and c > 0:
                frac = (target - cum) / c
                return lo + frac * (ub - lo)
            cum += c
            lo = ub
        return self.le[-1]    # overflow bucket: clamp to the last edge


def _fmt_key(name: str, labels: tuple[tuple[str, str], ...]) -> str:
    if not labels:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Get-or-create metric instruments keyed by (name, labels)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[tuple, Counter] = {}
        self._gauges: dict[tuple, Gauge] = {}
        self._histograms: dict[tuple, Histogram] = {}

    @staticmethod
    def _key(name: str, labels: dict) -> tuple:
        return (name, tuple(sorted((str(k), str(v))
                                   for k, v in labels.items())))

    def counter(self, name: str, **labels) -> Counter:
        k = self._key(name, labels)
        c = self._counters.get(k)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(k, Counter())
        return c

    def gauge(self, name: str, **labels) -> Gauge:
        k = self._key(name, labels)
        g = self._gauges.get(k)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(k, Gauge())
        return g

    def histogram(self, name: str, buckets: tuple[float, ...] | None = None,
                  **labels) -> Histogram:
        k = self._key(name, labels)
        h = self._histograms.get(k)
        if h is None:
            with self._lock:
                h = self._histograms.setdefault(
                    k, Histogram(buckets or DEFAULT_TIME_BUCKETS))
        return h

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()

    # ------------------------------------------------------------- export
    def snapshot(self) -> dict:
        """JSON-ready dict of everything (schema-versioned; all values
        finite floats/ints, so ``json.dump`` round-trips losslessly)."""
        counters = {_fmt_key(*k): c.value
                    for k, c in sorted(self._counters.items())}
        gauges = {_fmt_key(*k): g.value
                  for k, g in sorted(self._gauges.items())}
        hists = {}
        for k, h in sorted(self._histograms.items()):
            hists[_fmt_key(*k)] = {
                "count": h.count, "sum": h.sum, "le": list(h.le),
                "bucket_counts": list(h.bucket_counts),
                "p50": h.percentile(0.50), "p95": h.percentile(0.95),
                "p99": h.percentile(0.99),
            }
        return {"schema": SNAPSHOT_SCHEMA, "counters": counters,
                "gauges": gauges, "histograms": hists}

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (0.0.4)."""
        lines: list[str] = []
        typed: set[str] = set()

        def type_line(name: str, kind: str) -> None:
            if name not in typed:
                typed.add(name)
                lines.append(f"# TYPE {name} {kind}")

        for (name, labels), c in sorted(self._counters.items()):
            type_line(name, "counter")
            lines.append(f"{_fmt_key(name, labels)} {c.value:g}")
        for (name, labels), g in sorted(self._gauges.items()):
            type_line(name, "gauge")
            lines.append(f"{_fmt_key(name, labels)} {g.value:g}")
        for (name, labels), h in sorted(self._histograms.items()):
            type_line(name, "histogram")
            cum = 0
            for ub, c in zip(h.le, h.bucket_counts):
                cum += c
                lbl = labels + (("le", f"{ub:g}"),)
                lines.append(f"{_fmt_key(name + '_bucket', lbl)} {cum}")
            lbl = labels + (("le", "+Inf"),)
            lines.append(f"{_fmt_key(name + '_bucket', lbl)} {h.count}")
            lines.append(f"{_fmt_key(name + '_sum', labels)} {h.sum:g}")
            lines.append(f"{_fmt_key(name + '_count', labels)} {h.count}")
        return "\n".join(lines) + ("\n" if lines else "")


# ---------------------------------------------------------------- globals
_registry = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return _registry


def set_registry(r: MetricsRegistry) -> MetricsRegistry:
    global _registry
    _registry = r
    return r


def counter(name: str, **labels) -> Counter:
    return _registry.counter(name, **labels)


def gauge(name: str, **labels) -> Gauge:
    return _registry.gauge(name, **labels)


def histogram(name: str, buckets: tuple[float, ...] | None = None,
              **labels) -> Histogram:
    return _registry.histogram(name, buckets, **labels)


def snapshot() -> dict:
    return _registry.snapshot()


def to_prometheus() -> str:
    return _registry.to_prometheus()
