"""First-class query API: the one-stop facade over the counting stack.

The unit of a query is a :class:`~repro.core.templates.TemplateSpec` — a
serializable tree description (edge list + root + optional name) that
coerces from registry names, ``TreeTemplate`` objects, and raw edge lists.
A :class:`CountQuery` bundles N specs with a precision contract
(``rel_stderr`` target and/or ``max_iters`` budget) and engine knobs;
:func:`compile_query` lowers it onto a graph as one fused
:class:`~repro.core.engines.CountingEngine` per template size k, so
canonical rooted sub-templates shared *across* the bundle (leaf one-hots,
shared paths/stars, common caterpillar arms) are computed once per
coloring instead of once per template. Template identity everywhere is the
:attr:`~repro.core.templates.TreeTemplate.canonical_hash`, never a name.

Typical use::

    from repro.api import count, count_many, TemplateSpec

    res = count(g, "u5", rel_stderr=0.05)            # registry sugar
    print(res.estimate, "+-", res.stderr, res.ci95)

    bundle = ["u5", "path5", "star5", "u7"]          # motif vector
    for spec, r in zip(bundle, count_many(g, bundle, max_iters=64)):
        print(spec, r.estimate)

    chair = TemplateSpec(edges=((0, 1), (1, 2), (1, 3)))   # arbitrary tree
    count(g, chair, max_iters=32)
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.colorsets import colorful_probability
from repro.core.engines import CountingEngine, build_engine
from repro.core.motif_features import motif_features
from repro.core.templates import TemplateSpec, as_template
from repro.service.requests import RequestResult, RunningStat

__all__ = [
    "TemplateSpec", "as_template", "CountQuery", "CompiledQuery",
    "RequestResult", "compile_query", "count", "count_many", "template",
    "motif_features", "DEFAULT_MAX_ITERS",
]

# hard iteration ceiling for queries that only set a rel_stderr target
DEFAULT_MAX_ITERS = 64


@dataclasses.dataclass
class CountQuery:
    """N templates + a precision contract + a budget, as one declarative
    query. ``templates`` coerces each entry through
    :meth:`TemplateSpec.of`; the contract mirrors the service's
    :class:`~repro.service.requests.CountRequest` (``rel_stderr`` adaptive
    target and/or ``max_iters`` cap, ``min_iters`` early-stop guard);
    ``memory_budget_bytes`` bounds each fused engine's device tables via
    the executor's memory model; ``reorder`` ("rcm" or "degree") permutes
    the graph once per engine for BSR locality, with results mapped back to
    the caller's vertex ids at the boundary."""

    templates: tuple[TemplateSpec, ...]
    rel_stderr: float | None = None
    max_iters: int | None = None
    min_iters: int = 4
    seed: int = 0
    engine: str = "pgbsc"
    plan: str = "optimized"
    round_size: int = 8
    memory_budget_bytes: int | None = None
    batch_size: int | None = None
    reorder: str | None = None

    def __post_init__(self):
        tpls = self.templates
        if isinstance(tpls, str) or not isinstance(tpls, (list, tuple)):
            tpls = (tpls,)
        self.templates = tuple(TemplateSpec.of(t) for t in tpls)

    def validate(self) -> None:
        if not self.templates:
            raise ValueError("query needs at least one template")
        if self.rel_stderr is None and self.max_iters is None:
            raise ValueError("query needs a precision contract: "
                             "rel_stderr and/or max_iters")
        if self.rel_stderr is not None and self.rel_stderr <= 0:
            raise ValueError(f"rel_stderr must be > 0, got {self.rel_stderr}")
        if self.max_iters is not None and self.max_iters < 1:
            raise ValueError(f"max_iters must be >= 1, got {self.max_iters}")

    @property
    def cap(self) -> int:
        return self.max_iters if self.max_iters is not None \
            else DEFAULT_MAX_ITERS


class CompiledQuery:
    """A :class:`CountQuery` lowered onto one graph.

    Templates are grouped by k (one coloring stream per k) and each group
    becomes a single fused-plan engine; :meth:`run` drives adaptive rounds
    per group and returns one :class:`RequestResult` per template, in query
    order. ``engines`` exposes the group engines (dispatch counters
    included) for introspection and tests.
    """

    def __init__(self, g, query: CountQuery, engine_cache=None):
        query.validate()
        self.g = g
        self.query = query
        by_k: dict[int, list[int]] = {}
        for i, spec in enumerate(query.templates):
            by_k.setdefault(spec.k, []).append(i)
        kw = {}
        if query.memory_budget_bytes is not None:
            kw["memory_budget_bytes"] = int(query.memory_budget_bytes)
        if query.reorder:
            kw["reorder"] = query.reorder
        self.groups: list[tuple[list[int], CountingEngine]] = []
        for k in sorted(by_k):
            idxs = by_k[k]
            specs = [query.templates[i] for i in idxs]
            tpl = specs if len(specs) > 1 else specs[0]
            if engine_cache is not None:
                eng = engine_cache.get(g, tpl, query.engine, query.plan, **kw)
            else:
                trees = [s.tree for s in specs]
                eng = build_engine(g, trees if len(trees) > 1 else trees[0],
                                   query.engine, plan=query.plan, **kw)
            self.groups.append((idxs, eng))

    @property
    def engines(self) -> list[CountingEngine]:
        return [eng for _, eng in self.groups]

    def _satisfied(self, stat: RunningStat) -> bool:
        q = self.query
        if stat.n >= q.cap:
            return True
        return (q.rel_stderr is not None
                and stat.n >= min(q.min_iters, q.cap)
                and stat.rel_stderr <= q.rel_stderr)

    def run(self) -> list[RequestResult]:
        q = self.query
        out: list[RequestResult | None] = [None] * len(q.templates)
        for idxs, eng in self.groups:
            t0 = time.time()
            p = colorful_probability(eng.k)
            scales = [1.0 / (q.templates[i].automorphisms * p) for i in idxs]
            stats = [RunningStat() for _ in idxs]
            cursor = 0
            while not all(self._satisfied(s) for s in stats):
                n_new = min(q.round_size, q.cap - cursor)
                if n_new <= 0:
                    break
                ids = list(range(cursor, cursor + n_new))
                per = eng.count_iterations_batch(ids, seed=q.seed,
                                                 batch_size=q.batch_size)
                for it in ids:
                    vals = np.atleast_1d(np.asarray(per[it]))
                    for j, stat in enumerate(stats):
                        # retired templates stop consuming, exactly like
                        # service requests that met their target
                        if not self._satisfied(stat):
                            stat.update(float(vals[j]) * scales[j])
                cursor += n_new
            seconds = time.time() - t0
            for j, i in enumerate(idxs):
                stat = stats[j]
                out[i] = RequestResult(
                    estimate=stat.mean, stderr=stat.stderr,
                    rel_stderr=stat.rel_stderr, ci95=stat.ci95,
                    iterations=stat.n,
                    target_met=(q.rel_stderr is None
                                or stat.rel_stderr <= q.rel_stderr),
                    shared_group=len(idxs) > 1, seconds=seconds)
        return out


def compile_query(g, query: CountQuery, engine_cache=None) -> CompiledQuery:
    """Lower a :class:`CountQuery` onto ``g``: one fused engine per k-group
    (served from ``engine_cache`` when given — keys are canonical hashes,
    so two spellings of the same tree share one engine)."""
    return CompiledQuery(g, query, engine_cache=engine_cache)


def count_many(g, templates, *, rel_stderr: float | None = None,
               max_iters: int | None = None, min_iters: int = 4,
               seed: int = 0, engine: str = "pgbsc", plan: str = "optimized",
               round_size: int = 8, memory_budget_bytes: int | None = None,
               batch_size: int | None = None, reorder: str | None = None,
               engine_cache=None) -> list[RequestResult]:
    """Estimate counts for N templates with cross-template subplan sharing.

    Accepts any mix of registry names, :class:`TemplateSpec`, TreeTemplate
    objects, and raw edge lists; returns one result per template, in input
    order. Same-k templates run on ONE fused plan, so their shared
    canonical sub-templates cost one SpMM per coloring for the whole
    bundle; each template's samples still come from exactly the colorings a
    solo :func:`count` with the same seed would draw, so the estimates
    agree with per-template runs to floating-point reassociation.
    """
    if rel_stderr is None and max_iters is None:
        max_iters = DEFAULT_MAX_ITERS
    if isinstance(templates, str):    # a bare name is one template, not
        templates = (templates,)      # an iterable of characters
    query = CountQuery(
        templates=tuple(templates), rel_stderr=rel_stderr,
        max_iters=max_iters, min_iters=min_iters, seed=seed, engine=engine,
        plan=plan, round_size=round_size,
        memory_budget_bytes=memory_budget_bytes, batch_size=batch_size,
        reorder=reorder)
    return compile_query(g, query, engine_cache=engine_cache).run()


def count(g, template, **kw) -> RequestResult:
    """Estimate the count of one template (see :func:`count_many` for the
    accepted template forms and keywords)."""
    return count_many(g, [template], **kw)[0]


def template(obj) -> TemplateSpec:
    """Coerce anything template-ish into a :class:`TemplateSpec`."""
    return TemplateSpec.of(obj)
