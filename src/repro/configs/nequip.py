"""nequip: O(3)-equivariant interatomic potential, l_max=2
[arXiv:2101.03164]. Cartesian-irrep implementation (see models/equivariant)."""
from repro.configs.base import ArchConfig, GNNConfig
from repro.configs.shapes import gnn_cells

CONFIG = ArchConfig(
    arch_id="nequip", family="gnn",
    model=GNNConfig(name="nequip", kind="nequip", n_layers=5, d_hidden=32,
                    n_classes=1,
                    extras=(("l_max", 2), ("n_rbf", 8), ("cutoff", 5.0))),
    cells=gnn_cells(),
    notes="Non-molecule cells feed synthetic positions/species with the "
          "cell's node/edge counts (graph shapes are family-wide).",
)
