"""smollm-360m: llama-arch small dense LM [hf:HuggingFaceTB/SmolLM; hf]."""
from repro.configs.base import ArchConfig, LMConfig
from repro.configs.shapes import lm_cells

CONFIG = ArchConfig(
    arch_id="smollm-360m", family="lm",
    model=LMConfig(
        name="smollm-360m", n_layers=32, d_model=960, n_heads=15,
        n_kv_heads=5, d_ff=2560, vocab_size=49152),
    cells=lm_cells(),
    notes="GQA 3:1 (15q/5kv); heads not divisible by model axis -> "
          "head_dim-sharded attention (see train/sharding.py).",
)
