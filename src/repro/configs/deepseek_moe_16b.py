"""deepseek-moe-16b: 2 shared + 64 routed experts, top-6, fine-grained
[arXiv:2401.06066]. Layer 0 is a dense FFN (d_ff 10944) per the paper."""
from repro.configs.base import ArchConfig, LMConfig, MoEConfig
from repro.configs.shapes import lm_cells

CONFIG = ArchConfig(
    arch_id="deepseek-moe-16b", family="lm",
    model=LMConfig(
        name="deepseek-moe-16b", n_layers=28, d_model=2048, n_heads=16,
        n_kv_heads=16, d_ff=1408, vocab_size=102400,
        moe=MoEConfig(n_experts=64, top_k=6, n_shared=2),
        first_dense_layers=1, dense_d_ff=10944),
    cells=lm_cells(),
)
