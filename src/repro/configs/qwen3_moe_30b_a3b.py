"""qwen3-moe-30b-a3b: 128 experts top-8, QK-norm [hf:Qwen/Qwen3-30B-A3B]."""
from repro.configs.base import ArchConfig, LMConfig, MoEConfig
from repro.configs.shapes import lm_cells

CONFIG = ArchConfig(
    arch_id="qwen3-moe-30b-a3b", family="lm",
    model=LMConfig(
        name="qwen3-moe-30b-a3b", n_layers=48, d_model=2048, n_heads=32,
        n_kv_heads=4, d_ff=768, vocab_size=151936, d_head=128,
        use_qk_norm=True, rope_theta=1_000_000.0,
        moe=MoEConfig(n_experts=128, top_k=8)),
    cells=lm_cells(),
)
