"""Config dataclasses for the assigned architectures.

Every architecture id maps to an ArchConfig with its model config and its
four input-shape cells (the assigned (arch x shape) grid). ``input_specs``
produce jax.ShapeDtypeStruct stand-ins — no allocation — for dry-run
lowering; smoke tests build *reduced* configs via ``reduced()``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

__all__ = ["MoEConfig", "LMConfig", "GNNConfig", "RecsysConfig",
           "ShapeCell", "ArchConfig"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    groups: int = 1   # GShard dispatch groups; = data-shard count at scale


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int | None = None            # default d_model // n_heads
    moe: MoEConfig | None = None
    first_dense_layers: int = 0          # leading dense-FFN layers (deepseek)
    dense_d_ff: int | None = None        # FFN width of those layers
    sliding_window: int | None = None    # local-attention window (gemma3)
    global_every: int = 0                # every Nth layer is global (gemma3 6)
    use_qk_norm: bool = False            # qwen3
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    param_dtype: Any = jnp.bfloat16
    # memory/compile knobs
    remat: bool = True
    scan_layers: bool = True
    attn_unroll: bool = False   # dry-run: python-loop attention chunks

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    def param_count(self) -> int:
        d, dh = self.d_model, self.head_dim
        attn = d * dh * (self.n_heads + 2 * self.n_kv_heads) + \
            self.n_heads * dh * d
        if self.moe:
            ff_moe = 3 * d * self.d_ff * (self.moe.n_experts
                                          + self.moe.n_shared)
            router = d * self.moe.n_experts
            n_moe = self.n_layers - self.first_dense_layers
            ff_total = n_moe * (ff_moe + router) + self.first_dense_layers * \
                3 * d * (self.dense_d_ff or self.d_ff)
        else:
            ff_total = self.n_layers * 3 * d * self.d_ff
        norms = self.n_layers * 2 * d + d
        return (self.n_layers * attn + ff_total + norms
                + 2 * self.vocab_size * d)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top_k experts only)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        attn = d * self.head_dim * (self.n_heads + 2 * self.n_kv_heads) + \
            self.n_heads * self.head_dim * d
        ff_active = 3 * d * self.d_ff * (self.moe.top_k + self.moe.n_shared)
        n_moe = self.n_layers - self.first_dense_layers
        ff_total = n_moe * (ff_active + d * self.moe.n_experts) + \
            self.first_dense_layers * 3 * d * (self.dense_d_ff or self.d_ff)
        return (self.n_layers * attn + ff_total + 2 * self.vocab_size * d)


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    kind: str                    # graphsage | pna | gatedgcn | nequip
    n_layers: int
    d_hidden: int
    extras: tuple = ()           # kind-specific (key, value) pairs
    n_classes: int = 64
    param_dtype: Any = jnp.float32

    def extra(self, key, default=None):
        return dict(self.extras).get(key, default)


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    n_sparse: int = 39
    n_dense: int = 13
    embed_dim: int = 16
    n_attn_layers: int = 3
    n_heads: int = 2
    d_attn: int = 32
    vocab_size: int = 1_000_000   # rows per sparse table
    bag_fields: int = 2           # leading fields are multi-hot bags
    bag_size: int = 8             # nnz per bag (padded)
    mlp_dims: tuple = (256, 128)
    param_dtype: Any = jnp.float32


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (architecture x input-shape) grid cell."""

    name: str
    kind: str        # train | prefill | decode | serve
    dims: dict

    def __repr__(self):
        return f"ShapeCell({self.name}, {self.kind}, {self.dims})"


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str      # lm | gnn | recsys | pgbsc
    model: Any
    cells: tuple[ShapeCell, ...]
    notes: str = ""

    def cell(self, name: str) -> ShapeCell:
        for c in self.cells:
            if c.name == name:
                return c
        raise KeyError(f"{self.arch_id} has no shape cell {name!r}; "
                       f"have {[c.name for c in self.cells]}")
