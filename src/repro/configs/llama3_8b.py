"""llama3-8b: dense GQA LM with 128k vocab [arXiv:2407.21783]."""
from repro.configs.base import ArchConfig, LMConfig
from repro.configs.shapes import lm_cells

CONFIG = ArchConfig(
    arch_id="llama3-8b", family="lm",
    model=LMConfig(
        name="llama3-8b", n_layers=32, d_model=4096, n_heads=32,
        n_kv_heads=8, d_ff=14336, vocab_size=128256, rope_theta=500_000.0),
    cells=lm_cells(),
)
