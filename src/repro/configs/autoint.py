"""autoint: self-attentive feature interaction CTR model [arXiv:1810.11921]."""
from repro.configs.base import ArchConfig, RecsysConfig
from repro.configs.shapes import recsys_cells

CONFIG = ArchConfig(
    arch_id="autoint", family="recsys",
    model=RecsysConfig(name="autoint", n_sparse=39, embed_dim=16,
                       n_attn_layers=3, n_heads=2, d_attn=32,
                       vocab_size=1_000_000, n_dense=13),
    cells=recsys_cells(),
)
