"""pna: Principal Neighbourhood Aggregation — 4 aggregators x 3 scalers
[arXiv:2004.05718]."""
from repro.configs.base import ArchConfig, GNNConfig
from repro.configs.shapes import gnn_cells

CONFIG = ArchConfig(
    arch_id="pna", family="gnn",
    model=GNNConfig(name="pna", kind="pna", n_layers=4, d_hidden=75,
                    n_classes=64),
    cells=gnn_cells(),
)
