"""Per-family shape cells and input-spec builders.

``input_specs(arch, cell)`` returns (batch_tree_of_ShapeDtypeStruct,
batch_partition_specs, statics) — nothing is allocated; the dry-run lowers
against these. Node/candidate counts that don't divide the mesh are padded to
the next multiple of 512 (the real pipeline pads identically via
Graph.padded / batch padding).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCell

__all__ = ["lm_cells", "gnn_cells", "recsys_cells", "input_specs",
           "pad_to"]

DATA = ("pod", "data")   # flattened over both when present (mesh-dependent)


def pad_to(n: int, mult: int = 512) -> int:
    return -(-n // mult) * mult


# ------------------------------------------------------------------- cells
def lm_cells() -> tuple[ShapeCell, ...]:
    return (
        ShapeCell("train_4k", "train", {"seq": 4096, "batch": 256}),
        ShapeCell("prefill_32k", "prefill", {"seq": 32768, "batch": 32}),
        ShapeCell("decode_32k", "decode", {"seq": 32768, "batch": 128}),
        ShapeCell("long_500k", "decode", {"seq": 524288, "batch": 1}),
    )


def gnn_cells() -> tuple[ShapeCell, ...]:
    # minibatch_lg: 1024 seeds, fanout 15-10 => 169,984 nodes / 168,960 edges
    return (
        ShapeCell("full_graph_sm", "train",
                  {"n": 2708, "e": 10556, "d_feat": 1433}),
        ShapeCell("minibatch_lg", "train",
                  {"n": 169_984, "e": 168_960, "d_feat": 602,
                   "seeds": 1024}),
        ShapeCell("ogb_products", "train",
                  {"n": 2_449_029, "e": 61_859_140, "d_feat": 100}),
        ShapeCell("molecule", "train",
                  {"n": 30, "e": 64, "batch": 128, "d_feat": 16}),
    )


def recsys_cells() -> tuple[ShapeCell, ...]:
    return (
        ShapeCell("train_batch", "train", {"batch": 65_536}),
        ShapeCell("serve_p99", "serve", {"batch": 512}),
        ShapeCell("serve_bulk", "serve", {"batch": 262_144}),
        ShapeCell("retrieval_cand", "retrieval",
                  {"batch": 1, "n_candidates": 1 << 20, "d_cand": 64}),
    )


# ------------------------------------------------------------- input specs
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _lm_specs(arch: ArchConfig, cell: ShapeCell):
    from repro.models.transformer import init_decode_cache
    m = arch.model
    b, s = cell.dims["batch"], cell.dims["seq"]
    if cell.kind == "train":
        batch = {"tokens": _sds((b, s), jnp.int32),
                 "targets": _sds((b, s), jnp.int32)}
        specs = {"tokens": P(DATA, None), "targets": P(DATA, None)}
        return batch, specs, {}
    if cell.kind == "prefill":
        batch = {"tokens": _sds((b, s), jnp.int32)}
        specs = {"tokens": P(DATA, None)}
        return batch, specs, {}
    # decode: cache + one token (cache dtype follows param dtype)
    cache_dtype = m.param_dtype
    cache = jax.eval_shape(
        lambda: init_decode_cache(m, b, s, dtype=cache_dtype))
    if b == 1:
        # long-context single-request decode: S over model only (a single
        # mesh axis keeps the size-1 cache write partitionable), head_dim
        # over data (flash-decoding-style partial attention both ways).
        cache_spec = P(None, None, "model", None, "data")
        tok_spec = P(None, None)
    else:
        cache_spec = P(None, DATA, "model", None, None)
        tok_spec = P(DATA, None)
    cache_specs = {
        "k": cache_spec, "v": cache_spec,
        "k_front": cache_spec, "v_front": cache_spec,
        "len": P(),
    }
    batch = {"token": _sds((b, 1), jnp.int32), "cache": cache}
    specs = {"token": tok_spec, "cache": cache_specs}
    return batch, specs, {}


def _gnn_specs(arch: ArchConfig, cell: ShapeCell):
    m = arch.model
    d = cell.dims
    if cell.name in ("molecule", "smoke_molecule"):
        n = d["n"] * d["batch"]
        e = d["e"] * d["batch"]
        n_graphs = d["batch"]
        pooled = True
    else:
        n, e = d["n"], d["e"]
        n_graphs = 1
        pooled = False
    # pad for sharding on the big cells; small cells stay replicated.
    # Edges shard over BOTH mesh axes: per-layer (E, d) message tensors are
    # the GNN activation hog (gatedgcn ogb: 17 GiB/layer global) and edges
    # have no model-axis conflict (§Perf iteration 6).
    big = n >= 100_000
    n_p = pad_to(n) if big else n
    e_p = pad_to(e) if big else e
    node_spec = P(DATA, None) if big else P(None, None)
    flat_spec = P(DATA) if big else P(None)
    edge_spec = P(None, DATA + ("model",)) if big else P(None, None)

    batch = {"edge_index": _sds((2, e_p), jnp.int32)}
    specs = {"edge_index": edge_spec}
    statics = {"n_graphs": n_graphs, "pool": pooled}

    if m.kind == "nequip":
        batch.update(positions=_sds((n_p, 3), jnp.float32),
                     species=_sds((n_p,), jnp.int32),
                     node_graph=_sds((n_p,), jnp.int32),
                     labels=_sds((n_graphs,), jnp.float32))
        specs.update(positions=node_spec, species=flat_spec,
                     node_graph=flat_spec,
                     labels=P(DATA) if n_graphs >= 128 else P(None))
        return batch, specs, statics

    batch.update(x=_sds((n_p, d["d_feat"]), jnp.float32))
    specs.update(x=node_spec)
    if pooled:
        batch.update(node_graph=_sds((n_p,), jnp.int32),
                     labels=_sds((n_graphs,), jnp.float32))
        specs.update(node_graph=flat_spec,
                     labels=P(DATA) if n_graphs >= 128 else P(None))
    else:
        batch.update(labels=_sds((n_p,), jnp.int32),
                     label_mask=_sds((n_p,), jnp.float32),
                     node_graph=_sds((n_p,), jnp.int32))
        specs.update(labels=flat_spec, label_mask=flat_spec,
                     node_graph=flat_spec)
    return batch, specs, statics


def _recsys_specs(arch: ArchConfig, cell: ShapeCell):
    m = arch.model
    b = cell.dims["batch"]
    big = b >= 512
    bs = P(DATA) if big else P(None)
    batch = {
        "sparse_ids": _sds((b, m.n_sparse), jnp.int32),
        "bag_ids": _sds((b, m.bag_fields, m.bag_size), jnp.int32),
        "dense": _sds((b, m.n_dense), jnp.float32),
    }
    specs = {
        "sparse_ids": P(DATA, None) if big else P(None, None),
        "bag_ids": P(DATA, None, None) if big else P(None, None, None),
        "dense": P(DATA, None) if big else P(None, None),
    }
    if cell.kind == "train":
        batch["labels"] = _sds((b,), jnp.float32)
        specs["labels"] = bs
    if cell.kind == "retrieval":
        nc, dc = cell.dims["n_candidates"], cell.dims["d_cand"]
        n_fields = m.n_sparse + 1
        batch["candidates"] = _sds((nc, dc), jnp.float32)
        batch["retrieval_proj"] = _sds((n_fields * m.d_attn, dc), jnp.float32)
        specs["candidates"] = P(DATA + ("model",), None)
        specs["retrieval_proj"] = P(None, None)
    return batch, specs, statics_recsys()


def statics_recsys():
    return {}


def input_specs(arch: ArchConfig, cell_name: str):
    cell = arch.cell(cell_name)
    if arch.family == "lm":
        return _lm_specs(arch, cell)
    if arch.family == "gnn":
        return _gnn_specs(arch, cell)
    if arch.family == "recsys":
        return _recsys_specs(arch, cell)
    raise ValueError(arch.family)


def decode_hint_specs(arch: ArchConfig, cell: ShapeCell):
    """Per-layer cache + logits PartitionSpecs for decode shard hints."""
    b = cell.dims["batch"]
    if b == 1:
        cache = P(None, "model", None, "data")    # (B, S, Hkv, Dh)
        logits = P(None, None, None, None, "model")   # (B, Hkv, G, 1, S)
    else:
        cache = P(DATA, "model", None, None)
        logits = P(DATA, None, None, None, "model")
    return {"cache": cache, "logits": logits}


def resolve_for_mesh(spec_tree, mesh):
    """Drop mesh axes that don't exist (e.g. 'pod' on a single-pod mesh)."""
    names = set(mesh.axis_names)

    def fix_entry(e):
        if e is None:
            return None
        if isinstance(e, str):
            return e if e in names else None
        kept = tuple(a for a in e if a in names)
        return kept if kept else None

    def fix(p):
        return P(*(fix_entry(e) for e in p))

    return jax.tree_util.tree_map(
        fix, spec_tree, is_leaf=lambda x: isinstance(x, P))
