"""Architecture registry: --arch <id> resolves here.

Includes the 10 assigned architectures plus the paper's own PGBSC workloads
(pgbsc-* configs are handled by launch/dryrun.py directly since their "step"
is the distributed counting step, not a train step).
"""

from __future__ import annotations

import dataclasses

from repro.configs import (autoint, deepseek_moe_16b, gatedgcn, gemma3_1b,
                           graphsage_reddit, llama3_8b, nequip, pna,
                           qwen3_moe_30b_a3b, smollm_360m)
from repro.configs.base import (ArchConfig, GNNConfig, LMConfig, MoEConfig,
                                RecsysConfig, ShapeCell)
from repro.configs.shapes import input_specs, resolve_for_mesh

_MODULES = (smollm_360m, llama3_8b, gemma3_1b, deepseek_moe_16b,
            qwen3_moe_30b_a3b, graphsage_reddit, pna, gatedgcn, nequip,
            autoint)

REGISTRY: dict[str, ArchConfig] = {m.CONFIG.arch_id: m.CONFIG
                                   for m in _MODULES}
ARCH_IDS = tuple(REGISTRY)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(REGISTRY)}")
    return REGISTRY[arch_id]


def reduced_config(arch_id: str) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests (few layers, small dims,
    few experts, small vocab/tables)."""
    import jax.numpy as jnp
    arch = get_config(arch_id)
    m = arch.model
    if arch.family == "lm":
        moe = m.moe and MoEConfig(n_experts=min(8, m.moe.n_experts),
                                  top_k=min(2, m.moe.top_k),
                                  n_shared=m.moe.n_shared)
        rm = dataclasses.replace(
            m, n_layers=2 + m.first_dense_layers, d_model=64,
            n_heads=max(2, min(4, m.n_heads)),
            n_kv_heads=max(1, min(2, m.n_kv_heads)), d_ff=96,
            dense_d_ff=128 if m.dense_d_ff else None,
            vocab_size=256, d_head=16 if m.d_head else None,
            sliding_window=8 if m.sliding_window else None,
            moe=moe, param_dtype=jnp.float32, remat=False)
        cells = (ShapeCell("smoke_train", "train", {"seq": 32, "batch": 2}),
                 ShapeCell("smoke_prefill", "prefill", {"seq": 48, "batch": 1}),
                 ShapeCell("smoke_decode", "decode", {"seq": 32, "batch": 2}))
    elif arch.family == "gnn":
        rm = dataclasses.replace(m, n_layers=2, d_hidden=16, n_classes=5)
        cells = (
            ShapeCell("smoke_full", "train", {"n": 40, "e": 160, "d_feat": 9}),
            ShapeCell("smoke_molecule", "train",
                      {"n": 8, "e": 16, "batch": 4, "d_feat": 6}),
        )
    else:
        rm = dataclasses.replace(m, vocab_size=64, n_attn_layers=2)
        cells = (
            ShapeCell("smoke_train", "train", {"batch": 16}),
            ShapeCell("smoke_retrieval", "retrieval",
                      {"batch": 2, "n_candidates": 128, "d_cand": 8}),
        )
    return dataclasses.replace(arch, model=rm, cells=cells)


__all__ = ["REGISTRY", "ARCH_IDS", "get_config", "reduced_config",
           "input_specs", "resolve_for_mesh", "ArchConfig", "LMConfig",
           "GNNConfig", "RecsysConfig", "MoEConfig", "ShapeCell"]
