"""gatedgcn: 16-layer edge-gated GCN [arXiv:2003.00982 / 1711.07553]."""
from repro.configs.base import ArchConfig, GNNConfig
from repro.configs.shapes import gnn_cells

CONFIG = ArchConfig(
    arch_id="gatedgcn", family="gnn",
    model=GNNConfig(name="gatedgcn", kind="gatedgcn", n_layers=16,
                    d_hidden=70, n_classes=64),
    cells=gnn_cells(),
)
