"""graphsage-reddit: 2-layer mean-aggregator GraphSAGE [arXiv:1706.02216]."""
from repro.configs.base import ArchConfig, GNNConfig
from repro.configs.shapes import gnn_cells

CONFIG = ArchConfig(
    arch_id="graphsage-reddit", family="gnn",
    model=GNNConfig(name="graphsage-reddit", kind="graphsage", n_layers=2,
                    d_hidden=128, n_classes=41,
                    extras=(("sample_sizes", (25, 10)),)),
    cells=gnn_cells(),
)
