"""gemma3-1b: 5:1 local:global sliding-window attention, 262k vocab
[hf:google/gemma-3-1b-pt]."""
from repro.configs.base import ArchConfig, LMConfig
from repro.configs.shapes import lm_cells

CONFIG = ArchConfig(
    arch_id="gemma3-1b", family="lm",
    model=LMConfig(
        name="gemma3-1b", n_layers=26, d_model=1152, n_heads=4,
        n_kv_heads=1, d_ff=6912, vocab_size=262144, d_head=256,
        sliding_window=512, global_every=6, rope_theta=1_000_000.0),
    cells=lm_cells(),
    notes="Every 6th layer global, others 512-token window; long_500k decode "
          "runs with full-length cache (window-trimmed cache is a recorded "
          "Perf optimization).",
)
